//! Quickstart — a 60-second tour of the public API on the `test` preset.
//!
//! 1. load AOT artifacts into the PJRT engine,
//! 2. generate the synthetic multi-domain corpus,
//! 3. take a few AdamW steps on one shard,
//! 4. build a 2x2 DiPaCo topology, assemble a path, split a delta,
//! 5. apply one per-module Nesterov outer update.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use dipaco::config::{CorpusConfig, TopologySpec};
use dipaco::data::corpus::Corpus;
use dipaco::data::dataset::{BatchSampler, Sharding};
use dipaco::optim::{Nesterov, OuterAccumulator};
use dipaco::runtime::engine::{artifact_dir, Engine};
use dipaco::topology::{ModuleStore, Topology};

fn main() -> Result<()> {
    // 1. engine
    let engine = Engine::load(&artifact_dir("test"))?;
    let mc = engine.model().clone();
    println!(
        "engine: preset={} params={} batch={} seq={}",
        mc.preset, engine.manifest.total_params, mc.batch, mc.seq_train
    );

    // 2. corpus
    let corpus = Corpus::synthetic(&CorpusConfig {
        n_domains: 4,
        n_docs: 200,
        doc_len: (80, 140),
        skew: 0.0,
        seed: 1,
    });
    println!("corpus: {} docs, {} train", corpus.docs.len(), corpus.train.len());

    // 3. a few inner steps
    let n = engine.manifest.total_params;
    let mut theta = engine.init(0)?;
    let (mut m, mut v) = (vec![0.0; n], vec![0.0; n]);
    let sharding = Sharding::single(&corpus, 0.0, 1);
    let mut sampler = BatchSampler::new(&sharding.shards[0].docs, mc.batch, mc.seq_train, 2);
    let theta_before = theta.clone();
    for step in 1..=5 {
        let (tokens, _) = sampler.next_batch(&corpus);
        let out = engine.train_step(&theta, &m, &v, step as f32, 1e-3, &tokens)?;
        println!("  step {step}: loss {:.4}", out.loss);
        theta = out.theta;
        m = out.m;
        v = out.v;
    }

    // 4. DiPaCo topology algebra
    let topo = Topology::build(&engine.manifest, &TopologySpec::grid(vec![2, 2]));
    println!(
        "topology: {} paths, {} modules, mixture {} params",
        topo.paths,
        topo.all_modules().len(),
        topo.mixture_params()
    );
    let store = ModuleStore::from_base(&topo, &theta_before);
    let assembled = store.assemble(&topo, 3);
    assert_eq!(assembled, theta_before);
    let deltas = topo.split_delta(3, &theta_before, &theta);
    for (mid, d) in &deltas {
        let norm: f32 = d.iter().map(|x| x * x).sum::<f32>().sqrt();
        println!("  outer gradient {mid}: {} floats, |Delta| = {norm:.4}", d.len());
    }

    // 5. one outer update on the first module
    let (mid, d) = &deltas[0];
    let mut acc = OuterAccumulator::new(d.len());
    acc.add(d, 1.0);
    let mut store = store;
    let mut opt = Nesterov::new(0.7, 0.9);
    opt.step(*mid, store.get_mut(*mid), &acc.average());
    println!("applied Nesterov outer update to {mid}");

    // eval
    let ppl = dipaco::eval::ppl_docs(&engine, &theta, &corpus.valid, &corpus, mc.seq_eval)?;
    println!("validation ppl after 5 steps: {ppl:.2}");
    println!("\nquickstart OK");
    Ok(())
}
