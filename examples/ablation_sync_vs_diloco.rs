//! §4.5 ablation — DiLoCo-style partially-synchronous DiPaCo vs fully
//! synchronous training.
//!
//! Paper: "DiPaCo trained with DiLoCo slightly outperforms their
//! fully-synchronously-trained version by 0.3 and 0.6 perplexity points
//! when using a 2x2 and 4x4 architecture"; at 8x8 sync wins by only 0.1
//! "despite communicating hundreds of times more". Shape: the gap is
//! small (|delta| << the gain over the baseline), i.e. DiLoCo loses
//! essentially nothing while communicating 1/tau as often.
//!
//! Scaled: 2x2 grid, same sharding/steps/schedule both ways.
//! Output: results/ablation_sync.csv.

use anyhow::Result;
use std::sync::Arc;

use dipaco::config::{RunConfig, TopologySpec};
use dipaco::coordinator::phases::DipacoRun;
use dipaco::data::dataset::Sharding;
use dipaco::metrics::{print_table, results_dir, CsvWriter};
use dipaco::routing::features::extract_features;
use dipaco::routing::router::{assignments_of, fit_generative, shard_by_router};
use dipaco::topology::Topology;
use dipaco::train::pipeline::{default_corpus, default_schedule, eval_docs, Env};
use dipaco::train::sync::train_sync;
use dipaco::util::rng::Rng;

const DOCS: usize = 2500;
const PRETRAIN: usize = 200;
const PHASES: usize = 4;
const TAU: usize = 20;

fn main() -> Result<()> {
    let mut engine = dipaco::runtime::engine::Engine::load(
        &dipaco::runtime::engine::artifact_dir("path"),
    )?;
    engine.ensure_loaded("grad_step")?;
    let env = Env {
        engine: Arc::new(engine),
        corpus: Arc::new(dipaco::data::corpus::Corpus::synthetic(&default_corpus(DOCS))),
        workdir: results_dir().join("runs"),
    };
    std::fs::create_dir_all(&env.workdir)?;
    let ev = eval_docs(&env.corpus, 64);
    let steps = PHASES * TAU;
    let total = PRETRAIN + steps;
    let mut sched = default_schedule(total);
    sched.inner_steps = TAU;
    let base = env.base_model(PRETRAIN, &sched, 7)?;

    // same routing/sharding for both arms
    let spec = TopologySpec::grid(vec![2, 2]);
    let topo = Arc::new(Topology::build(&env.engine.manifest, &spec));
    let feats = extract_features(&env.engine, &base, &env.corpus.train, &env.corpus)?;
    let mut rng = Rng::new(13);
    let router = fit_generative(&feats, topo.paths, None, &Default::default(), &mut rng);
    let sharding = Arc::new(shard_by_router(
        &router,
        &env.corpus.train,
        &feats,
        topo.paths,
        1,
        0.0,
        7,
    ));
    let ev_feats = extract_features(&env.engine, &base, &ev, &env.corpus)?;
    let assign = assignments_of(&router, &ev, &ev_feats);

    // --- arm 1: DiLoCo (tau = 20, communicate once per phase) ---
    let mut run = DipacoRun::new(
        Arc::clone(&env.engine),
        Arc::clone(&env.corpus),
        Arc::clone(&sharding),
        Arc::clone(&topo),
        &base,
        sched.clone(),
        RunConfig {
            workers: 4,
            outer_executors: 2,
            lease_ms: 120_000,
            ..Default::default()
        },
        env.workdir.join("rd").join("ablation-diloco"),
        false,
    )?;
    run.run(PHASES)?;
    let diloco_thetas = run.all_path_thetas();
    run.shutdown();
    let diloco_ppl = dipaco::eval::eval_routed(
        &env.engine,
        &diloco_thetas,
        |d| assign[&d],
        &ev,
        &env.corpus,
        env.engine.model().seq_eval,
    )?;

    // --- arm 2: fully synchronous (communicate every step) ---
    let sync = train_sync(
        &env.engine,
        &env.corpus,
        &sharding,
        &topo,
        &base,
        &sched,
        steps,
        7,
        1,
    )?;
    let sync_thetas: std::collections::HashMap<usize, Vec<f32>> = (0..topo.paths)
        .map(|p| (p, sync.store.assemble(&topo, p)))
        .collect();
    let sync_ppl = dipaco::eval::eval_routed(
        &env.engine,
        &sync_thetas,
        |d| assign[&d],
        &ev,
        &env.corpus,
        env.engine.model().seq_eval,
    )?;

    let mut csv = CsvWriter::create(
        &results_dir().join("ablation_sync.csv"),
        &["arm", "comm_rounds", "valid_ppl"],
    )?;
    csv.row(&["diloco".into(), PHASES.to_string(), format!("{diloco_ppl:.4}")])?;
    csv.row(&["synchronous".into(), steps.to_string(), format!("{sync_ppl:.4}")])?;
    print_table(
        "§4.5 ablation (scaled): DiLoCo vs fully synchronous (2x2 DiPaCo)",
        &["arm", "communication rounds", "valid ppl"],
        &[
            vec!["DiLoCo (tau=20)".into(), PHASES.to_string(), format!("{diloco_ppl:.3}")],
            vec!["fully synchronous".into(), steps.to_string(), format!("{sync_ppl:.3}")],
        ],
    );
    println!(
        "\nshape check: |gap| small -> DiLoCo matches sync with {}x less communication. gap = {:+.3}",
        steps / PHASES,
        diloco_ppl - sync_ppl
    );
    println!("csv: {}", results_dir().join("ablation_sync.csv").display());
    Ok(())
}
