//! Figure 9 — Scaling the number of paths in DiPaCo.
//!
//! Paper: validation PPL improves monotonically as paths (8 -> 256) and
//! total parameters grow, at FIXED path size (serving cost). Scaled grids:
//! 2x2 (P=4), 2x4 (P=8), 4x4 (P=16, shared with Figure 8), plus a
//! path-specific-modules variant (paper §4.2: extra capacity by not
//! communicating some blocks).
//!
//! Output: results/fig9_scaling.csv (config, paths, mixture_params, ppl).

use anyhow::Result;

use dipaco::config::TopologySpec;
use dipaco::metrics::{print_table, results_dir, CsvWriter};
use dipaco::topology::Topology;
use dipaco::train::pipeline::{
    cached_dipaco, default_corpus, default_schedule, eval_docs, std_recipe, Env,
};

const DOCS: usize = 2500;
const PRETRAIN: usize = 200;

fn main() -> Result<()> {
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs"))?;
    let ev = eval_docs(&env.corpus, 64);
    let total = PRETRAIN + 100;
    let sched = default_schedule(total);
    let base = env.base_model(PRETRAIN, &sched, 7)?;

    let mut ps_spec = TopologySpec::grid(vec![2, 4]);
    // paper §4.2: "blocks 0, 5, 6, 11 and the embedding matrix are not
    // communicated" — scaled to 4 blocks: first/last block path-specific.
    ps_spec.path_specific_blocks = vec![0, 3];
    let configs: Vec<(&str, TopologySpec, Option<(usize, usize)>)> = vec![
        ("2x2", TopologySpec::grid(vec![2, 2]), Some((2, 2))),
        ("2x4", TopologySpec::grid(vec![2, 4]), Some((2, 4))),
        ("4x4", TopologySpec::grid(vec![4, 4]), Some((4, 4))),
        ("2x4+path-specific", ps_spec, Some((2, 4))),
    ];

    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        &results_dir().join("fig9_scaling.csv"),
        &["config", "paths", "mixture_params", "valid_ppl"],
    )?;
    for (name, spec, grid) in configs {
        let topo = Topology::build(&env.engine.manifest, &spec);
        let tag = format!("dipaco-{}", name.replace('+', "-"));
        // the 4x4 run is shared with fig8's cache
        let tag = if name == "4x4" { "dipaco-4x4".to_string() } else { tag };
        let overlap = if topo.paths >= 16 { 2 } else { 1 };
        let recipe = std_recipe(&env, spec.clone(), grid, total, overlap, true, &tag);
        let trained = cached_dipaco(&env, &tag, &recipe, base.clone(), 4, 1)?;
        let ppl = trained.ppl_once(&env, &ev, true)?;
        csv.row(&[
            name.into(),
            topo.paths.to_string(),
            topo.mixture_params().to_string(),
            format!("{ppl:.4}"),
        ])?;
        rows.push(vec![
            name.to_string(),
            topo.paths.to_string(),
            format!("{:.2}M", topo.mixture_params() as f64 / 1e6),
            format!("{ppl:.3}"),
        ]);
    }
    print_table(
        "Figure 9 (scaled): scaling paths at fixed path size",
        &["config", "paths", "mixture params", "valid ppl"],
        &rows,
    );
    println!("\nshape check: PPL should improve (drop) down the table as paths grow.");
    println!("csv: {}", results_dir().join("fig9_scaling.csv").display());
    Ok(())
}
