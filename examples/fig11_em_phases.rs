//! Figure 11 — Validation PPL vs number of alternating minimization
//! phases (16-path flat MoE in the paper: 14.0 -> 13.38 -> 13.36 -> 13.25
//! for 0/1/2/3 discriminative phases).
//!
//! Shape: each alternation of [re-shard discriminatively, retrain]
//! improves PPL, with diminishing returns. Scaled: 8-path flat MoE,
//! 2 phases x 20 steps per alternation stage.
//!
//! This driver uses the coordinator API directly (DipacoRun) because it
//! needs arbitrary-depth EM alternation, not the standard 2-stage recipe.
//!
//! Output: results/fig11.csv (alternations, valid_ppl).

use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

use dipaco::config::{RoutingConfig, RunConfig, TopologySpec};
use dipaco::coordinator::phases::DipacoRun;
use dipaco::data::dataset::Sharding;
use dipaco::metrics::{print_table, results_dir, CsvWriter};
use dipaco::routing::features::extract_features;
use dipaco::routing::router::{
    assignments_of, fit_discriminative, fit_generative, score_router_docs, shard_by_router,
    Router,
};
use dipaco::topology::{ModuleStore, Topology};
use dipaco::train::pipeline::{
    default_corpus, default_schedule, eval_docs, router_docs, Env,
};
use dipaco::util::rng::Rng;

const DOCS: usize = 2500;
const PRETRAIN: usize = 200;
const P: usize = 8;
const PHASES_PER_STAGE: usize = 2;
const ALTERNATIONS: usize = 3;

fn main() -> Result<()> {
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs"))?;
    let ev = eval_docs(&env.corpus, 64);
    let rdocs = router_docs(&env.corpus, 96);
    let total = PRETRAIN + (1 + ALTERNATIONS) * PHASES_PER_STAGE * 20;
    let mut sched = default_schedule(total);
    sched.inner_steps = 20;
    let base = env.base_model(PRETRAIN, &sched, 7)?;

    let spec = TopologySpec::flat_moe(P);
    let topo = Arc::new(Topology::build(&env.engine.manifest, &spec));
    let routing = RoutingConfig::default();

    // stage 0: generative sharding
    let train_feats = extract_features(&env.engine, &base, &env.corpus.train, &env.corpus)?;
    let mut rng = Rng::new(11);
    let mut router = fit_generative(&train_feats, P, None, &routing, &mut rng);
    let mut store_seed: Option<HashMap<usize, Vec<f32>>> = None; // thetas per path

    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(&results_dir().join("fig11.csv"), &["alternations", "valid_ppl"])?;

    let mut thetas: HashMap<usize, Vec<f32>> = HashMap::new();
    for alt in 0..=ALTERNATIONS {
        if alt > 0 {
            // EM re-shard: score router docs under current paths, refit.
            let router_feats =
                extract_features(&env.engine, &base, &rdocs, &env.corpus)?;
            let scores = score_router_docs(&env.engine, &thetas, &rdocs, &env.corpus)?;
            router = fit_discriminative(&router_feats, &scores, P, &routing);
        }
        let sharding = Arc::new(shard_by_router(
            &router,
            &env.corpus.train,
            &train_feats,
            P,
            1,
            0.0,
            7 ^ alt as u64,
        ));
        let mut run = DipacoRun::new(
            Arc::clone(&env.engine),
            Arc::clone(&env.corpus),
            sharding,
            Arc::clone(&topo),
            &base,
            sched.clone(),
            RunConfig {
                workers: 4,
                outer_executors: 2,
                lease_ms: 120_000,
                ..Default::default()
            },
            env.workdir.join("rd").join(format!("f11-alt{alt}")),
            false,
        )?;
        if let Some(seed) = &store_seed {
            // continue from the previous stage's modules
            let mut store = run.store.lock().unwrap();
            for m in topo.all_modules() {
                let path = topo.paths_of_module(m)[0];
                let data = topo.extract(m.level, &seed[&path]);
                *store.get_mut(m) = data;
            }
        }
        for t in 0..PHASES_PER_STAGE {
            run.run_phase(alt * PHASES_PER_STAGE + t)?;
        }
        thetas = run.all_path_thetas();
        store_seed = Some(thetas.clone());
        run.shutdown();

        // eval: route valid docs with the CURRENT router
        let ev_feats = extract_features(&env.engine, &base, &ev, &env.corpus)?;
        let assign = assignments_of(&router, &ev, &ev_feats);
        let ppl = dipaco::eval::eval_routed(
            &env.engine,
            &thetas,
            |d| assign[&d],
            &ev,
            &env.corpus,
            env.engine.model().seq_eval,
        )?;
        csv.row(&[alt.to_string(), format!("{ppl:.4}")])?;
        rows.push(vec![alt.to_string(), router_kind(&router).into(), format!("{ppl:.3}")]);
    }

    print_table(
        "Figure 11 (scaled): PPL vs alternating minimization phases (flat MoE P=8)",
        &["alternations", "router", "valid ppl"],
        &rows,
    );
    println!("\nshape check: each alternation improves, with diminishing returns.");
    println!("csv: {}", results_dir().join("fig11.csv").display());
    let _ = ModuleStore::from_base(&topo, &base); // (api parity; silences unused import on some cfgs)
    Ok(())
}

fn router_kind(r: &Router) -> &'static str {
    r.kind()
}
