//! Figure 8 — Convergence curves of DiPaCo vs dense baselines.
//!
//! Paper: a 150M dense model is pretrained, then a 16x16 DiPaCo (P=256,
//! top-2 overlapping shards, one discriminative phase) is fine-tuned from
//! it; its curve dips below the 150M baseline and approaches the dense
//! 1.3B. Scaled here (see DESIGN.md): `path` preset vs `large` preset,
//! 4x4 DiPaCo (P=16).
//!
//! Output: results/fig8_convergence.csv (series, step, valid_ppl) and the
//! paper-shaped summary printed at the end. Run AFTER `make artifacts`.

use anyhow::Result;
use std::sync::Arc;

use dipaco::config::TopologySpec;
use dipaco::metrics::{print_table, results_dir, CsvWriter};
use dipaco::train::pipeline::{
    cached_dense, cached_dipaco, default_corpus, default_schedule, eval_docs, std_recipe, Env,
};

const DOCS: usize = 2500;
const PRETRAIN: usize = 200;
const PHASES: (usize, usize) = (4, 1); // generative, discriminative
const STEPS_PER_PHASE: usize = 20;

fn main() -> Result<()> {
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs"))?;
    let ev = eval_docs(&env.corpus, 64);
    let total = PRETRAIN + (PHASES.0 + PHASES.1) * STEPS_PER_PHASE;

    // --- dense path-sized baseline (the "150M") — full length curve ---
    let sched = default_schedule(total);
    let (_, _, path_ppl) = cached_dense(&env, "dense-path-300", total, &sched, 7)?;

    // --- dense large baseline (the "1.3B") ---
    let env_l = Env::new("large", &default_corpus(DOCS), results_dir().join("runs"))?;
    let (ltheta, _, large_ppl) = cached_dense(&env_l, "dense-large-300", total, &sched, 7)?;
    let large_final = env_l.valid_ppl_subset(&ltheta, &ev)?;

    // --- DiPaCo 4x4 from the 200-step pretrained base ---
    let base = env.base_model(PRETRAIN, &sched, 7)?;
    let recipe = std_recipe(
        &env,
        TopologySpec::grid(vec![4, 4]),
        Some((4, 4)),
        total,
        2,    // top-2 overlapping shards like the paper's 16x16
        true, // early stopping
        "fig8-4x4",
    );
    let trained = cached_dipaco(&env, "dipaco-4x4", &recipe, base.clone(), PHASES.0, PHASES.1)?;

    // DiPaCo eval point at the end + base point at fork
    let base_ppl = env.valid_ppl_subset(&base, &ev)?;
    let dipaco_ppl = trained.ppl_once(&env, &ev, true)?;

    let mut csv = CsvWriter::create(
        &results_dir().join("fig8_convergence.csv"),
        &["series", "step", "valid_ppl"],
    )?;
    for (s, p) in &path_ppl {
        csv.row(&["dense_path".into(), s.to_string(), format!("{p:.4}")])?;
    }
    for (s, p) in &large_ppl {
        csv.row(&["dense_large".into(), s.to_string(), format!("{p:.4}")])?;
    }
    csv.row(&["pretrain_fork".into(), PRETRAIN.to_string(), format!("{base_ppl:.4}")])?;
    // loss curve of DiPaCo phases (train loss; ppl measured at end)
    for (s, l) in &trained.loss_curve {
        csv.row(&["dipaco_4x4_trainloss".into(), (PRETRAIN + s).to_string(), format!("{l:.4}")])?;
    }
    csv.row(&["dipaco_4x4".into(), total.to_string(), format!("{dipaco_ppl:.4}")])?;

    let path_final = path_ppl.last().map(|&(_, p)| p).unwrap_or(f64::NAN);
    print_table(
        "Figure 8 (scaled): final validation PPL",
        &["model", "params/path", "valid ppl"],
        &[
            vec!["dense path-size".into(), "0.25M".into(), format!("{path_final:.3}")],
            vec!["dense large (7x)".into(), "1.7M".into(), format!("{large_final:.3}")],
            vec!["DiPaCo 4x4 (P=16)".into(), "0.25M".into(), format!("{dipaco_ppl:.3}")],
        ],
    );
    println!(
        "\nshape check: DiPaCo ({dipaco_ppl:.3}) < dense path-size ({path_final:.3})? {}",
        dipaco_ppl < path_final
    );
    println!(
        "shape check: DiPaCo within reach of dense large ({large_final:.3})? gap = {:+.3}",
        dipaco_ppl - large_final
    );
    println!("csv: {}", results_dir().join("fig8_convergence.csv").display());
    Ok(())
}
