//! Figure 10 — Generative vs discriminative flat MoE at varying P.
//!
//! Paper: for each path count, the discriminative branch (re-sharded with
//! the trained-paths router) sits below its generative ancestor. Scaled:
//! flat MoE with P ∈ {4, 8}; each P trained (a) purely generatively and
//! (b) with one discriminative re-sharding continuation from the same
//! generative ancestor — exactly the branching structure of the figure.
//!
//! Output: results/fig10.csv (config, paths, routing, ppl).

use anyhow::Result;

use dipaco::config::TopologySpec;
use dipaco::metrics::{print_table, results_dir, CsvWriter};
use dipaco::train::pipeline::{
    cached_dipaco, default_corpus, default_schedule, eval_docs, std_recipe, Env,
};

const DOCS: usize = 2500;
const PRETRAIN: usize = 200;

fn main() -> Result<()> {
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs"))?;
    let ev = eval_docs(&env.corpus, 64);
    let total = PRETRAIN + 100;
    let sched = default_schedule(total);
    let base = env.base_model(PRETRAIN, &sched, 7)?;

    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        &results_dir().join("fig10.csv"),
        &["paths", "routing", "valid_ppl"],
    )?;
    for p in [4usize, 8] {
        // generative branch: all phases on the k-means sharding
        let recipe = std_recipe(
            &env,
            TopologySpec::flat_moe(p),
            None,
            total,
            1,
            false,
            &format!("f10-gen{p}"),
        );
        let gen = cached_dipaco(&env, &format!("f10-gen-p{p}"), &recipe, base.clone(), 5, 0)?;
        let gen_ppl = gen.ppl_once(&env, &ev, false)?;
        // discriminative branch: same ancestor, last phase re-sharded
        let recipe = std_recipe(
            &env,
            TopologySpec::flat_moe(p),
            None,
            total,
            1,
            false,
            &format!("f10-disc{p}"),
        );
        let disc = cached_dipaco(&env, &format!("f10-disc-p{p}"), &recipe, base.clone(), 4, 1)?;
        let disc_ppl = disc.ppl_once(&env, &ev, false)?;
        csv.row(&[p.to_string(), "generative".into(), format!("{gen_ppl:.4}")])?;
        csv.row(&[p.to_string(), "discriminative".into(), format!("{disc_ppl:.4}")])?;
        rows.push(vec![
            format!("P={p}"),
            format!("{gen_ppl:.3}"),
            format!("{disc_ppl:.3}"),
            format!("{:+.3}", disc_ppl - gen_ppl),
        ]);
    }
    print_table(
        "Figure 10 (scaled): generative vs discriminative flat MoE",
        &["paths", "generative ppl", "discriminative ppl", "delta"],
        &rows,
    );
    println!("\nshape check: discriminative branch below its generative ancestor.");
    println!("csv: {}", results_dir().join("fig10.csv").display());
    Ok(())
}
