//! Table 5 (appendix §7.2) — Sharding method impact on an 8x8 DiPaCo.
//!
//! Paper (8x8, P=64, 32 outer steps x 62 inner): k-means 17.2, product
//! k-means 16.8, discriminative 16.5. Shape: discriminative < product
//! k-means < k-means. Scaled: 2x4 DiPaCo (P=8), 4 phases x 20 steps.
//!
//! Output: results/table5.csv.

use anyhow::Result;

use dipaco::config::TopologySpec;
use dipaco::metrics::{print_table, results_dir, CsvWriter};
use dipaco::train::pipeline::{
    cached_dipaco, default_corpus, default_schedule, eval_docs, std_recipe, Env,
};

const DOCS: usize = 2500;
const PRETRAIN: usize = 200;

fn main() -> Result<()> {
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs"))?;
    let ev = eval_docs(&env.corpus, 64);
    let total = PRETRAIN + 80;
    let sched = default_schedule(total);
    let base = env.base_model(PRETRAIN, &sched, 7)?;

    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        &results_dir().join("table5.csv"),
        &["sharding", "valid_ppl"],
    )?;

    // (name, product_kmeans?, discriminative phases)
    let variants: &[(&str, bool, usize)] = &[
        ("k-means", false, 0),
        ("product k-means", true, 0),
        ("discriminative", true, 1), // paper: disc router is based on product k-means init
    ];
    for &(name, product, disc) in variants {
        let mut recipe = std_recipe(
            &env,
            TopologySpec::grid(vec![2, 4]),
            Some((2, 4)),
            total,
            1,
            false,
            &format!("t5-{}", name.replace(' ', "-")),
        );
        recipe.routing.product_kmeans = product;
        let gen = 4 - disc;
        let trained = cached_dipaco(
            &env,
            &format!("t5-{}", name.replace(' ', "-")),
            &recipe,
            base.clone(),
            gen,
            disc,
        )?;
        let ppl = trained.ppl_once(&env, &ev, false)?;
        csv.row(&[name.into(), format!("{ppl:.4}")])?;
        rows.push(vec![name.to_string(), format!("{ppl:.3}")]);
    }

    print_table(
        "Table 5 (scaled): sharding impact on a 2x4 DiPaCo",
        &["sharding", "valid ppl"],
        &rows,
    );
    println!("\nshape check: discriminative <= product k-means <= k-means.");
    println!("csv: {}", results_dir().join("table5.csv").display());
    Ok(())
}
