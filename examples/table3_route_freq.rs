//! Table 3 — Frequent re-routing at eval time.
//!
//! Paper (16x16, P=256, seq 1024): routing once 12.39 (12.22 with early
//! stopping); every 128 -> 11.48, 64 -> 11.38, 32 -> 11.31, 16 -> 11.26;
//! matches a dense 1B (11.41) at W=64. Shape: monotone improvement as the
//! window W shrinks; early stopping helps the once-per-sequence row.
//!
//! Scaled: 4x4 DiPaCo (cached from Figure 8), seq_eval 256, W ∈
//! {64, 32, 16, 8}, learned chunk router (logistic head substitution —
//! DESIGN.md) plus the oracle upper bound.
//!
//! Output: results/table3.csv.

use anyhow::Result;

use dipaco::config::{RoutingConfig, TopologySpec};
use dipaco::eval::{all_path_logprobs, ppl_chunked, ppl_chunked_oracle};
use dipaco::metrics::{print_table, results_dir, CsvWriter};
use dipaco::routing::router::ChunkRouter;
use dipaco::train::pipeline::{
    cached_dipaco, default_corpus, default_schedule, eval_docs, router_docs, std_recipe, Env,
};

const DOCS: usize = 2500;
const PRETRAIN: usize = 200;

fn main() -> Result<()> {
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs"))?;
    let ev = eval_docs(&env.corpus, 64);
    let total = PRETRAIN + 100;
    let sched = default_schedule(total);
    let base = env.base_model(PRETRAIN, &sched, 7)?;

    // 4x4 DiPaCo — shared cache with fig8/fig9/table1.
    let recipe = std_recipe(
        &env,
        TopologySpec::grid(vec![4, 4]),
        Some((4, 4)),
        total,
        2,
        true,
        "dipaco-4x4",
    );
    let trained = cached_dipaco(&env, "dipaco-4x4", &recipe, base, 4, 1)?;

    let mc = env.engine.model().clone();
    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        &results_dir().join("table3.csv"),
        &["early_stop", "route_every", "router", "valid_ppl"],
    )?;

    // rows 1-2: route once per sequence, +- early stopping
    for es in [false, true] {
        let ppl = trained.ppl_once(&env, &ev, es)?;
        csv.row(&[
            if es { "yes" } else { "no" }.into(),
            "once".into(),
            "document".into(),
            format!("{ppl:.4}"),
        ])?;
        rows.push(vec![
            if es { "yes" } else { "no" }.into(),
            "once per sequence".into(),
            format!("{ppl:.3}"),
        ]);
    }

    // chunked rows: precompute per-path logprob matrices ONCE (scoring
    // mode), then sweep W for free — early-stopped params throughout,
    // matching the paper's best rows.
    let scores = all_path_logprobs(&env.engine, &trained.early, &ev, &env.corpus, mc.seq_eval)?;
    let rdocs = router_docs(&env.corpus, 48);
    for w in [64usize, 32, 16, 8] {
        let router = ChunkRouter::train(
            &env.engine,
            &trained.base,
            &trained.early,
            &rdocs,
            &env.corpus,
            w,
            &RoutingConfig {
                logistic_epochs: 25,
                ..Default::default()
            },
        )?;
        let choices = router.route_docs(&env.engine, &trained.base, &ev, &env.corpus, w)?;
        let learned = ppl_chunked(&scores, ev.len(), mc.seq_eval, mc.prefix, w, |d, c| {
            choices[d].get(c).copied().unwrap_or(0)
        });
        let oracle = ppl_chunked_oracle(&scores, ev.len(), mc.seq_eval, mc.prefix, w);
        csv.row(&["yes".into(), w.to_string(), "learned".into(), format!("{learned:.4}")])?;
        csv.row(&["yes".into(), w.to_string(), "oracle".into(), format!("{oracle:.4}")])?;
        rows.push(vec![
            "yes".into(),
            format!("{w}"),
            format!("{learned:.3}  (oracle {oracle:.3})"),
        ]);
    }

    print_table(
        "Table 3 (scaled): frequent routing at eval time (4x4 DiPaCo)",
        &["early stopping", "route every", "valid ppl"],
        &rows,
    );
    println!("\nshape check: ppl improves monotonically as W shrinks; ES helps row 1.");
    println!("csv: {}", results_dir().join("table3.csv").display());
    Ok(())
}
