//! Serving demo — paper §2.6: "at test time, the paths are instantiated,
//! and served independently, with text routed to each path via a router";
//! only a single 150M path executes per query, never the full mixture.
//!
//! Loads the cached 2x2 run (trains a short one if missing), instantiates
//! one "path server" per path (each owns only ITS parameters), routes a
//! stream of incoming documents by prefix features, and reports
//! per-request latency percentiles + throughput.
//!
//! Run: `cargo run --release --example serve_paths` (after train_dipaco)

use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

use dipaco::config::TopologySpec;
use dipaco::metrics::{print_table, results_dir};
use dipaco::train::pipeline::{
    cached_dipaco, default_corpus, default_schedule, std_recipe, Env, TrainedPaths,
};
use dipaco::util::stats::percentile;

const DOCS: usize = 2500;

fn main() -> Result<()> {
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs"))?;
    let mc = env.engine.model().clone();

    // load (or train) a small DiPaCo
    let trained: TrainedPaths = match TrainedPaths::load(&env, "serve-2x2") {
        Some(t) => t,
        None => {
            let total = 200 + 60;
            let sched = default_schedule(total);
            let base = env.base_model(200, &sched, 7)?;
            let recipe = std_recipe(
                &env,
                TopologySpec::grid(vec![2, 2]),
                Some((2, 2)),
                total,
                1,
                false,
                "serve-2x2",
            );
            cached_dipaco(&env, "serve-2x2", &recipe, base, 3, 0)?
        }
    };
    let paths: Vec<usize> = {
        let mut p: Vec<usize> = trained.thetas.keys().copied().collect();
        p.sort();
        p
    };
    println!(
        "serving {} paths of {} params each (mixture never materialized)",
        paths.len(),
        env.engine.manifest.total_params
    );

    // request stream: validation docs, batched per routed path
    let requests: Vec<usize> = env.corpus.valid.iter().copied().take(96).collect();
    let engine = Arc::clone(&env.engine);

    let t0 = Instant::now();
    // step 1: route each request from its prefix (router cost)
    let feats = dipaco::routing::features::extract_features(
        &engine,
        &trained.base,
        &requests,
        &env.corpus,
    )?;
    let routed: Vec<usize> = feats.iter().map(|z| trained.router.assign(z)).collect();
    let route_time = t0.elapsed();

    // step 2: each path server scores its own queue
    let mut latencies: Vec<f64> = Vec::new();
    let mut per_path = vec![0usize; paths.len()];
    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    let serve_t0 = Instant::now();
    for (batch_start, chunk) in requests.chunks(mc.batch).enumerate() {
        let t = Instant::now();
        // group this batch per path (a real deployment would queue per server)
        for (i, &doc) in chunk.iter().enumerate() {
            let gi = batch_start * mc.batch + i;
            per_path[routed[gi]] += 1;
        }
        // serve: execute the (single) assigned path per doc, batched
        let mut toks = Vec::with_capacity(mc.batch * mc.seq_eval);
        for &d in chunk {
            toks.extend_from_slice(&env.corpus.sequence(d, mc.seq_eval));
        }
        for _ in chunk.len()..mc.batch {
            toks.extend_from_slice(&env.corpus.sequence(requests[0], mc.seq_eval));
        }
        let path = routed[batch_start * mc.batch]; // batch-major routing
        let lp = engine.token_logprobs(&trained.thetas[&path], &toks, mc.seq_eval)?;
        let (nll, n) =
            dipaco::eval::nll_masked(&lp, mc.batch, mc.seq_eval, mc.prefix, chunk.len());
        total_nll += nll;
        total_tok += n;
        latencies.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let serve_time = serve_t0.elapsed();
    let served_tokens = requests.len() * (mc.seq_eval - mc.prefix);

    print_table(
        "serving stats",
        &["metric", "value"],
        &[
            vec!["requests".into(), requests.len().to_string()],
            vec!["routing time (all)".into(), format!("{:.1} ms", route_time.as_secs_f64() * 1e3)],
            vec!["batch latency p50".into(), format!("{:.1} ms", percentile(&latencies, 50.0))],
            vec!["batch latency p95".into(), format!("{:.1} ms", percentile(&latencies, 95.0))],
            vec![
                "throughput".into(),
                format!("{:.0} tok/s", served_tokens as f64 / serve_time.as_secs_f64()),
            ],
            vec!["per-path load".into(), format!("{per_path:?}")],
            vec!["served ppl".into(), format!("{:.3}", (total_nll / total_tok as f64).exp())],
        ],
    );
    println!("\nserve_paths OK");
    Ok(())
}
