//! Serving demo — paper §2.6: "at test time, the paths are instantiated,
//! and served independently, with text routed to each path via a router";
//! only a single 150M path executes per query, never the full mixture.
//!
//! Thin client of the `serve::` subsystem (see DESIGN.md, "serve"): loads
//! the cached 2x2 run (trains a short one if missing), starts one path
//! server per path (each owns only ITS parameters), routes a stream of
//! incoming documents INDIVIDUALLY by prefix features — the old inline
//! demo executed whole batches on their first document's path — and
//! reports per-request latency percentiles + throughput from `ServeStats`.
//!
//! Run: `cargo run --release --example serve_paths` (after train_dipaco)

use anyhow::Result;
use std::time::Instant;

use dipaco::config::ServeConfig;
use dipaco::metrics::{print_table, results_dir};
use dipaco::serve::server::{engine_executors, Server};
use dipaco::train::pipeline::{default_corpus, serve_demo_paths, Env};

const DOCS: usize = 2500;
const REQUESTS: usize = 96;

fn main() -> Result<()> {
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs"))?;
    let mc = env.engine.model().clone();

    let trained = serve_demo_paths(&env, "serve-2x2")?;
    println!(
        "serving {} paths of {} params each (mixture never materialized)",
        trained.thetas.len(),
        env.engine.manifest.total_params
    );

    // request stream: validation docs
    let requests: Vec<usize> = env.corpus.valid.iter().copied().take(REQUESTS).collect();

    // step 1: per-document routing features (router admission cost)
    let t0 = Instant::now();
    let feats = dipaco::routing::features::extract_features(
        &env.engine,
        &trained.base,
        &requests,
        &env.corpus,
    )?;
    let route_time = t0.elapsed();

    // step 2: the serve:: subsystem — each document goes to ITS OWN
    // assigned path's queue; partial micro-batches flush on deadline.
    let cfg = ServeConfig::default();
    let server = Server::start(
        &cfg,
        trained.router.clone(),
        engine_executors(&env.engine, trained.thetas)?,
    );
    // The park policy can still reject if a path stays saturated past the
    // admission timeout — count that as backpressure, don't crash.
    let mut tickets = Vec::new();
    let mut rejected = 0usize;
    for (i, (&d, z)) in requests.iter().zip(&feats).enumerate() {
        match server.submit(z, env.corpus.sequence(d, mc.seq_eval)) {
            Ok(t) => tickets.push((i, t)),
            Err(e) => {
                eprintln!("request rejected: {e}");
                rejected += 1;
            }
        }
    }

    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    for (i, t) in tickets {
        // regression guard for the old batch-major bug: the answering
        // path must be the one assigned to THIS document's features
        let expect = trained.router.assign(&feats[i]);
        let resp = t.wait().expect("server answers every admitted request");
        assert_eq!(
            resp.path, expect,
            "doc {i} served by path {} but routed to {expect}",
            resp.path
        );
        total_nll += resp.nll;
        total_tok += resp.tokens_scored;
    }
    let report = server.shutdown();
    assert_eq!(report.served as usize, requests.len() - rejected);

    let mut rows = vec![
        vec!["requests".into(), requests.len().to_string()],
        vec![
            "routing time (all)".into(),
            format!("{:.1} ms", route_time.as_secs_f64() * 1e3),
        ],
    ];
    rows.extend(report.rows());
    rows.push(vec![
        "served ppl".into(),
        format!("{:.3}", (total_nll / total_tok.max(1) as f64).exp()),
    ]);
    print_table("serving stats", &["metric", "value"], &rows);
    println!("\nserve_paths OK (per-document routing honored)");
    Ok(())
}
