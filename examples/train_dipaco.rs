//! END-TO-END DRIVER (the repro brief's required workload): trains a real
//! DiPaCo mixture on the synthetic multi-domain corpus through the FULL
//! stack — Pallas-kernel HLO artifacts, PJRT engine, generative routing,
//! fault-injected worker pool + backup pool + monitor, sharded online
//! outer-optimization executors, one discriminative re-sharding phase,
//! early stopping, and routed + frequent-re-routing evaluation.
//!
//! Logs the loss curve to results/e2e_loss.csv and a summary to
//! results/e2e_summary.json; recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example train_dipaco`

use anyhow::Result;
use std::sync::Arc;

use dipaco::config::{RoutingConfig, RunConfig, TopologySpec};
use dipaco::eval::{all_path_logprobs, ppl_chunked_oracle};
use dipaco::metrics::{results_dir, write_summary, CsvWriter};
use dipaco::routing::router::domain_alignment;
use dipaco::train::dipaco::DipacoRecipe;
use dipaco::train::pipeline::{default_corpus, default_schedule, eval_docs, Env};
use dipaco::util::json::Json;

const DOCS: usize = 2500;
const PRETRAIN: usize = 200;
const GEN_PHASES: usize = 4;
const DISC_PHASES: usize = 1;
const TAU: usize = 20;

fn main() -> Result<()> {
    let t0 = std::time::Instant::now();
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs"))?;
    let ev = eval_docs(&env.corpus, 64);
    let total = PRETRAIN + (GEN_PHASES + DISC_PHASES) * TAU;
    let mut sched = default_schedule(total);
    sched.inner_steps = TAU;

    // 1. pretrain the base model (cached)
    let base = env.base_model(PRETRAIN, &sched, 7)?;
    let base_ppl = env.valid_ppl_subset(&base, &ev)?;
    println!("base model after {PRETRAIN} steps: valid ppl {base_ppl:.3}");

    // 2. DiPaCo 2x2 with the full coordinator, INCLUDING failure injection
    let recipe = DipacoRecipe {
        engine: Arc::clone(&env.engine),
        corpus: Arc::clone(&env.corpus),
        spec: TopologySpec::grid(vec![2, 2]),
        diloco: sched,
        routing: RoutingConfig::default(),
        run: RunConfig {
            workers: 3,
            backup_workers: 1,     // paper §3.4 backup pool
            preemption_prob: 0.15, // live fault injection
            lease_ms: 20_000,
            transfer_delay_ms: 5, // simulated cross-DC checkpoint copy
            outer_executors: 2,
            seed: 7,
        },
        rundir: env.workdir.join("rd").join("e2e"),
        early_stop: true,
        holdout_frac: 0.1,
        grid: Some((2, 2)),
    };
    let result = recipe.train(base, GEN_PHASES, DISC_PHASES)?;

    // 3. loss curve
    let mut csv = CsvWriter::create(&results_dir().join("e2e_loss.csv"), &["step", "train_loss"])?;
    for &(s, l) in &result.loss_curve {
        csv.rowf(&[(PRETRAIN + s) as f64, l])?;
    }
    println!("\nloss curve ({} phases):", result.loss_curve.len());
    for &(s, l) in &result.loss_curve {
        println!("  step {:>4}: loss {l:.4}", PRETRAIN + s);
    }

    // 4. routing diagnostics against ground-truth domains
    let feats = dipaco::routing::features::extract_features(
        &env.engine,
        &result.base_theta,
        &ev,
        &env.corpus,
    )?;
    let assigns: Vec<usize> = feats.iter().map(|z| result.router.assign(z)).collect();
    let alignment = domain_alignment(&env.corpus, &ev, &assigns);
    println!("\nrouter/domain alignment on eval docs: {alignment:.3}");

    // 5. evaluation: routed once + oracle frequent re-routing
    let ppl_once = result.eval_routed_once(&env.engine, &env.corpus)?;
    let mc = env.engine.model().clone();
    let scores =
        all_path_logprobs(&env.engine, &result.early_stopped, &ev, &env.corpus, mc.seq_eval)?;
    let ppl_w16 = ppl_chunked_oracle(&scores, ev.len(), mc.seq_eval, mc.prefix, 16);
    let requeues: u64 = result.phase_stats.iter().map(|s| s.requeues).sum();
    let outer_s: f64 = result.phase_stats.iter().map(|s| s.outer_update_s).sum();
    let wall_s: f64 = result.phase_stats.iter().map(|s| s.wallclock_s).sum();

    println!("\n===== end-to-end summary =====");
    println!("base ppl (fork point)          {base_ppl:.3}");
    println!("DiPaCo ppl (route once)        {ppl_once:.3}");
    println!("DiPaCo ppl (re-route W=16)     {ppl_w16:.3}");
    println!("task requeues (injected)       {requeues}");
    println!("outer-update time / total      {outer_s:.1}s / {wall_s:.1}s");
    println!("total wall clock               {:.1}s", t0.elapsed().as_secs_f64());

    write_summary(
        &results_dir().join("e2e_summary.json"),
        vec![
            ("base_ppl", Json::num(base_ppl)),
            ("dipaco_ppl_once", Json::num(ppl_once)),
            ("dipaco_ppl_w16_oracle", Json::num(ppl_w16)),
            ("router_domain_alignment", Json::num(alignment)),
            ("requeues", Json::num(requeues as f64)),
            ("outer_update_s", Json::num(outer_s)),
            ("wallclock_s", Json::num(t0.elapsed().as_secs_f64())),
        ],
    )?;
    println!("\nsummary: {}", results_dir().join("e2e_summary.json").display());
    assert!(ppl_once < base_ppl, "DiPaCo must improve on its fork point");
    Ok(())
}
