//! Table 1 — DiPaCo vs Flat MoE vs DiLoCo vs dense baselines.
//!
//! Paper rows (88k steps, path size 150M): Baseline 16.23; DiLoCo P=8
//! 15.02 / P=64 14.96; Flat MoE P=8 14.62 / P=64 12.76; DiPaCo 2x4 14.86,
//! 8x8 13.37, 8x8+PS 12.70; Baseline 8x steps 14.72. Shape to reproduce:
//! every distributed variant beats the baseline at equal wall-clock;
//! DiPaCo grids beat DiLoCo; capacity (flat MoE / path-specific) helps at
//! these shard sizes; the overtrained baseline lags the distributed runs.
//!
//! Scaled: P in {4, 8, 16}; grids 2x4 / 4x4 (+ path-specific);
//! baseline 4x steps. Output: results/table1.csv.

use anyhow::Result;

use dipaco::config::TopologySpec;
use dipaco::metrics::{print_table, results_dir, CsvWriter};
use dipaco::topology::Topology;
use dipaco::train::pipeline::{
    cached_dense, cached_dipaco, default_corpus, default_schedule, eval_docs, std_recipe, Env,
};

const DOCS: usize = 2500;
const PRETRAIN: usize = 200;

fn main() -> Result<()> {
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs"))?;
    let ev = eval_docs(&env.corpus, 64);
    let total = PRETRAIN + 100;
    let sched = default_schedule(total);
    let base = env.base_model(PRETRAIN, &sched, 7)?;

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut csv = CsvWriter::create(
        &results_dir().join("table1.csv"),
        &["model", "time", "compute", "total_params", "valid_ppl"],
    )?;
    let mut add = |csv: &mut CsvWriter,
                   rows: &mut Vec<Vec<String>>,
                   model: &str,
                   time: &str,
                   compute: &str,
                   params: usize,
                   ppl: f64|
     -> Result<()> {
        csv.row(&[
            model.into(),
            time.into(),
            compute.into(),
            params.to_string(),
            format!("{ppl:.4}"),
        ])?;
        rows.push(vec![
            model.into(),
            time.into(),
            compute.into(),
            format!("{:.2}M", params as f64 / 1e6),
            format!("{ppl:.3}"),
        ]);
        Ok(())
    };

    let n_params = env.engine.manifest.total_params;

    // Baseline: dense path-size model, same wall-clock (reuses fig8 cache).
    let (btheta, _, _) = cached_dense(&env, "dense-path-300", total, &sched, 7)?;
    let bppl = env.valid_ppl_subset(&btheta, &ev)?;
    add(&mut csv, &mut rows, "Baseline", "1x", "1x", n_params, bppl)?;

    // Baseline, 4x steps (paper's 8x row, scaled for single-core budget).
    let sched4 = default_schedule(4 * total);
    let (b4, _, _) = cached_dense(&env, "dense-path-4x", 4 * total, &sched4, 7)?;
    let b4ppl = env.valid_ppl_subset(&b4, &ev)?;
    add(&mut csv, &mut rows, "Baseline, 4x steps", "4x", "4x", n_params, b4ppl)?;

    // DiLoCo P=4 and P=8: replicas of one model on random shards.
    for p in [4usize, 8] {
        let spec = TopologySpec::diloco(p);
        let recipe = std_recipe(&env, spec, None, total, 1, false, &format!("diloco{p}"));
        let trained = cached_dipaco(&env, &format!("diloco-p{p}"), &recipe, base.clone(), 5, 0)?;
        // every replica is identical: evaluate replica 0 densely
        let ppl = env.valid_ppl_subset(&trained.thetas[&0], &ev)?;
        add(&mut csv, &mut rows, &format!("DiLoCo P={p}"), "1x", &format!("{p}x"), n_params, ppl)?;
    }

    // Flat MoE P=4 and P=8 (discriminative routing like the paper).
    for p in [4usize, 8] {
        let spec = TopologySpec::flat_moe(p);
        let topo = Topology::build(&env.engine.manifest, &spec);
        let recipe = std_recipe(&env, spec, None, total, 1, false, &format!("flat{p}"));
        let trained = cached_dipaco(&env, &format!("flatmoe-p{p}"), &recipe, base.clone(), 4, 1)?;
        let ppl = trained.ppl_once(&env, &ev, false)?;
        add(
            &mut csv,
            &mut rows,
            &format!("Flat MoE P={p}"),
            "1x",
            &format!("{p}x"),
            topo.mixture_params(),
            ppl,
        )?;
    }

    // DiPaCo 2x4, 4x4, 2x4+path-specific (cached from fig8/fig9 when run).
    let mut ps_spec = TopologySpec::grid(vec![2, 4]);
    ps_spec.path_specific_blocks = vec![0, 3];
    let dipaco_cfgs: Vec<(&str, &str, TopologySpec, Option<(usize, usize)>, usize)> = vec![
        ("DiPaCo 2x4", "dipaco-2x4", TopologySpec::grid(vec![2, 4]), Some((2, 4)), 1),
        ("DiPaCo 4x4", "dipaco-4x4", TopologySpec::grid(vec![4, 4]), Some((4, 4)), 2),
        ("DiPaCo 2x4 + PS modules", "dipaco-2x4-path-specific", ps_spec, Some((2, 4)), 1),
    ];
    for (name, tag, spec, grid, overlap) in dipaco_cfgs {
        let topo = Topology::build(&env.engine.manifest, &spec);
        let p = topo.paths;
        let recipe = std_recipe(&env, spec, grid, total, overlap, true, tag);
        let trained = cached_dipaco(&env, tag, &recipe, base.clone(), 4, 1)?;
        let ppl = trained.ppl_once(&env, &ev, true)?;
        add(
            &mut csv,
            &mut rows,
            name,
            "1x",
            &format!("{p}x"),
            topo.mixture_params(),
            ppl,
        )?;
    }

    print_table(
        "Table 1 (scaled): DiPaCo vs Flat MoE vs DiLoCo",
        &["model", "time", "compute+data", "total params", "valid ppl"],
        &rows,
    );
    println!("\nshape checks (paper orderings):");
    println!("  every distributed variant < Baseline at 1x wall-clock");
    println!("  DiPaCo grids < DiLoCo at same compute");
    println!("  extra capacity (flat MoE / path-specific) helps at these shard sizes");
    println!("csv: {}", results_dir().join("table1.csv").display());
    Ok(())
}
