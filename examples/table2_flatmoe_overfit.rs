//! Table 2 — Flat MoE (independent paths) overfits as P grows.
//!
//! Paper: P=8 14.6, P=16 13.9, P=256 14.2 (regression!), and overlapping
//! shards + early stopping recover P=256 to 13.6. Shape: PPL improves
//! then REGRESSES once shards get too small for fully-independent paths,
//! and overlap+early-stopping claws part of it back. Scaled: a smaller
//! corpus (800 docs) so P=8 shards are ~90 docs, P ∈ {2, 4, 8}.
//!
//! Output: results/table2.csv.

use anyhow::Result;

use dipaco::config::TopologySpec;
use dipaco::metrics::{print_table, results_dir, CsvWriter};
use dipaco::train::pipeline::{
    cached_dipaco, default_corpus, default_schedule, eval_docs, std_recipe, Env,
};

const DOCS: usize = 800; // deliberately small: induces overfitting
const PRETRAIN: usize = 150;

fn main() -> Result<()> {
    let env = Env::new("path", &default_corpus(DOCS), results_dir().join("runs2"))?;
    let ev = eval_docs(&env.corpus, 64);
    let total = PRETRAIN + 100;
    let sched = default_schedule(total);
    let base = env.base_model(PRETRAIN, &sched, 7)?;

    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        &results_dir().join("table2.csv"),
        &["config", "paths", "overlap", "early_stop", "valid_ppl"],
    )?;

    for p in [2usize, 4, 8] {
        let recipe = std_recipe(
            &env,
            TopologySpec::flat_moe(p),
            None,
            total,
            1,
            false,
            &format!("t2-flat{p}"),
        );
        let trained = cached_dipaco(&env, &format!("t2-flat-p{p}"), &recipe, base.clone(), 4, 1)?;
        let ppl = trained.ppl_once(&env, &ev, false)?;
        csv.row(&[format!("P={p}"), p.to_string(), "1".into(), "no".into(), format!("{ppl:.4}")])?;
        rows.push(vec![format!("P={p}"), format!("{ppl:.3}")]);
    }

    // Recovery: largest P with top-2 overlapping shards + early stopping
    // (paper §2.4.4 + §2.7).
    let p = 8;
    let recipe = std_recipe(
        &env,
        TopologySpec::flat_moe(p),
        None,
        total,
        2,
        true,
        "t2-flat8-recover",
    );
    let trained = cached_dipaco(&env, "t2-flat-p8-recover", &recipe, base, 4, 1)?;
    let ppl = trained.ppl_once(&env, &ev, true)?;
    csv.row(&["P=8+overlap+ES".into(), "8".into(), "2".into(), "yes".into(), format!("{ppl:.4}")])?;
    rows.push(vec!["P=8 + overlap + early stop".into(), format!("{ppl:.3}")]);

    print_table(
        "Table 2 (scaled): flat MoE overfits as P grows",
        &["# independent paths", "valid ppl"],
        &rows,
    );
    println!("\nshape check: ppl improves then regresses with P; overlap+ES recovers part.");
    println!("csv: {}", results_dir().join("table2.csv").display());
    Ok(())
}
