//! `dipaco` — CLI for the DiPaCo reproduction.
//!
//! Subcommands:
//!   info                         inspect artifacts / manifest
//!   corpus   [--docs N]          generate + describe the synthetic corpus
//!   pretrain [--steps N]         pretrain the base dense model
//!   train    [--grid 4x4 ...]    full DiPaCo pipeline (route + phases)
//!   eval     [--ckpt FILE]       evaluate a checkpoint
//!   serve    [--requests N ...]  serve paths behind the router (§2.6)
//!
//! The paper's tables/figures regenerate via the dedicated drivers in
//! `examples/` (see DESIGN.md's experiment index); this binary is the
//! operational entrypoint a user would script against.

use anyhow::{bail, Context, Result};
use std::sync::Arc;

use dipaco::config::{DeltaCodec, RunConfig, ServeConfig, StemPlacement, TopologySpec};
use dipaco::metrics;
use dipaco::runtime::engine::{artifact_dir, Engine};
use dipaco::train::dipaco::DipacoRecipe;
use dipaco::train::pipeline::{default_corpus, default_schedule, serve_demo_paths, Env};
use dipaco::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_grid(s: &str) -> Result<Vec<usize>> {
    s.split('x')
        .map(|p| p.parse::<usize>().context("bad grid"))
        .collect()
}

fn run() -> Result<()> {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("info") => info_cmd(&args),
        Some("corpus") => corpus_cmd(&args),
        Some("pretrain") => pretrain_cmd(&args),
        Some("train") => train_cmd(&args),
        Some("eval") => eval_cmd(&args),
        Some("serve") => serve_cmd(&args),
        Some("bench-summary") => bench_summary_cmd(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            eprintln!(
                "usage: dipaco <info|corpus|pretrain|train|eval> [options]\n\
                 \n\
                 common options:\n\
                 --preset path|large      model artifacts (default path)\n\
                 --docs N                 corpus size (default 3000)\n\
                 \n\
                 train options:\n\
                 --grid KxK               DiPaCo grid (default 2x2)\n\
                 --phases N               outer phases (default 8)\n\
                 --inner N                inner steps per phase (default 50)\n\
                 --workers N              worker pool size (default 4)\n\
                 --backup N               backup pool size (default 0)\n\
                 --preempt P              preemption probability (default 0)\n\
                 --overlap N              top-n shard overlap (default 1)\n\
                 --disc-phases N          discriminative phases (default 1)\n\
                 --early-stop             enable per-shard early stopping\n\
                 --path-specific          path-specific stem (flat-MoE style)\n\
                 --delta-codec C          delta wire codec: f32|bf16|int8 (default f32)\n\
                 --publish-groups N       staggered publication groups (default 0 = off)\n\
                 --grace-ms N             straggler grace window, ms (default 0 = off)\n\
                 --transport M            section exchange plane: local|tcp (default local)\n\
                 --net-connect-ms N       tcp connect timeout per attempt (default 1000)\n\
                 --net-read-ms N          tcp ack read timeout (default 2000)\n\
                 --net-retries N          re-sends per section after the first (default 4)\n\
                 --net-backoff-ms N       first retry backoff, doubles per attempt (default 10)\n\
                 --net-backoff-cap-ms N   retry backoff cap (default 250)\n\
                 \n\
                 serve options:\n\
                 --requests N             request stream size (default 96)\n\
                 --queue-cap N            per-path queue capacity (default 64)\n\
                 --max-batch N            micro-batch flush size (default engine batch)\n\
                 --max-wait-ms N          micro-batch flush deadline (default 15)\n\
                 --serve-workers N        concurrent client threads (default 4)\n\
                 --reject                 reject-on-full backpressure (default park)\n\
                 \n\
                 bench-summary: merge results/bench/BENCH_*.json into BENCH_summary.json"
            );
            Ok(())
        }
    }
}

fn info_cmd(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "path");
    let dir = artifact_dir(preset);
    let engine = Engine::load(&dir)?;
    let man = &engine.manifest;
    println!("preset            {}", man.preset);
    println!("artifact dir      {}", dir.display());
    println!("total params      {}", man.total_params);
    println!("leaves            {}", man.leaves.len());
    println!(
        "model             d={} layers={} heads={} ff={}",
        man.model.d_model, man.model.n_layers, man.model.n_heads, man.model.d_ff
    );
    println!(
        "sequences         train={} eval={} prefix={} batch={}",
        man.model.seq_train, man.model.seq_eval, man.model.prefix, man.model.batch
    );
    println!("entrypoints       {}", man.entrypoints.join(", "));
    Ok(())
}

fn corpus_cmd(args: &Args) -> Result<()> {
    let mut cfg = default_corpus(args.usize("docs", 3000));
    cfg.n_domains = args.usize("domains", cfg.n_domains);
    cfg.seed = args.u64("seed", cfg.seed);
    let corpus = dipaco::data::corpus::Corpus::synthetic(&cfg);
    println!(
        "docs={} train={} valid={} router={}",
        corpus.docs.len(),
        corpus.train.len(),
        corpus.valid.len(),
        corpus.router.len()
    );
    let mut counts = vec![0usize; cfg.n_domains];
    for d in &corpus.docs {
        counts[d.domain] += 1;
    }
    println!("domain histogram: {counts:?}");
    let sample = &corpus.docs[0];
    let text = dipaco::data::tokenizer::Tokenizer::decode(
        &dipaco::data::tokenizer::ByteTokenizer,
        &sample.tokens[..80.min(sample.tokens.len())],
    );
    println!("sample (domain {}): {text}...", sample.domain);
    Ok(())
}

fn pretrain_cmd(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "path");
    let steps = args.usize("steps", 300);
    let env = Env::new(
        preset,
        &default_corpus(args.usize("docs", 3000)),
        metrics::results_dir().join("runs"),
    )?;
    let schedule = default_schedule(steps.max(1));
    let theta = env.base_model(steps, &schedule, args.u64("seed", 7))?;
    let ppl = env.valid_ppl(&theta)?;
    println!("pretrained {steps} steps; validation ppl {ppl:.3}");
    Ok(())
}

fn train_cmd(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "path");
    let grid = parse_grid(args.get_or("grid", "2x2"))?;
    let phases = args.usize("phases", 8);
    let inner = args.usize("inner", 50);
    let disc_phases = args.usize("disc-phases", 1);
    let pre_steps = args.usize("pretrain", 200);
    let env = Env::new(
        preset,
        &default_corpus(args.usize("docs", 3000)),
        metrics::results_dir().join("runs"),
    )?;
    let total = pre_steps + (phases + disc_phases) * inner;
    let schedule = {
        let mut s = default_schedule(total);
        s.inner_steps = inner;
        s
    };
    let base = env.base_model(pre_steps, &schedule, 7)?;

    let mut spec = TopologySpec::grid(grid.clone());
    if args.flag("path-specific") {
        spec.stem = StemPlacement::PathSpecific;
    }
    let routing = dipaco::config::RoutingConfig {
        train_overlap: args.usize("overlap", 1),
        ..Default::default()
    };
    let recipe = DipacoRecipe {
        engine: Arc::clone(&env.engine),
        corpus: Arc::clone(&env.corpus),
        spec,
        diloco: schedule,
        routing,
        run: RunConfig {
            workers: args.usize("workers", 4),
            backup_workers: args.usize("backup", 0),
            preemption_prob: args.f64("preempt", 0.0),
            lease_ms: 60_000,
            transfer_delay_ms: args.u64("transfer-delay", 0),
            outer_executors: args.usize("executors", 2),
            assembly_threads: args.usize("assembly-threads", 4),
            delta_codec: {
                let s = args.get_or("delta-codec", "f32");
                DeltaCodec::parse(s)
                    .with_context(|| format!("bad --delta-codec {s:?} (f32|bf16|int8)"))?
            },
            publish_groups: args.usize("publish-groups", 0),
            straggler_grace_ms: args.u64("grace-ms", 0),
            transport: {
                let s = args.get_or("transport", "local");
                let mode = dipaco::config::TransportMode::parse(s)
                    .with_context(|| format!("bad --transport {s:?} (local|tcp)"))?;
                dipaco::config::TransportConfig {
                    mode,
                    connect_timeout_ms: args.u64("net-connect-ms", 1000),
                    read_timeout_ms: args.u64("net-read-ms", 2000),
                    retries: args.usize("net-retries", 4) as u32,
                    backoff_ms: args.u64("net-backoff-ms", 10),
                    backoff_cap_ms: args.u64("net-backoff-cap-ms", 250),
                }
            },
            seed: args.u64("seed", 7),
        },
        rundir: env.workdir.join(format!(
            "dipaco-{}-{}",
            grid.iter().map(|k| k.to_string()).collect::<Vec<_>>().join("x"),
            args.u64("seed", 7)
        )),
        early_stop: args.flag("early-stop"),
        holdout_frac: if args.flag("early-stop") { 0.1 } else { 0.0 },
        grid: if grid.len() == 2 { Some((grid[0], grid[1])) } else { None },
    };
    let result = recipe.train(base, phases, disc_phases)?;
    let ppl = result.eval_routed_once(&env.engine, &env.corpus)?;
    println!("\nDiPaCo {grid:?}: validation ppl (route once) = {ppl:.3}");
    for s in &result.phase_stats {
        println!(
            "  phase {:>2}: loss {:.4}  wall {:.1}s  outer {:.2}s  requeues {}",
            s.phase, s.mean_train_loss, s.wallclock_s, s.outer_update_s, s.requeues
        );
    }
    Ok(())
}

/// Serve a stream of validation documents through the §2.6 subsystem:
/// per-document router admission, bounded per-path queues, one path
/// server per path, deadline micro-batching. Reports latency percentiles
/// and throughput from the shared `ServeStats`.
fn serve_cmd(args: &Args) -> Result<()> {
    use dipaco::serve::server::{engine_executors, Server};

    let preset = args.get_or("preset", "path");
    let n_requests = args.usize("requests", 96);
    let env = Env::new(
        preset,
        &default_corpus(args.usize("docs", 2500)),
        metrics::results_dir().join("runs"),
    )?;
    let trained = serve_demo_paths(&env, "serve-2x2")?;
    let cfg = ServeConfig {
        queue_cap: args.usize("queue-cap", 64),
        max_batch: args.usize("max-batch", 0),
        max_wait_ms: args.u64("max-wait-ms", 15),
        reject_on_full: args.flag("reject"),
        workers: args.usize("serve-workers", 4).max(1),
        ..Default::default()
    };
    let seq = env.engine.model().seq_eval;

    // Request stream: validation docs, cycled up to --requests.
    let docs: Vec<usize> = env
        .corpus
        .valid
        .iter()
        .copied()
        .cycle()
        .take(n_requests)
        .collect();
    let t0 = std::time::Instant::now();
    let feats = dipaco::routing::features::extract_features(
        &env.engine,
        &trained.base,
        &docs,
        &env.corpus,
    )?;
    let route_ms = t0.elapsed().as_secs_f64() * 1e3;

    let server = Server::start(
        &cfg,
        trained.router.clone(),
        engine_executors(&env.engine, trained.thetas)?,
    );

    // cfg.workers concurrent clients: each submits its slice, then waits.
    let clients = cfg.workers;
    let (total_nll, total_tok, rejects) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|w| {
                let server = &server;
                let docs = &docs;
                let feats = &feats;
                let corpus = &env.corpus;
                s.spawn(move || {
                    let mut tickets = Vec::new();
                    let mut rejects = 0usize;
                    for i in (w..docs.len()).step_by(clients) {
                        let toks = corpus.sequence(docs[i], seq);
                        match server.submit(&feats[i], toks) {
                            Ok(t) => tickets.push(t),
                            Err(_) => rejects += 1,
                        }
                    }
                    let mut nll = 0.0f64;
                    let mut tok = 0usize;
                    for t in tickets {
                        if let Ok(r) = t.wait() {
                            nll += r.nll;
                            tok += r.tokens_scored;
                        }
                    }
                    (nll, tok, rejects)
                })
            })
            .collect();
        let mut acc = (0.0f64, 0usize, 0usize);
        for h in handles {
            let (n, t, r) = h.join().expect("client thread panicked");
            acc = (acc.0 + n, acc.1 + t, acc.2 + r);
        }
        acc
    });
    let report = server.shutdown();

    let mut rows = vec![
        vec!["requests".into(), n_requests.to_string()],
        vec!["routing time (all)".into(), format!("{route_ms:.1} ms")],
    ];
    rows.extend(report.rows());
    rows.push(vec![
        "served ppl".into(),
        format!("{:.3}", (total_nll / (total_tok.max(1)) as f64).exp()),
    ]);
    metrics::print_table("serving stats", &["metric", "value"], &rows);
    if rejects > 0 {
        println!("({rejects} requests rejected by backpressure)");
    }
    Ok(())
}

/// Merge every `results/bench/BENCH_*.json` the bench binaries emitted
/// into one `BENCH_summary.json`, keyed by bench name (the file stem
/// minus the `BENCH_` prefix). The perf trajectory PR over PR is judged
/// from this file; `make bench-all` ends by calling it.
fn bench_summary_cmd() -> Result<()> {
    use dipaco::util::json::Json;

    let dir = metrics::results_dir().join("bench");
    let mut parts: Vec<(String, Json)> = Vec::new();
    if dir.is_dir() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else {
                continue;
            };
            let Some(name) = stem.strip_prefix("BENCH_") else {
                continue;
            };
            if name == "summary" || path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let json = Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("{}: {e:?}", path.display()))?;
            parts.push((name.to_string(), json));
        }
    }
    parts.sort_by(|a, b| a.0.cmp(&b.0));
    if parts.is_empty() {
        println!(
            "no BENCH_*.json under {} — run `make bench-all` first",
            dir.display()
        );
        return Ok(());
    }
    let names: Vec<String> = parts.iter().map(|(n, _)| n.clone()).collect();
    let entries: Vec<(&str, Json)> = parts.iter().map(|(n, j)| (n.as_str(), j.clone())).collect();
    let out = dir.join("BENCH_summary.json");
    metrics::write_summary(&out, entries)?;
    println!("merged {} benches ({}) into {}", names.len(), names.join(", "), out.display());
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "path");
    let Some(ckpt) = args.get("ckpt") else {
        bail!("--ckpt <file.dpc> required");
    };
    let env = Env::new(
        preset,
        &default_corpus(args.usize("docs", 3000)),
        metrics::results_dir().join("runs"),
    )?;
    let ck = dipaco::params::checkpoint::Checkpoint::load(std::path::Path::new(ckpt))?;
    let theta = ck.get("theta").context("checkpoint missing theta")?;
    let ppl = env.valid_ppl(theta)?;
    println!("validation ppl {ppl:.3}");
    Ok(())
}
