//! `dipaco` — CLI for the DiPaCo reproduction.
//!
//! Subcommands:
//!   info                         inspect artifacts / manifest
//!   corpus   [--docs N]          generate + describe the synthetic corpus
//!   pretrain [--steps N]         pretrain the base dense model
//!   train    [--grid 4x4 ...]    full DiPaCo pipeline (route + phases)
//!   eval     [--ckpt FILE]       evaluate a checkpoint
//!
//! The paper's tables/figures regenerate via the dedicated drivers in
//! `examples/` (see DESIGN.md's experiment index); this binary is the
//! operational entrypoint a user would script against.

use anyhow::{bail, Context, Result};
use std::sync::Arc;

use dipaco::config::{RunConfig, StemPlacement, TopologySpec};
use dipaco::metrics;
use dipaco::runtime::engine::{artifact_dir, Engine};
use dipaco::train::dipaco::DipacoRecipe;
use dipaco::train::pipeline::{default_corpus, default_schedule, Env};
use dipaco::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_grid(s: &str) -> Result<Vec<usize>> {
    s.split('x')
        .map(|p| p.parse::<usize>().context("bad grid"))
        .collect()
}

fn run() -> Result<()> {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("info") => info_cmd(&args),
        Some("corpus") => corpus_cmd(&args),
        Some("pretrain") => pretrain_cmd(&args),
        Some("train") => train_cmd(&args),
        Some("eval") => eval_cmd(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            eprintln!(
                "usage: dipaco <info|corpus|pretrain|train|eval> [options]\n\
                 \n\
                 common options:\n\
                 --preset path|large      model artifacts (default path)\n\
                 --docs N                 corpus size (default 3000)\n\
                 \n\
                 train options:\n\
                 --grid KxK               DiPaCo grid (default 2x2)\n\
                 --phases N               outer phases (default 8)\n\
                 --inner N                inner steps per phase (default 50)\n\
                 --workers N              worker pool size (default 4)\n\
                 --backup N               backup pool size (default 0)\n\
                 --preempt P              preemption probability (default 0)\n\
                 --overlap N              top-n shard overlap (default 1)\n\
                 --disc-phases N          discriminative phases (default 1)\n\
                 --early-stop             enable per-shard early stopping\n\
                 --path-specific          path-specific stem (flat-MoE style)"
            );
            Ok(())
        }
    }
}

fn info_cmd(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "path");
    let dir = artifact_dir(preset);
    let engine = Engine::load(&dir)?;
    let man = &engine.manifest;
    println!("preset            {}", man.preset);
    println!("artifact dir      {}", dir.display());
    println!("total params      {}", man.total_params);
    println!("leaves            {}", man.leaves.len());
    println!(
        "model             d={} layers={} heads={} ff={}",
        man.model.d_model, man.model.n_layers, man.model.n_heads, man.model.d_ff
    );
    println!(
        "sequences         train={} eval={} prefix={} batch={}",
        man.model.seq_train, man.model.seq_eval, man.model.prefix, man.model.batch
    );
    println!("entrypoints       {}", man.entrypoints.join(", "));
    Ok(())
}

fn corpus_cmd(args: &Args) -> Result<()> {
    let mut cfg = default_corpus(args.usize("docs", 3000));
    cfg.n_domains = args.usize("domains", cfg.n_domains);
    cfg.seed = args.u64("seed", cfg.seed);
    let corpus = dipaco::data::corpus::Corpus::synthetic(&cfg);
    println!(
        "docs={} train={} valid={} router={}",
        corpus.docs.len(),
        corpus.train.len(),
        corpus.valid.len(),
        corpus.router.len()
    );
    let mut counts = vec![0usize; cfg.n_domains];
    for d in &corpus.docs {
        counts[d.domain] += 1;
    }
    println!("domain histogram: {counts:?}");
    let sample = &corpus.docs[0];
    let text = dipaco::data::tokenizer::Tokenizer::decode(
        &dipaco::data::tokenizer::ByteTokenizer,
        &sample.tokens[..80.min(sample.tokens.len())],
    );
    println!("sample (domain {}): {text}...", sample.domain);
    Ok(())
}

fn pretrain_cmd(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "path");
    let steps = args.usize("steps", 300);
    let env = Env::new(
        preset,
        &default_corpus(args.usize("docs", 3000)),
        metrics::results_dir().join("runs"),
    )?;
    let schedule = default_schedule(steps.max(1));
    let theta = env.base_model(steps, &schedule, args.u64("seed", 7))?;
    let ppl = env.valid_ppl(&theta)?;
    println!("pretrained {steps} steps; validation ppl {ppl:.3}");
    Ok(())
}

fn train_cmd(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "path");
    let grid = parse_grid(args.get_or("grid", "2x2"))?;
    let phases = args.usize("phases", 8);
    let inner = args.usize("inner", 50);
    let disc_phases = args.usize("disc-phases", 1);
    let pre_steps = args.usize("pretrain", 200);
    let env = Env::new(
        preset,
        &default_corpus(args.usize("docs", 3000)),
        metrics::results_dir().join("runs"),
    )?;
    let total = pre_steps + (phases + disc_phases) * inner;
    let schedule = {
        let mut s = default_schedule(total);
        s.inner_steps = inner;
        s
    };
    let base = env.base_model(pre_steps, &schedule, 7)?;

    let mut spec = TopologySpec::grid(grid.clone());
    if args.flag("path-specific") {
        spec.stem = StemPlacement::PathSpecific;
    }
    let routing = dipaco::config::RoutingConfig {
        train_overlap: args.usize("overlap", 1),
        ..Default::default()
    };
    let recipe = DipacoRecipe {
        engine: Arc::clone(&env.engine),
        corpus: Arc::clone(&env.corpus),
        spec,
        diloco: schedule,
        routing,
        run: RunConfig {
            workers: args.usize("workers", 4),
            backup_workers: args.usize("backup", 0),
            preemption_prob: args.f64("preempt", 0.0),
            lease_ms: 60_000,
            transfer_delay_ms: args.u64("transfer-delay", 0),
            outer_executors: args.usize("executors", 2),
            seed: args.u64("seed", 7),
        },
        rundir: env.workdir.join(format!(
            "dipaco-{}-{}",
            grid.iter().map(|k| k.to_string()).collect::<Vec<_>>().join("x"),
            args.u64("seed", 7)
        )),
        early_stop: args.flag("early-stop"),
        holdout_frac: if args.flag("early-stop") { 0.1 } else { 0.0 },
        grid: if grid.len() == 2 { Some((grid[0], grid[1])) } else { None },
    };
    let result = recipe.train(base, phases, disc_phases)?;
    let ppl = result.eval_routed_once(&env.engine, &env.corpus)?;
    println!("\nDiPaCo {grid:?}: validation ppl (route once) = {ppl:.3}");
    for s in &result.phase_stats {
        println!(
            "  phase {:>2}: loss {:.4}  wall {:.1}s  outer {:.2}s  requeues {}",
            s.phase, s.mean_train_loss, s.wallclock_s, s.outer_update_s, s.requeues
        );
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "path");
    let Some(ckpt) = args.get("ckpt") else {
        bail!("--ckpt <file.dpc> required");
    };
    let env = Env::new(
        preset,
        &default_corpus(args.usize("docs", 3000)),
        metrics::results_dir().join("runs"),
    )?;
    let ck = dipaco::params::checkpoint::Checkpoint::load(std::path::Path::new(ckpt))?;
    let theta = ck.get("theta").context("checkpoint missing theta")?;
    let ppl = env.valid_ppl(theta)?;
    println!("validation ppl {ppl:.3}");
    Ok(())
}
