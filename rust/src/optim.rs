//! Per-module outer optimization (paper §2.5–§2.7, Algorithm 1 lines 11-16).
//!
//! Each module `(l, e)` receives outer gradients `theta(l,e)^{t-1} -
//! theta(l,e)^t_i` from the paths `i` that traverse it. [`OuterAccumulator`]
//! averages them **online** (paper §3.3: accumulate each checkpoint as it
//! arrives instead of gathering all first), with optional shard-size
//! weighting (Eq. 2-3). [`Nesterov`] then applies the outer update with
//! optional norm rescaling by `sqrt(P_le / P_max)` (§2.7: "we have rescaled
//! the outer gradient norm by the square root of the number of paths going
//! through a module" — implemented relative to the most-shared module so
//! the DiLoCo-calibrated outer LR of 0.7/0.9 stays valid for it).

use std::collections::HashMap;

use crate::topology::{ModuleId, Topology};
use crate::util::kernels;

/// Online weighted average of outer gradients for one module.
#[derive(Debug, Clone)]
pub struct OuterAccumulator {
    sum: Vec<f32>,
    weight: f64,
    contributions: usize,
}

impl OuterAccumulator {
    pub fn new(size: usize) -> Self {
        OuterAccumulator {
            sum: vec![0.0; size],
            weight: 0.0,
            contributions: 0,
        }
    }

    /// Reset to a pristine accumulator of `size` elements, keeping the
    /// sum buffer's allocation — executors reduce many modules per phase
    /// and reuse one accumulator across them.
    pub fn reset(&mut self, size: usize) {
        self.sum.clear();
        self.sum.resize(size, 0.0);
        self.weight = 0.0;
        self.contributions = 0;
    }

    /// Add one path's outer gradient with weight `w` (shard size under
    /// loss reweighing, 1.0 otherwise). O(size); no buffering of deltas.
    pub fn add(&mut self, delta: &[f32], w: f64) {
        assert_eq!(delta.len(), self.sum.len());
        assert!(w > 0.0);
        kernels::accumulate(&mut self.sum, delta, w);
        self.weight += w;
        self.contributions += 1;
    }

    pub fn contributions(&self) -> usize {
        self.contributions
    }

    /// Weighted mean (Eq. 2-3 with alpha normalized by total weight).
    pub fn average(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.average_into(&mut out);
        out
    }

    /// Weighted mean into a caller-owned (typically pooled) buffer —
    /// bit-identical to [`OuterAccumulator::average`], no allocation in
    /// steady state.
    pub fn average_into(&self, out: &mut Vec<f32>) {
        assert!(self.weight > 0.0, "no contributions");
        let inv = (1.0 / self.weight) as f32;
        kernels::scale_into(&self.sum, inv, out);
    }
}

/// Per-module Nesterov momentum, the outer optimizer DiLoCo/DiPaCo found
/// most effective (paper §2.5; lr 0.7, momentum 0.9 in §7.1).
#[derive(Debug)]
pub struct Nesterov {
    pub lr: f32,
    pub momentum: f32,
    velocity: HashMap<ModuleId, Vec<f32>>,
}

impl Nesterov {
    pub fn new(lr: f32, momentum: f32) -> Self {
        Nesterov {
            lr,
            momentum,
            velocity: HashMap::new(),
        }
    }

    /// Nesterov step: v <- mu v + g;  theta <- theta - lr (g + mu v).
    /// `g` is the (already averaged / rescaled) outer gradient.
    pub fn step(&mut self, m: ModuleId, params: &mut [f32], g: &[f32]) {
        assert_eq!(params.len(), g.len());
        let v = self
            .velocity
            .entry(m)
            .or_insert_with(|| vec![0.0; g.len()]);
        kernels::nesterov_step(params, v, g, self.lr, self.momentum);
    }

    pub fn velocity_of(&self, m: ModuleId) -> Option<&[f32]> {
        self.velocity.get(&m).map(|v| v.as_slice())
    }

    /// Rebuild an optimizer around externally-held velocity state. Outer
    /// momentum belongs to the *module*, not to any particular executor:
    /// when executors drop or re-join between phases and modules are
    /// re-sharded, each module's velocity must follow it to whichever
    /// executor now owns it.
    pub fn from_velocity(lr: f32, momentum: f32, velocity: HashMap<ModuleId, Vec<f32>>) -> Self {
        Nesterov {
            lr,
            momentum,
            velocity,
        }
    }

    /// Surrender the velocity map (inverse of [`Nesterov::from_velocity`]).
    pub fn into_velocity(self) -> HashMap<ModuleId, Vec<f32>> {
        self.velocity
    }
}

/// Norm-rescale factor for a module (paper §2.7), relative to the
/// most-shared level so the most-averaged module keeps factor 1.0.
pub fn rescale_factor(topo: &Topology, m: ModuleId, enabled: bool) -> f32 {
    if !enabled {
        return 1.0;
    }
    let p_le = topo.paths_through(m) as f32;
    let p_max = topo
        .levels
        .iter()
        .enumerate()
        .map(|(l, _)| topo.paths_through(ModuleId { level: l, expert: 0 }))
        .max()
        .unwrap_or(1) as f32;
    (p_le / p_max).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologySpec;
    use crate::params::manifest::Manifest;
    use crate::util::json::Json;

    fn mid(l: usize, e: usize) -> ModuleId {
        ModuleId { level: l, expert: e }
    }

    #[test]
    fn accumulator_weighted_average() {
        let mut acc = OuterAccumulator::new(3);
        acc.add(&[1.0, 2.0, 3.0], 1.0);
        acc.add(&[3.0, 2.0, 1.0], 3.0);
        let avg = acc.average();
        // (1*1+3*3)/4, (2*1+2*3)/4, (3*1+1*3)/4
        assert!((avg[0] - 2.5).abs() < 1e-6);
        assert!((avg[1] - 2.0).abs() < 1e-6);
        assert!((avg[2] - 1.5).abs() < 1e-6);
        assert_eq!(acc.contributions(), 2);
    }

    #[test]
    fn average_into_matches_average_and_reset_reuses() {
        let mut acc = OuterAccumulator::new(3);
        acc.add(&[1.0, 2.0, 3.0], 1.0);
        acc.add(&[3.0, 2.0, 1.0], 3.0);
        let a = acc.average();
        let mut b = vec![9.0f32; 7]; // dirty, wrong-sized buffer
        acc.average_into(&mut b);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "average_into must be bit-identical to average"
        );
        // reset: pristine state, same buffer
        acc.reset(2);
        assert_eq!(acc.contributions(), 0);
        acc.add(&[4.0, 6.0], 2.0);
        assert_eq!(acc.contributions(), 1);
        assert_eq!(acc.average(), vec![4.0, 6.0]);
    }

    #[test]
    fn online_equals_batch_average() {
        let deltas: Vec<Vec<f32>> = (0..7)
            .map(|i| (0..5).map(|j| (i * 5 + j) as f32 * 0.1).collect())
            .collect();
        let mut acc = OuterAccumulator::new(5);
        for d in &deltas {
            acc.add(d, 1.0);
        }
        let avg = acc.average();
        for j in 0..5 {
            let batch: f32 = deltas.iter().map(|d| d[j]).sum::<f32>() / 7.0;
            assert!((avg[j] - batch).abs() < 1e-5);
        }
    }

    #[test]
    fn nesterov_first_step() {
        let mut opt = Nesterov::new(0.5, 0.9);
        let mut p = vec![1.0f32, 1.0];
        opt.step(mid(0, 0), &mut p, &[0.2, -0.2]);
        // v = g; update = g + mu*v = 1.9*g; p -= lr*1.9*g
        assert!((p[0] - (1.0 - 0.5 * 1.9 * 0.2)).abs() < 1e-6);
        assert!((p[1] - (1.0 + 0.5 * 1.9 * 0.2)).abs() < 1e-6);
    }

    #[test]
    fn nesterov_momentum_accumulates() {
        let mut opt = Nesterov::new(0.1, 0.9);
        let mut p = vec![0.0f32];
        opt.step(mid(0, 0), &mut p, &[1.0]);
        let after1 = p[0];
        opt.step(mid(0, 0), &mut p, &[1.0]);
        let delta2 = after1 - p[0];
        let delta1 = -after1;
        // second step moves farther than first (momentum)
        assert!(delta2 > -delta1 * 0.99 && delta2 > 0.0);
        assert!(p[0] < after1);
    }

    #[test]
    fn velocity_is_per_module() {
        let mut opt = Nesterov::new(0.1, 0.9);
        let mut a = vec![0.0f32];
        let mut b = vec![0.0f32];
        opt.step(mid(0, 0), &mut a, &[1.0]);
        opt.step(mid(0, 0), &mut a, &[1.0]);
        opt.step(mid(1, 0), &mut b, &[1.0]);
        // b only saw one step: shallower update
        assert!(b[0] > a[0] / 2.0);
        assert!(opt.velocity_of(mid(1, 0)).is_some());
        assert!(opt.velocity_of(mid(2, 2)).is_none());
    }

    #[test]
    fn velocity_transplant_is_bitwise_equivalent() {
        // Moving velocity between optimizer instances mid-stream (executor
        // drop/re-join re-sharding) must not perturb the trajectory.
        let g: Vec<f32> = (0..4).map(|i| 0.1 * (i as f32 + 1.0)).collect();
        let mut cont = Nesterov::new(0.7, 0.9);
        let mut p1 = vec![0.5f32; 4];
        cont.step(mid(0, 0), &mut p1, &g);
        cont.step(mid(0, 0), &mut p1, &g);

        let mut a = Nesterov::new(0.7, 0.9);
        let mut p2 = vec![0.5f32; 4];
        a.step(mid(0, 0), &mut p2, &g);
        let mut b = Nesterov::from_velocity(0.7, 0.9, a.into_velocity());
        b.step(mid(0, 0), &mut p2, &g);
        for (x, y) in p1.iter().zip(&p2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(
            cont.velocity_of(mid(0, 0)),
            b.velocity_of(mid(0, 0)),
            "velocity state diverged across the transplant"
        );
    }

    #[test]
    fn rescale_relative_to_most_shared() {
        let j = crate::params::manifest::tests::fake_manifest_json(4, 8);
        let man = Manifest::from_json(&Json::parse(&j).unwrap()).unwrap();
        let topo = Topology::build(&man, &TopologySpec::grid(vec![4]));
        // stem shared by 4 paths -> factor 1; grid level expert by 1 path -> 0.5
        assert!((rescale_factor(&topo, mid(0, 0), true) - 1.0).abs() < 1e-6);
        assert!((rescale_factor(&topo, mid(1, 0), true) - 0.5).abs() < 1e-6);
        assert_eq!(rescale_factor(&topo, mid(1, 0), false), 1.0);
    }
}
