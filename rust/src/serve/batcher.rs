//! Bounded per-path queues with deadline-based micro-batching.
//!
//! Each path server owns one [`BoundedQueue`]: admission pushes documents
//! (non-blocking reject or parked push, the backpressure knob), the
//! worker drains micro-batches with [`BoundedQueue::pop_batch`] — flush
//! when `max_batch` documents are waiting OR `max_wait` has elapsed since
//! the first document of the batch was taken, whichever comes first. The
//! compiled HLO batch shape is fixed, so partial batches are padded to
//! full rows with [`pad_batch`] (pad rows are excluded from scoring by
//! the caller, same convention as `eval::eval_docs`).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Push failure, returning the rejected item to the caller.
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue at capacity (after any park timeout elapsed).
    Full(T),
    /// Queue closed for shutdown.
    Closed(T),
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// MPSC bounded queue: many admission threads push, one path worker pops.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "queue capacity must be >= 1");
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(cap),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking push (reject-on-full backpressure). On success returns
    /// the queue depth INCLUDING the new item.
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        let depth = g.items.len();
        drop(g);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Parked push (block-on-full backpressure): waits up to `timeout` for
    /// space, then gives up with `Full`.
    pub fn push(&self, item: T, timeout: Duration) -> Result<usize, PushError<T>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(PushError::Closed(item));
            }
            if g.items.len() < self.cap {
                g.items.push_back(item);
                let depth = g.items.len();
                drop(g);
                self.not_empty.notify_one();
                return Ok(depth);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(PushError::Full(item));
            }
            let (g2, _) = self.not_full.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
    }

    /// Close for shutdown: pushes fail from now on; the worker drains what
    /// is left and then gets `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Drain one micro-batch.
    ///
    /// Blocks up to `idle_timeout` for the first item; an idle tick
    /// returns `Some(vec![])` so the worker can do housekeeping and call
    /// again. Once the first item is taken, keeps collecting until
    /// `max_batch` items are in hand or `max_wait` has elapsed since the
    /// first item was taken (the flush deadline). Returns `None` only when
    /// the queue is closed AND drained.
    pub fn pop_batch(
        &self,
        max_batch: usize,
        max_wait: Duration,
        idle_timeout: Duration,
    ) -> Option<Vec<T>> {
        assert!(max_batch >= 1);
        let idle_deadline = Instant::now() + idle_timeout;
        let mut g = self.inner.lock().unwrap();
        // Phase 1: wait for the first item.
        loop {
            if !g.items.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= idle_deadline {
                return Some(Vec::new()); // idle tick
            }
            let (g2, _) = self.not_empty.wait_timeout(g, idle_deadline - now).unwrap();
            g = g2;
        }
        // Phase 2: collect until size or deadline.
        let flush_deadline = Instant::now() + max_wait;
        let mut out = Vec::with_capacity(max_batch);
        loop {
            while out.len() < max_batch {
                match g.items.pop_front() {
                    Some(item) => out.push(item),
                    None => break,
                }
            }
            if !out.is_empty() {
                self.not_full.notify_all();
            }
            if out.len() >= max_batch || g.closed {
                return Some(out);
            }
            let now = Instant::now();
            if now >= flush_deadline {
                return Some(out);
            }
            let (g2, _) = self
                .not_empty
                .wait_timeout(g, flush_deadline - now)
                .unwrap();
            g = g2;
        }
    }
}

/// Pad a partial micro-batch of equal-length token rows to the compiled
/// `batch` row count by repeating the first row (same convention as
/// `eval::eval_docs`, which pads with doc 0). Returns the flattened
/// `[batch, seq]` buffer; the caller scores only the first `rows.len()`
/// rows.
pub fn pad_batch(rows: &[&[i32]], batch: usize) -> Vec<i32> {
    let mut out = Vec::new();
    pad_batch_into(rows, batch, &mut out);
    out
}

/// Allocation-free variant of [`pad_batch`]: pads into a caller-owned
/// buffer (cleared first), so the serve worker's steady state reuses one
/// flattened token buffer per batch instead of allocating `batch * seq`
/// ints per flush.
pub fn pad_batch_into(rows: &[&[i32]], batch: usize, out: &mut Vec<i32>) {
    assert!(!rows.is_empty(), "cannot pad an empty batch");
    assert!(rows.len() <= batch, "{} rows > batch {batch}", rows.len());
    let seq = rows[0].len();
    out.clear();
    out.reserve(batch * seq);
    for r in rows {
        assert_eq!(r.len(), seq, "ragged token rows in one batch");
        out.extend_from_slice(r);
    }
    for _ in rows.len()..batch {
        out.extend_from_slice(rows[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn flush_on_size_does_not_wait_for_deadline() {
        let q = BoundedQueue::new(16);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        let t0 = Instant::now();
        let b = q
            .pop_batch(4, Duration::from_secs(5), Duration::from_secs(5))
            .unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "full batch must flush immediately"
        );
    }

    #[test]
    fn flush_on_deadline_returns_partial_batch() {
        let q = BoundedQueue::new(16);
        q.try_push(42).unwrap();
        let t0 = Instant::now();
        let b = q
            .pop_batch(8, Duration::from_millis(40), Duration::from_secs(5))
            .unwrap();
        assert_eq!(b, vec![42]);
        let waited = t0.elapsed();
        assert!(
            waited >= Duration::from_millis(30),
            "partial batch flushed before the deadline ({waited:?})"
        );
    }

    #[test]
    fn item_arriving_mid_wait_joins_the_open_batch() {
        // Deadline edge: the batch is already open (first item taken) when
        // the second item lands — it must join THIS batch and flush on
        // size, not wait out the deadline or start a new batch.
        let q = Arc::new(BoundedQueue::new(8));
        q.try_push(1).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.try_push(2).unwrap();
        });
        let t0 = Instant::now();
        let b = q
            .pop_batch(2, Duration::from_secs(5), Duration::from_millis(100))
            .unwrap();
        assert_eq!(b, vec![1, 2], "late arrival joins the open batch");
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "size flush, not deadline flush"
        );
        h.join().unwrap();
    }

    #[test]
    fn zero_wait_deadline_flushes_whatever_is_in_hand() {
        // max_wait == 0 (the chaos/latency-sensitive configuration): the
        // flush deadline is already past when the batch opens, so the pop
        // returns what is queued right now and never parks.
        let q = BoundedQueue::new(8);
        q.try_push(7).unwrap();
        q.try_push(8).unwrap();
        let t0 = Instant::now();
        let b = q.pop_batch(4, Duration::ZERO, Duration::from_millis(50)).unwrap();
        assert_eq!(b, vec![7, 8]);
        assert!(t0.elapsed() < Duration::from_millis(40), "zero wait never parks");
    }

    #[test]
    fn idle_wakeup_fires_at_deadline_and_queue_stays_usable() {
        // Zero-item deadline wakeup: an empty queue returns Some(vec![])
        // at the idle deadline (the worker's housekeeping tick), and the
        // queue keeps serving normally afterwards.
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let t0 = Instant::now();
        let b = q
            .pop_batch(4, Duration::from_secs(5), Duration::from_millis(30))
            .unwrap();
        assert!(b.is_empty(), "idle tick is an empty batch");
        assert!(
            t0.elapsed() >= Duration::from_millis(25),
            "idle tick honors the idle deadline"
        );
        q.try_push(9).unwrap();
        let b2 = q
            .pop_batch(4, Duration::ZERO, Duration::from_millis(50))
            .unwrap();
        assert_eq!(b2, vec![9], "queue still drains after an idle tick");
    }

    #[test]
    fn idle_tick_then_closed() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let b = q
            .pop_batch(4, Duration::from_millis(1), Duration::from_millis(5))
            .unwrap();
        assert!(b.is_empty(), "idle tick is an empty batch, not None");
        q.close();
        assert!(q
            .pop_batch(4, Duration::from_millis(1), Duration::from_millis(5))
            .is_none());
    }

    #[test]
    fn close_drains_remaining_items_first() {
        let q = BoundedQueue::new(8);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        let b = q
            .pop_batch(8, Duration::from_millis(1), Duration::from_millis(5))
            .unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(q
            .pop_batch(8, Duration::from_millis(1), Duration::from_millis(5))
            .is_none());
    }

    #[test]
    fn bounded_reject_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        assert_eq!(q.try_push(2).unwrap(), 2);
        match q.try_push(3) {
            Err(PushError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        // parked push with a short timeout also gives up
        match q.push(3, Duration::from_millis(20)) {
            Err(PushError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
    }

    #[test]
    fn parked_push_unblocks_when_worker_drains() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(1).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push(2, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        let b = q
            .pop_batch(1, Duration::from_millis(1), Duration::from_millis(100))
            .unwrap();
        assert_eq!(b, vec![1]);
        assert!(h.join().unwrap().is_ok(), "parked push must succeed after drain");
    }

    #[test]
    fn push_after_close_is_rejected() {
        let q = BoundedQueue::new(2);
        q.close();
        assert!(matches!(q.try_push(1), Err(PushError::Closed(1))));
        assert!(matches!(
            q.push(1, Duration::from_millis(1)),
            Err(PushError::Closed(1))
        ));
    }

    #[test]
    fn pad_batch_repeats_first_row() {
        let r0: &[i32] = &[1, 2, 3];
        let r1: &[i32] = &[4, 5, 6];
        let out = pad_batch(&[r0, r1], 4);
        assert_eq!(out, vec![1, 2, 3, 4, 5, 6, 1, 2, 3, 1, 2, 3]);
        // already full: no padding
        assert_eq!(pad_batch(&[r0], 1), vec![1, 2, 3]);
    }

    #[test]
    fn pad_batch_into_matches_and_reuses_buffer() {
        let r0: &[i32] = &[1, 2, 3];
        let r1: &[i32] = &[4, 5, 6];
        let mut buf = vec![99; 100]; // dirty, oversized — must be cleared
        pad_batch_into(&[r0, r1], 4, &mut buf);
        assert_eq!(buf, pad_batch(&[r0, r1], 4));
        let cap = buf.capacity();
        pad_batch_into(&[r1], 2, &mut buf);
        assert_eq!(buf, vec![4, 5, 6, 4, 5, 6]);
        assert_eq!(buf.capacity(), cap, "steady state must not reallocate");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn pad_batch_rejects_ragged_rows() {
        let r0: &[i32] = &[1, 2, 3];
        let r1: &[i32] = &[4, 5];
        let _ = pad_batch(&[r0, r1], 4);
    }

    // Property (testkit): any interleaving of pushes and batch pops
    // preserves FIFO order, never exceeds capacity, and loses nothing.
    #[test]
    fn prop_fifo_bounded_lossless() {
        crate::testkit::forall(
            "bounded queue is FIFO, bounded, lossless",
            11,
            40,
            |rng| {
                let cap = 1 + rng.gen_range(6);
                let max_batch = 1 + rng.gen_range(5);
                let ops: Vec<bool> = (0..30).map(|_| rng.f64() < 0.6).collect(); // true = push
                (cap, max_batch, ops)
            },
            |&(cap, max_batch, ref ops)| {
                let q = BoundedQueue::new(cap);
                let mut next = 0u32;
                let mut accepted = 0usize;
                let mut popped: Vec<u32> = Vec::new();
                for &is_push in ops {
                    if is_push {
                        if q.try_push(next).is_ok() {
                            accepted += 1;
                        }
                        if q.len() > cap {
                            return Err(format!("depth {} > cap {cap}", q.len()));
                        }
                        next += 1;
                    } else {
                        let b = q
                            .pop_batch(max_batch, Duration::ZERO, Duration::ZERO)
                            .unwrap_or_default();
                        if b.len() > max_batch {
                            return Err(format!("batch {} > max {max_batch}", b.len()));
                        }
                        popped.extend(b);
                    }
                }
                q.close();
                while let Some(b) = q.pop_batch(max_batch, Duration::ZERO, Duration::ZERO) {
                    popped.extend(b);
                }
                if popped.len() != accepted {
                    return Err(format!("popped {} != accepted {accepted}", popped.len()));
                }
                if popped.windows(2).any(|w| w[0] >= w[1]) {
                    return Err(format!("FIFO order violated: {popped:?}"));
                }
                Ok(())
            },
        );
    }
}
