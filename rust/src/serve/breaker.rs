//! Per-path circuit breaker, consulted by the admission front-end.
//!
//! Classic three-state machine over a sliding window of recent batch
//! outcomes:
//!
//! * **Closed** — traffic flows; every batch outcome (ok/failed, execution
//!   time) lands in the window. When the window holds at least
//!   `min_samples` outcomes and either the failure fraction reaches
//!   `error_rate` or the mean batch execution time reaches `latency_ms`
//!   (if enabled), the breaker trips to Open.
//! * **Open** — admission refuses the path outright (degraded-mode routing
//!   in [`super::server`] then redirects to the router's runner-up). After
//!   `cooldown_ms` the next admission attempt transitions to HalfOpen.
//! * **HalfOpen** — up to `probes` requests are admitted as probe batches.
//!   Any probe failure re-opens immediately (fresh cooldown); `probes`
//!   successes close the breaker and clear the window.
//!
//! The breaker records *batch* outcomes, not per-request outcomes: one
//! wedged or panicking micro-batch is one failure sample regardless of
//! fill, which keeps trip behaviour independent of batching luck.
//!
//! Everything lives behind one short Mutex; admission does a single lock
//! per submit on the healthy path.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::BreakerConfig;

/// Breaker position, exposed per path in `ServeReport`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn as_str(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

#[derive(Debug)]
struct Inner {
    state: BreakerState,
    /// When the breaker last tripped (meaningful while Open).
    opened_at: Instant,
    /// Sliding window of (ok, batch execution ms), newest last.
    outcomes: VecDeque<(bool, f64)>,
    /// Probe admissions handed out since entering HalfOpen.
    probes_sent: usize,
    /// Successful probe outcomes since entering HalfOpen.
    probe_successes: usize,
    /// Closed→Open transitions over the breaker's lifetime.
    trips: u64,
}

/// One breaker guards one path. Shared (Arc) between the admission
/// front-end (admit) and that path's worker (record_*).
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    inner: Mutex<Inner>,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        CircuitBreaker {
            cfg,
            inner: Mutex::new(Inner {
                state: BreakerState::Closed,
                opened_at: Instant::now(),
                outcomes: VecDeque::new(),
                probes_sent: 0,
                probe_successes: 0,
                trips: 0,
            }),
        }
    }

    /// May a new request be routed to this path right now? Open→HalfOpen
    /// promotion happens here (first admission attempt after the
    /// cooldown becomes the first probe).
    pub fn admit(&self) -> bool {
        if !self.cfg.enabled {
            return true;
        }
        let mut g = self.inner.lock().unwrap();
        match g.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if g.opened_at.elapsed() >= Duration::from_millis(self.cfg.cooldown_ms) {
                    g.state = BreakerState::HalfOpen;
                    g.probes_sent = 1;
                    g.probe_successes = 0;
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => {
                if g.probes_sent < self.cfg.probes {
                    g.probes_sent += 1;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// A batch on this path completed successfully in `exec_ms`.
    pub fn record_success(&self, exec_ms: f64) {
        if !self.cfg.enabled {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        match g.state {
            BreakerState::HalfOpen => {
                g.probe_successes += 1;
                if g.probe_successes >= self.cfg.probes {
                    g.state = BreakerState::Closed;
                    g.outcomes.clear();
                }
            }
            BreakerState::Closed => {
                self.push_outcome(&mut g, true, exec_ms);
                self.evaluate(&mut g);
            }
            // Stale completion from a batch admitted before the trip.
            BreakerState::Open => {}
        }
    }

    /// A batch on this path failed (executor error or panic) after
    /// `exec_ms`.
    pub fn record_failure(&self, exec_ms: f64) {
        if !self.cfg.enabled {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        match g.state {
            // Any failed probe re-opens with a fresh cooldown.
            BreakerState::HalfOpen => self.trip(&mut g),
            BreakerState::Closed => {
                self.push_outcome(&mut g, false, exec_ms);
                self.evaluate(&mut g);
            }
            BreakerState::Open => {}
        }
    }

    /// An admitted probe never reached the worker (its enqueue was
    /// refused); treat it as a failed probe so the breaker cannot wedge in
    /// HalfOpen with all probe slots spent and no outcomes coming.
    pub fn probe_aborted(&self) {
        if !self.cfg.enabled {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if g.state == BreakerState::HalfOpen {
            self.trip(&mut g);
        }
    }

    pub fn state(&self) -> BreakerState {
        if !self.cfg.enabled {
            return BreakerState::Closed;
        }
        self.inner.lock().unwrap().state
    }

    /// Lifetime count of Closed/HalfOpen→Open transitions.
    pub fn trips(&self) -> u64 {
        self.inner.lock().unwrap().trips
    }

    fn push_outcome(&self, g: &mut Inner, ok: bool, exec_ms: f64) {
        g.outcomes.push_back((ok, exec_ms));
        while g.outcomes.len() > self.cfg.window {
            g.outcomes.pop_front();
        }
    }

    fn evaluate(&self, g: &mut Inner) {
        if g.outcomes.len() < self.cfg.min_samples {
            return;
        }
        let n = g.outcomes.len() as f64;
        let failures = g.outcomes.iter().filter(|(ok, _)| !ok).count() as f64;
        if failures / n >= self.cfg.error_rate {
            self.trip(g);
            return;
        }
        if self.cfg.latency_ms > 0.0 {
            let mean_ms = g.outcomes.iter().map(|(_, ms)| ms).sum::<f64>() / n;
            if mean_ms >= self.cfg.latency_ms {
                self.trip(g);
            }
        }
    }

    fn trip(&self, g: &mut Inner) {
        g.state = BreakerState::Open;
        g.opened_at = Instant::now();
        g.outcomes.clear();
        g.probes_sent = 0;
        g.probe_successes = 0;
        g.trips += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BreakerConfig {
        BreakerConfig {
            enabled: true,
            window: 8,
            min_samples: 4,
            error_rate: 0.5,
            latency_ms: 0.0,
            cooldown_ms: 20,
            probes: 2,
        }
    }

    #[test]
    fn stays_closed_under_min_samples() {
        let b = CircuitBreaker::new(fast_cfg());
        for _ in 0..3 {
            b.record_failure(1.0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admit());
        assert_eq!(b.trips(), 0);
    }

    #[test]
    fn trips_on_error_rate_and_blocks_admission() {
        let b = CircuitBreaker::new(fast_cfg());
        for _ in 0..4 {
            b.record_failure(1.0);
        }
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admit(), "open breaker must refuse before cooldown");
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn successes_keep_error_rate_below_threshold() {
        let b = CircuitBreaker::new(fast_cfg());
        // 8-slot window: 4 ok then 3 failed peaks at 3/7 ≈ 43% — closed;
        // the next failure makes 4/8 = 50% and trips.
        for _ in 0..4 {
            b.record_success(1.0);
        }
        for _ in 0..3 {
            b.record_failure(1.0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_failure(1.0); // 4/8 = 50%
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn half_open_probe_cycle_closes_on_success() {
        let b = CircuitBreaker::new(fast_cfg());
        for _ in 0..4 {
            b.record_failure(1.0);
        }
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(25));
        // cooldown elapsed: exactly `probes` admissions allowed
        assert!(b.admit());
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(b.admit());
        assert!(!b.admit(), "probe budget is exactly cfg.probes");
        b.record_success(1.0);
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.record_success(1.0);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admit());
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn failed_probe_reopens() {
        let b = CircuitBreaker::new(fast_cfg());
        for _ in 0..4 {
            b.record_failure(1.0);
        }
        std::thread::sleep(Duration::from_millis(25));
        assert!(b.admit());
        b.record_failure(1.0);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admit(), "fresh cooldown after failed probe");
        assert_eq!(b.trips(), 2);
    }

    #[test]
    fn latency_trip() {
        let b = CircuitBreaker::new(BreakerConfig {
            latency_ms: 50.0,
            ..fast_cfg()
        });
        // all successful, but slow: mean 80ms >= 50ms threshold
        for _ in 0..4 {
            b.record_success(80.0);
        }
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn latency_trip_disabled_at_zero() {
        let b = CircuitBreaker::new(fast_cfg());
        for _ in 0..20 {
            b.record_success(10_000.0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn disabled_breaker_never_trips() {
        let b = CircuitBreaker::new(BreakerConfig {
            enabled: false,
            ..fast_cfg()
        });
        for _ in 0..100 {
            b.record_failure(1.0);
            assert!(b.admit());
        }
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.trips(), 0);
    }

    #[test]
    fn aborted_probe_reopens_instead_of_wedging() {
        let b = CircuitBreaker::new(fast_cfg());
        for _ in 0..4 {
            b.record_failure(1.0);
        }
        std::thread::sleep(Duration::from_millis(25));
        assert!(b.admit()); // half-open probe admitted...
        b.probe_aborted(); // ...but its enqueue was refused
        assert_eq!(b.state(), BreakerState::Open);
        // after another cooldown the probe cycle restarts normally
        std::thread::sleep(Duration::from_millis(25));
        assert!(b.admit());
        b.record_success(1.0);
        b.admit();
        b.record_success(1.0);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn old_failures_age_out_of_window() {
        let b = CircuitBreaker::new(fast_cfg());
        for _ in 0..3 {
            b.record_failure(1.0);
        }
        // 8 successes push all 3 failures out of the 8-slot window
        for _ in 0..8 {
            b.record_success(1.0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.trips(), 0);
    }
}
