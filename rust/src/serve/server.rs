//! Path-serving server (paper §2.6: "at test time, the paths are
//! instantiated, and served independently, with text routed to each path
//! via a router" — only a single path executes per query, never the full
//! mixture).
//!
//! Topology: an admission front-end routes EACH document individually via
//! `router::assign`, then enqueues it on the bounded queue of its path.
//! One path-server worker per path (a dedicated `util::threadpool`
//! thread) owns only its own assembled `theta` and drains its queue with
//! deadline micro-batching ([`super::batcher`]), pads partial batches to
//! the compiled HLO batch shape, scores them, and answers each request
//! over its [`super::request::Ticket`]. Telemetry flows into a shared
//! [`super::stats::ServeStats`].
//!
//! Self-healing plane (the serving counterpart of the coordinator's
//! chaos-hardened monitor):
//!
//! * every worker runs under [`super::supervisor`] — executor panics are
//!   caught, their batches resolve loudly, and the worker restarts with
//!   capped exponential backoff (or goes `Down` past its budget);
//! * admission consults a per-path [`super::breaker::CircuitBreaker`] —
//!   error bursts and latency spikes stop traffic to a sick path;
//! * degraded-mode routing: when the assigned path is refused (breaker
//!   open or worker down), [`Server::submit`] walks the router's ranked
//!   fallbacks ([`Router::ranked`]) and redirects to the best admittable
//!   runner-up, shedding loudly when no fallback can take the request
//!   within the shed deadline.
//!
//! The executor is a trait so tests and benches can serve synthetic
//! backends; production uses [`EnginePathExecutor`] over the PJRT
//! [`Engine`] with thetas from a trained run (`TrainedPaths`).

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::config::ServeConfig;
use crate::routing::router::Router;
use crate::runtime::engine::Engine;
use crate::serve::batcher::{BoundedQueue, PushError};
use crate::serve::breaker::CircuitBreaker;
use crate::serve::request::{admit, ServeError, ServeRequest, Ticket};
use crate::serve::stats::{PathHealth, ServeReport, ServeStats};
use crate::serve::supervisor::run_supervised;
use crate::util::threadpool::ThreadPool;

/// One path's compute backend. Implementations own their path's
/// parameters; the server never materializes the mixture.
pub trait PathExecutor: Send + 'static {
    /// Compiled batch shape (rows per forward call).
    fn batch(&self) -> usize;
    /// Sequence length every token row must have.
    fn seq(&self) -> usize;
    /// Score the first `rows` rows of `toks` (`[batch, seq]` flattened,
    /// pad rows beyond `rows` ignored). Returns per-row
    /// `(nll, tokens_scored)`.
    fn forward(&mut self, toks: &[i32], rows: usize) -> Result<Vec<(f64, usize)>>;
}

/// Production executor: PJRT engine + this path's assembled theta,
/// scoring at `seq_eval` with the paper's prefix masking.
pub struct EnginePathExecutor {
    engine: Arc<Engine>,
    theta: Vec<f32>,
}

impl EnginePathExecutor {
    pub fn new(engine: Arc<Engine>, theta: Vec<f32>) -> Self {
        EnginePathExecutor { engine, theta }
    }
}

impl PathExecutor for EnginePathExecutor {
    fn batch(&self) -> usize {
        self.engine.model().batch
    }

    fn seq(&self) -> usize {
        self.engine.model().seq_eval
    }

    fn forward(&mut self, toks: &[i32], rows: usize) -> Result<Vec<(f64, usize)>> {
        let mc = self.engine.model();
        let seq = mc.seq_eval;
        let lp = self.engine.token_logprobs(&self.theta, toks, seq)?;
        Ok((0..rows.min(mc.batch))
            .map(|b| {
                crate::eval::nll_row(&lp[b * (seq - 1)..(b + 1) * (seq - 1)], seq, mc.prefix)
            })
            .collect())
    }
}

/// Build one [`EnginePathExecutor`] per path from a trained run's theta
/// map. Takes the map by value and MOVES each theta into its executor —
/// at real path sizes a clone would double resident parameter memory.
/// Path ids must be contiguous `0..P` (as produced by
/// `routing::router::thetas_map`), since `router::assign` returns ids in
/// that range.
pub fn engine_executors(
    engine: &Arc<Engine>,
    mut thetas: HashMap<usize, Vec<f32>>,
) -> Result<Vec<EnginePathExecutor>> {
    (0..thetas.len())
        .map(|p| {
            let theta = thetas
                .remove(&p)
                .with_context(|| format!("path ids not contiguous: missing path {p}"))?;
            Ok(EnginePathExecutor::new(Arc::clone(engine), theta))
        })
        .collect()
}

/// The serving subsystem: admission front-end + supervised per-path
/// workers behind per-path circuit breakers.
pub struct Server {
    router: Router,
    queues: Vec<Arc<BoundedQueue<ServeRequest>>>,
    breakers: Vec<Arc<CircuitBreaker>>,
    stats: Arc<ServeStats>,
    seq: usize,
    reject_on_full: bool,
    admission_timeout: Duration,
    shed_deadline: Duration,
    next_id: AtomicU64,
    pool: Option<ThreadPool>,
}

impl Server {
    /// Spawn one supervised worker per executor (executor index == path
    /// id) and start accepting traffic.
    pub fn start<E: PathExecutor>(cfg: &ServeConfig, router: Router, executors: Vec<E>) -> Server {
        assert!(!executors.is_empty(), "need at least one path executor");
        let paths = executors.len();
        let stats = Arc::new(ServeStats::new(paths));
        let queues: Vec<Arc<BoundedQueue<ServeRequest>>> = (0..paths)
            .map(|_| Arc::new(BoundedQueue::new(cfg.queue_cap.max(1))))
            .collect();
        let breakers: Vec<Arc<CircuitBreaker>> = (0..paths)
            .map(|_| Arc::new(CircuitBreaker::new(cfg.breaker.clone())))
            .collect();
        let pool = ThreadPool::new(paths);
        let seq = executors[0].seq();
        for (path, exec) in executors.into_iter().enumerate() {
            assert_eq!(exec.seq(), seq, "executors disagree on seq length");
            let queue = Arc::clone(&queues[path]);
            let stats = Arc::clone(&stats);
            let breaker = Arc::clone(&breakers[path]);
            let sup = cfg.supervisor.clone();
            // Flush size is capped by the compiled batch shape: a larger
            // micro-batch cannot fit one forward call.
            let max_batch = if cfg.max_batch == 0 {
                exec.batch()
            } else {
                cfg.max_batch.min(exec.batch())
            };
            let max_wait = Duration::from_millis(cfg.max_wait_ms);
            let idle = Duration::from_millis(cfg.idle_ms.max(1));
            pool.execute(move || {
                run_supervised(
                    path, exec, queue, stats, breaker, sup, max_batch, max_wait, idle,
                )
            });
        }
        Server {
            router,
            queues,
            breakers,
            stats,
            seq,
            reject_on_full: cfg.reject_on_full,
            admission_timeout: Duration::from_millis(cfg.admission_timeout_ms),
            shed_deadline: Duration::from_millis(cfg.shed_deadline_ms),
            next_id: AtomicU64::new(0),
            pool: Some(pool),
        }
    }

    pub fn paths(&self) -> usize {
        self.queues.len()
    }

    /// Admission: route ONE document by its own features, then enqueue it
    /// on its path's queue. This is the per-document replacement for the
    /// old demo's batch-major `routed[batch_start * batch]` assignment.
    ///
    /// Degraded mode: when the assigned path is refused (breaker open /
    /// worker down), the request redirects to the router's best
    /// admittable runner-up — DiPaCo paths are trained on overlapping
    /// shards, so the runner-up is the next-best model for the document,
    /// not an arbitrary peer. A redirect that cannot enqueue within the
    /// shed deadline is shed loudly; if every path refuses, admission
    /// fails with `CircuitOpen` against the primary.
    pub fn submit(&self, z: &[f32], tokens: Vec<i32>) -> Result<Ticket, ServeError> {
        if tokens.len() != self.seq {
            return Err(ServeError::BadRequest {
                expect: self.seq,
                got: tokens.len(),
            });
        }
        let primary = self.router.assign(z);
        // Healthy fast path: one health load + one breaker check on top of
        // the pre-breaker admission cost (no ranked-scores sort).
        if self.admittable(primary) {
            return self.enqueue(primary, tokens);
        }
        for (path, _) in self.router.ranked(z) {
            if path == primary || !self.admittable(path) {
                continue;
            }
            self.stats.record_redirect(primary, path);
            return match self.enqueue_by_deadline(path, tokens) {
                Err(ServeError::Overloaded { .. }) => {
                    self.stats.record_shed(primary);
                    Err(ServeError::Shed { path })
                }
                other => other,
            };
        }
        Err(ServeError::CircuitOpen { path: primary })
    }

    /// Enqueue on an explicit path (pre-routed clients, tests, benches).
    /// Consults the path's health and breaker but never redirects: the
    /// caller chose the path, so refusal is loud instead of silent
    /// re-routing.
    pub fn submit_to(&self, path: usize, tokens: Vec<i32>) -> Result<Ticket, ServeError> {
        if tokens.len() != self.seq {
            return Err(ServeError::BadRequest {
                expect: self.seq,
                got: tokens.len(),
            });
        }
        if path >= self.queues.len() {
            return Err(ServeError::UnknownPath {
                path,
                paths: self.queues.len(),
            });
        }
        if self.stats.health(path) == PathHealth::Down {
            return Err(ServeError::WorkerDown { path });
        }
        if !self.breakers[path].admit() {
            return Err(ServeError::CircuitOpen { path });
        }
        self.enqueue(path, tokens)
    }

    /// Is `path` currently taking traffic? (Not down, breaker admits.)
    fn admittable(&self, path: usize) -> bool {
        self.stats.health(path) != PathHealth::Down && self.breakers[path].admit()
    }

    /// Enqueue under the configured backpressure policy (`path` already
    /// validated and admitted by the breaker).
    fn enqueue(&self, path: usize, tokens: Vec<i32>) -> Result<Ticket, ServeError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (req, ticket) = admit(id, path, tokens);
        let pushed = if self.reject_on_full {
            self.queues[path].try_push(req)
        } else {
            self.queues[path].push(req, self.admission_timeout)
        };
        self.finish_enqueue(path, ticket, pushed)
    }

    /// Enqueue a redirected request under the (short) shed deadline
    /// instead of the admission park timeout: a saturated fallback sheds
    /// fast rather than stacking parked admissions onto a degraded fleet.
    fn enqueue_by_deadline(&self, path: usize, tokens: Vec<i32>) -> Result<Ticket, ServeError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (req, ticket) = admit(id, path, tokens);
        let pushed = self.queues[path].push(req, self.shed_deadline);
        self.finish_enqueue(path, ticket, pushed)
    }

    fn finish_enqueue(
        &self,
        path: usize,
        ticket: Ticket,
        pushed: std::result::Result<usize, PushError<ServeRequest>>,
    ) -> Result<Ticket, ServeError> {
        match pushed {
            Ok(depth) => {
                self.stats.record_enqueue(path, depth);
                Ok(ticket)
            }
            Err(PushError::Full(_)) => {
                // An admitted half-open probe that never reached the
                // worker must not wedge the breaker in HalfOpen.
                self.breakers[path].probe_aborted();
                self.stats.record_reject(path);
                Err(ServeError::Overloaded { path })
            }
            Err(PushError::Closed(_)) => {
                self.breakers[path].probe_aborted();
                Err(ServeError::Closed)
            }
        }
    }

    /// Live telemetry snapshot, including per-path breaker states.
    pub fn report(&self) -> ServeReport {
        self.fill_breakers(self.stats.snapshot())
    }

    /// Stop admission, drain every queue, join the workers, and return
    /// the final report.
    pub fn shutdown(mut self) -> ServeReport {
        for q in &self.queues {
            q.close();
        }
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
        self.fill_breakers(self.stats.snapshot())
    }

    fn fill_breakers(&self, mut r: ServeReport) -> ServeReport {
        r.per_path_breaker = self
            .breakers
            .iter()
            .map(|b| b.state().as_str().to_string())
            .collect();
        r.per_path_trips = self.breakers.iter().map(|b| b.trips()).collect();
        r
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        for q in &self.queues {
            q.close();
        }
        // ThreadPool's own Drop joins the workers.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BreakerConfig, SupervisorConfig};
    use crate::testkit::exec::logging_fleet;
    use crate::testkit::install_quiet_panic_hook;
    use crate::testkit::routers::{one_hot, one_hot_router};

    /// Regression for the old demo's batch-major bug: every document must
    /// execute on ITS OWN assigned path, even when a contiguous submission
    /// window mixes paths.
    #[test]
    fn per_document_routing_honored() {
        let paths = 3;
        let (execs, log) = logging_fleet(paths, 4, 8, Duration::ZERO);
        let server = Server::start(&ServeConfig::default(), one_hot_router(paths), execs);
        // Interleaved stream: doc i belongs to path i % 3. The old demo
        // would have executed a whole 4-doc window on the first doc's path.
        let tickets: Vec<Ticket> = (0..24)
            .map(|i| {
                let mut toks = vec![0i32; 8];
                toks[0] = i as i32; // marker: which doc is this row
                server.submit(&one_hot(paths, (i as usize) % paths), toks).unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let resp = t.wait().expect("response");
            assert_eq!(resp.path, i % paths, "doc {i} answered by the wrong path");
            assert!(resp.tokens_scored > 0);
        }
        let report = server.shutdown();
        assert_eq!(report.served, 24);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.redirected, 0);
        assert_eq!(report.per_path_served, vec![8, 8, 8]);
        assert_eq!(report.per_path_breaker, vec!["closed"; 3]);
        // The executors themselves saw each doc on its assigned path.
        for &(path, marker) in log.lock().unwrap().iter() {
            assert_eq!(
                marker as usize % paths,
                path,
                "doc {marker} executed on path {path}"
            );
        }
    }

    #[test]
    fn reject_on_full_backpressure() {
        let (execs, _log) = logging_fleet(1, 2, 4, Duration::from_millis(30));
        let cfg = ServeConfig {
            queue_cap: 2,
            reject_on_full: true,
            max_wait_ms: 1,
            ..Default::default()
        };
        let server = Server::start(&cfg, one_hot_router(1), execs);
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for _ in 0..50 {
            match server.submit_to(0, vec![0; 4]) {
                Ok(t) => accepted.push(t),
                Err(ServeError::Overloaded { path: 0 }) => rejected += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(rejected > 0, "50 instant submits must overflow a 2-slot queue");
        for t in accepted {
            assert!(t.wait().is_ok(), "accepted requests are always answered");
        }
        let report = server.shutdown();
        assert_eq!(report.served + report.rejected, 50);
        assert_eq!(report.rejected as usize, rejected);
    }

    #[test]
    fn bad_request_and_shutdown_drain() {
        let (execs, _log) = logging_fleet(2, 4, 8, Duration::ZERO);
        let server = Server::start(&ServeConfig::default(), one_hot_router(2), execs);
        assert!(matches!(
            server.submit_to(0, vec![0; 5]),
            Err(ServeError::BadRequest { expect: 8, got: 5 })
        ));
        // out-of-range pre-routed path is an error, not a panic
        assert!(matches!(
            server.submit_to(7, vec![0; 8]),
            Err(ServeError::UnknownPath { path: 7, paths: 2 })
        ));
        let tickets: Vec<Ticket> = (0..9)
            .map(|i| server.submit_to(i % 2, vec![0; 8]).unwrap())
            .collect();
        // shutdown drains everything already admitted
        let report = server.shutdown();
        assert_eq!(report.served, 9);
        for t in tickets {
            assert!(t.wait().is_ok());
        }
    }

    #[test]
    fn partial_batches_flush_on_deadline() {
        let (execs, _log) = logging_fleet(1, 8, 4, Duration::ZERO);
        let cfg = ServeConfig {
            max_wait_ms: 10,
            ..Default::default()
        };
        let server = Server::start(&cfg, one_hot_router(1), execs);
        // 3 docs never fill the 8-row batch; only the deadline flushes them.
        let tickets: Vec<Ticket> =
            (0..3).map(|_| server.submit_to(0, vec![0; 4]).unwrap()).collect();
        for t in tickets {
            let r = t.wait().expect("deadline flush");
            assert!(r.batch_fill <= 3);
        }
        let report = server.shutdown();
        assert_eq!(report.served, 3);
        assert!(report.mean_batch_fill <= 3.0);
    }

    /// Satellite: a ticket whose receiver was dropped must not wedge the
    /// worker or skew the telemetry — its batch neighbours still serve.
    #[test]
    fn dropped_ticket_receiver_is_harmless() {
        let (execs, _log) = logging_fleet(1, 4, 4, Duration::ZERO);
        let server = Server::start(&ServeConfig::default(), one_hot_router(1), execs);
        let t0 = server.submit_to(0, vec![0; 4]).unwrap();
        let t1 = server.submit_to(0, vec![0; 4]).unwrap();
        let t2 = server.submit_to(0, vec![0; 4]).unwrap();
        drop(t1); // client went away before its response
        assert!(t0.wait().is_ok());
        assert!(t2.wait().is_ok());
        let report = server.shutdown();
        // the worker scored all 3; the dead send is dropped silently
        assert_eq!(report.served, 3);
        assert_eq!(report.failed, 0);
    }

    /// Always-failing executor for breaker tests (errors, not panics).
    struct FailingExec {
        fail: bool,
        batch: usize,
        seq: usize,
    }

    impl PathExecutor for FailingExec {
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq(&self) -> usize {
            self.seq
        }
        fn forward(&mut self, _t: &[i32], rows: usize) -> anyhow::Result<Vec<(f64, usize)>> {
            if self.fail {
                anyhow::bail!("FailingExec scripted error");
            }
            Ok((0..rows).map(|_| (1.0, self.seq - 1)).collect())
        }
    }

    fn strict_breaker_cfg() -> ServeConfig {
        ServeConfig {
            max_batch: 1,
            max_wait_ms: 0,
            breaker: BreakerConfig {
                enabled: true,
                window: 8,
                min_samples: 2,
                error_rate: 0.5,
                latency_ms: 0.0,
                cooldown_ms: 60_000, // stays open for the whole test
                probes: 2,
            },
            ..Default::default()
        }
    }

    /// Tentpole: error burst trips the breaker; `submit` then redirects to
    /// the router's runner-up and the redirect is recorded.
    #[test]
    fn open_breaker_redirects_submit_to_runner_up() {
        let execs = vec![
            FailingExec { fail: true, batch: 1, seq: 4 },
            FailingExec { fail: false, batch: 1, seq: 4 },
        ];
        let server = Server::start(&strict_breaker_cfg(), one_hot_router(2), execs);
        // two failing batches trip path 0's breaker (min_samples = 2)
        for _ in 0..2 {
            let t = server.submit(&one_hot(2, 0), vec![0; 4]).unwrap();
            assert_eq!(t.wait(), Err(ServeError::ExecFailed { path: 0 }));
        }
        // now path 0 refuses; the same features redirect to path 1
        let t = server.submit(&one_hot(2, 0), vec![0; 4]).unwrap();
        let resp = t.wait().expect("redirected request must serve");
        assert_eq!(resp.path, 1, "served by the runner-up path");
        // path 1 traffic is unaffected
        let t = server.submit(&one_hot(2, 1), vec![0; 4]).unwrap();
        assert_eq!(t.wait().unwrap().path, 1);
        let report = server.shutdown();
        assert_eq!(report.redirected, 1);
        assert_eq!(report.per_path_redirected, vec![1, 0]);
        assert_eq!(report.per_path_breaker[0], "open");
        assert_eq!(report.per_path_breaker[1], "closed");
        assert_eq!(report.per_path_trips, vec![1, 0]);
        assert_eq!(report.failed, 2);
        assert_eq!(report.shed, 0);
    }

    /// With a single path there is no runner-up: an open breaker surfaces
    /// as a loud CircuitOpen at admission, and submit_to agrees.
    #[test]
    fn open_breaker_without_fallback_is_circuit_open() {
        let execs = vec![FailingExec { fail: true, batch: 1, seq: 4 }];
        let server = Server::start(&strict_breaker_cfg(), one_hot_router(1), execs);
        for _ in 0..2 {
            let t = server.submit(&one_hot(1, 0), vec![0; 4]).unwrap();
            assert_eq!(t.wait(), Err(ServeError::ExecFailed { path: 0 }));
        }
        assert_eq!(
            server.submit(&one_hot(1, 0), vec![0; 4]).err(),
            Some(ServeError::CircuitOpen { path: 0 })
        );
        assert_eq!(
            server.submit_to(0, vec![0; 4]).err(),
            Some(ServeError::CircuitOpen { path: 0 })
        );
        let report = server.shutdown();
        assert_eq!(report.per_path_breaker[0], "open");
        assert_eq!(report.served, 0);
    }

    /// A redirect whose fallback queue is saturated sheds within the shed
    /// deadline instead of parking on a degraded fleet.
    #[test]
    fn saturated_fallback_sheds_loudly() {
        // path 1 is the only fallback and its worker is slow with a
        // 1-slot queue, so redirected traffic overflows quickly.
        struct SlowExec {
            batch: usize,
            seq: usize,
            delay: Duration,
        }
        impl PathExecutor for SlowExec {
            fn batch(&self) -> usize {
                self.batch
            }
            fn seq(&self) -> usize {
                self.seq
            }
            fn forward(&mut self, _t: &[i32], rows: usize) -> anyhow::Result<Vec<(f64, usize)>> {
                std::thread::sleep(self.delay);
                Ok((0..rows).map(|_| (1.0, self.seq - 1)).collect())
            }
        }
        // Heterogeneous fleet needs a common type: box the executors.
        impl PathExecutor for Box<dyn PathExecutor> {
            fn batch(&self) -> usize {
                (**self).batch()
            }
            fn seq(&self) -> usize {
                (**self).seq()
            }
            fn forward(&mut self, t: &[i32], rows: usize) -> anyhow::Result<Vec<(f64, usize)>> {
                (**self).forward(t, rows)
            }
        }
        let execs: Vec<Box<dyn PathExecutor>> = vec![
            Box::new(FailingExec { fail: true, batch: 1, seq: 4 }),
            Box::new(SlowExec { batch: 1, seq: 4, delay: Duration::from_millis(50) }),
        ];
        let cfg = ServeConfig {
            queue_cap: 1,
            shed_deadline_ms: 1,
            ..strict_breaker_cfg()
        };
        let server = Server::start(&cfg, one_hot_router(2), execs);
        for _ in 0..2 {
            let t = server.submit(&one_hot(2, 0), vec![0; 4]).unwrap();
            assert_eq!(t.wait(), Err(ServeError::ExecFailed { path: 0 }));
        }
        // Flood redirects at the 1-slot fallback: the worker holds one
        // batch for 50ms, so most enqueues cannot make the 1ms deadline.
        let mut shed = 0usize;
        let mut accepted = Vec::new();
        for _ in 0..8 {
            match server.submit(&one_hot(2, 0), vec![0; 4]) {
                Ok(t) => accepted.push(t),
                Err(ServeError::Shed { path: 1 }) => shed += 1,
                Err(e) => panic!("unexpected admission outcome: {e}"),
            }
        }
        assert!(shed > 0, "a 1-slot fallback must shed under an 8-doc burst");
        for t in accepted {
            assert!(t.wait().is_ok(), "admitted redirects still serve");
        }
        let report = server.shutdown();
        assert_eq!(report.shed as usize, shed);
        assert!(report.redirected >= shed as u64);
    }

    /// Panicking executor end to end through Server: supervisor keeps the
    /// path alive, tickets resolve loudly, and the path serves again once
    /// the fault clears.
    #[test]
    fn supervised_path_survives_panics_under_server() {
        install_quiet_panic_hook();
        struct PanicNExec {
            left: usize,
            batch: usize,
            seq: usize,
        }
        impl PathExecutor for PanicNExec {
            fn batch(&self) -> usize {
                self.batch
            }
            fn seq(&self) -> usize {
                self.seq
            }
            fn forward(&mut self, _t: &[i32], rows: usize) -> anyhow::Result<Vec<(f64, usize)>> {
                if self.left > 0 {
                    self.left -= 1;
                    panic!("chaos-inject: PanicNExec scripted panic");
                }
                Ok((0..rows).map(|_| (1.0, self.seq - 1)).collect())
            }
        }
        let cfg = ServeConfig {
            max_batch: 1,
            max_wait_ms: 0,
            breaker: BreakerConfig {
                enabled: false, // isolate supervision from breaker behaviour
                ..Default::default()
            },
            supervisor: SupervisorConfig {
                backoff_ms: 1,
                backoff_max_ms: 4,
                max_consecutive_panics: 0,
            },
            ..Default::default()
        };
        let execs = vec![PanicNExec { left: 2, batch: 1, seq: 4 }];
        let server = Server::start(&cfg, one_hot_router(1), execs);
        for i in 0..5 {
            let t = server.submit_to(0, vec![0; 4]).unwrap();
            let r = t.wait();
            if i < 2 {
                assert_eq!(r, Err(ServeError::ExecFailed { path: 0 }), "req {i}");
            } else {
                assert!(r.is_ok(), "req {i} after restart: {r:?}");
            }
        }
        let report = server.shutdown();
        assert_eq!(report.panics, 2);
        assert_eq!(report.restarts, 2);
        assert_eq!(report.failed, 2);
        assert_eq!(report.served, 3);
        assert_eq!(report.per_path_health, vec![PathHealth::Healthy]);
    }
}
