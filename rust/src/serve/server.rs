//! Path-serving server (paper §2.6: "at test time, the paths are
//! instantiated, and served independently, with text routed to each path
//! via a router" — only a single path executes per query, never the full
//! mixture).
//!
//! Topology: an admission front-end routes EACH document individually via
//! `router::assign`, then enqueues it on the bounded queue of its path.
//! One path-server worker per path (a dedicated `util::threadpool`
//! thread) owns only its own assembled `theta` and drains its queue with
//! deadline micro-batching ([`super::batcher`]), pads partial batches to
//! the compiled HLO batch shape, scores them, and answers each request
//! over its [`super::request::Ticket`]. Telemetry flows into a shared
//! [`super::stats::ServeStats`].
//!
//! The executor is a trait so tests and benches can serve synthetic
//! backends; production uses [`EnginePathExecutor`] over the PJRT
//! [`Engine`] with thetas from a trained run (`TrainedPaths`).

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::routing::router::Router;
use crate::runtime::engine::Engine;
use crate::serve::batcher::{pad_batch, BoundedQueue, PushError};
use crate::serve::request::{admit, ServeError, ServeRequest, ServeResponse, Ticket};
use crate::serve::stats::{ServeReport, ServeStats};
use crate::util::threadpool::ThreadPool;
use crate::warn_;

/// One path's compute backend. Implementations own their path's
/// parameters; the server never materializes the mixture.
pub trait PathExecutor: Send + 'static {
    /// Compiled batch shape (rows per forward call).
    fn batch(&self) -> usize;
    /// Sequence length every token row must have.
    fn seq(&self) -> usize;
    /// Score the first `rows` rows of `toks` (`[batch, seq]` flattened,
    /// pad rows beyond `rows` ignored). Returns per-row
    /// `(nll, tokens_scored)`.
    fn forward(&mut self, toks: &[i32], rows: usize) -> Result<Vec<(f64, usize)>>;
}

/// Production executor: PJRT engine + this path's assembled theta,
/// scoring at `seq_eval` with the paper's prefix masking.
pub struct EnginePathExecutor {
    engine: Arc<Engine>,
    theta: Vec<f32>,
}

impl EnginePathExecutor {
    pub fn new(engine: Arc<Engine>, theta: Vec<f32>) -> Self {
        EnginePathExecutor { engine, theta }
    }
}

impl PathExecutor for EnginePathExecutor {
    fn batch(&self) -> usize {
        self.engine.model().batch
    }

    fn seq(&self) -> usize {
        self.engine.model().seq_eval
    }

    fn forward(&mut self, toks: &[i32], rows: usize) -> Result<Vec<(f64, usize)>> {
        let mc = self.engine.model();
        let seq = mc.seq_eval;
        let lp = self.engine.token_logprobs(&self.theta, toks, seq)?;
        Ok((0..rows.min(mc.batch))
            .map(|b| {
                crate::eval::nll_row(&lp[b * (seq - 1)..(b + 1) * (seq - 1)], seq, mc.prefix)
            })
            .collect())
    }
}

/// Build one [`EnginePathExecutor`] per path from a trained run's theta
/// map. Takes the map by value and MOVES each theta into its executor —
/// at real path sizes a clone would double resident parameter memory.
/// Path ids must be contiguous `0..P` (as produced by
/// `routing::router::thetas_map`), since `router::assign` returns ids in
/// that range.
pub fn engine_executors(
    engine: &Arc<Engine>,
    mut thetas: HashMap<usize, Vec<f32>>,
) -> Result<Vec<EnginePathExecutor>> {
    (0..thetas.len())
        .map(|p| {
            let theta = thetas
                .remove(&p)
                .with_context(|| format!("path ids not contiguous: missing path {p}"))?;
            Ok(EnginePathExecutor::new(Arc::clone(engine), theta))
        })
        .collect()
}

/// The serving subsystem: admission front-end + per-path workers.
pub struct Server {
    router: Router,
    queues: Vec<Arc<BoundedQueue<ServeRequest>>>,
    stats: Arc<ServeStats>,
    seq: usize,
    reject_on_full: bool,
    admission_timeout: Duration,
    next_id: AtomicU64,
    pool: Option<ThreadPool>,
}

impl Server {
    /// Spawn one dedicated worker per executor (executor index == path
    /// id) and start accepting traffic.
    pub fn start<E: PathExecutor>(cfg: &ServeConfig, router: Router, executors: Vec<E>) -> Server {
        assert!(!executors.is_empty(), "need at least one path executor");
        let paths = executors.len();
        let stats = Arc::new(ServeStats::new(paths));
        let queues: Vec<Arc<BoundedQueue<ServeRequest>>> = (0..paths)
            .map(|_| Arc::new(BoundedQueue::new(cfg.queue_cap.max(1))))
            .collect();
        let pool = ThreadPool::new(paths);
        let seq = executors[0].seq();
        for (path, mut exec) in executors.into_iter().enumerate() {
            assert_eq!(exec.seq(), seq, "executors disagree on seq length");
            let queue = Arc::clone(&queues[path]);
            let stats = Arc::clone(&stats);
            // Flush size is capped by the compiled batch shape: a larger
            // micro-batch cannot fit one forward call.
            let max_batch = if cfg.max_batch == 0 {
                exec.batch()
            } else {
                cfg.max_batch.min(exec.batch())
            };
            let max_wait = Duration::from_millis(cfg.max_wait_ms);
            let idle = Duration::from_millis(cfg.idle_ms.max(1));
            pool.execute(move || {
                path_worker(path, &mut exec, &queue, &stats, max_batch, max_wait, idle)
            });
        }
        Server {
            router,
            queues,
            stats,
            seq,
            reject_on_full: cfg.reject_on_full,
            admission_timeout: Duration::from_millis(cfg.admission_timeout_ms),
            next_id: AtomicU64::new(0),
            pool: Some(pool),
        }
    }

    pub fn paths(&self) -> usize {
        self.queues.len()
    }

    /// Admission: route ONE document by its own features, then enqueue it
    /// on its path's queue. This is the per-document replacement for the
    /// old demo's batch-major `routed[batch_start * batch]` assignment.
    pub fn submit(&self, z: &[f32], tokens: Vec<i32>) -> Result<Ticket, ServeError> {
        let path = self.router.assign(z);
        self.submit_to(path, tokens)
    }

    /// Enqueue on an explicit path (pre-routed clients, tests, benches).
    pub fn submit_to(&self, path: usize, tokens: Vec<i32>) -> Result<Ticket, ServeError> {
        if tokens.len() != self.seq {
            return Err(ServeError::BadRequest {
                expect: self.seq,
                got: tokens.len(),
            });
        }
        if path >= self.queues.len() {
            return Err(ServeError::UnknownPath {
                path,
                paths: self.queues.len(),
            });
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (req, ticket) = admit(id, path, tokens);
        let pushed = if self.reject_on_full {
            self.queues[path].try_push(req)
        } else {
            self.queues[path].push(req, self.admission_timeout)
        };
        match pushed {
            Ok(depth) => {
                self.stats.record_enqueue(path, depth);
                Ok(ticket)
            }
            Err(PushError::Full(_)) => {
                self.stats.record_reject(path);
                Err(ServeError::Overloaded { path })
            }
            Err(PushError::Closed(_)) => Err(ServeError::Closed),
        }
    }

    /// Live telemetry snapshot.
    pub fn report(&self) -> ServeReport {
        self.stats.snapshot()
    }

    /// Stop admission, drain every queue, join the workers, and return
    /// the final report.
    pub fn shutdown(mut self) -> ServeReport {
        for q in &self.queues {
            q.close();
        }
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
        self.stats.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        for q in &self.queues {
            q.close();
        }
        // ThreadPool's own Drop joins the workers.
    }
}

/// Drain loop of one path server (runs on a dedicated pool thread until
/// its queue is closed and empty).
fn path_worker<E: PathExecutor>(
    path: usize,
    exec: &mut E,
    queue: &BoundedQueue<ServeRequest>,
    stats: &ServeStats,
    max_batch: usize,
    max_wait: Duration,
    idle: Duration,
) {
    loop {
        let batch = match queue.pop_batch(max_batch, max_wait, idle) {
            None => break,       // closed + drained
            Some(b) if b.is_empty() => continue, // idle tick
            Some(b) => b,
        };
        let taken = Instant::now();
        let fill = batch.len();
        let rows: Vec<&[i32]> = batch.iter().map(|r| r.tokens.as_slice()).collect();
        let toks = pad_batch(&rows, exec.batch());
        stats.record_batch(path, fill);
        match exec.forward(&toks, fill) {
            Ok(scored) if scored.len() != fill => {
                // A short/long result would silently drop tail requests in
                // the zip below — surface it as a batch-level failure.
                stats.record_exec_error(path);
                warn_!(
                    "serve",
                    "path {path} executor returned {} results for {fill}-doc batch",
                    scored.len()
                );
            }
            Ok(scored) => {
                for (req, (nll, ntok)) in batch.into_iter().zip(scored) {
                    let wait_ms =
                        taken.saturating_duration_since(req.accepted_at).as_secs_f64() * 1e3;
                    let latency_ms = req.accepted_at.elapsed().as_secs_f64() * 1e3;
                    stats.record_response(path, latency_ms, wait_ms, ntok);
                    // A gone client is not a server error; drop silently.
                    let _ = req.tx.send(ServeResponse {
                        id: req.id,
                        path,
                        nll,
                        tokens_scored: ntok,
                        latency_ms,
                        batch_fill: fill,
                    });
                }
            }
            Err(e) => {
                // Dropping the batch drops its senders; every waiting
                // ticket resolves to None rather than hanging.
                stats.record_exec_error(path);
                warn_!("serve", "path {path} forward failed on {fill}-doc batch: {e:#}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::exec::logging_fleet;
    use crate::testkit::routers::{one_hot, one_hot_router};

    /// Regression for the old demo's batch-major bug: every document must
    /// execute on ITS OWN assigned path, even when a contiguous submission
    /// window mixes paths.
    #[test]
    fn per_document_routing_honored() {
        let paths = 3;
        let (execs, log) = logging_fleet(paths, 4, 8, Duration::ZERO);
        let server = Server::start(&ServeConfig::default(), one_hot_router(paths), execs);
        // Interleaved stream: doc i belongs to path i % 3. The old demo
        // would have executed a whole 4-doc window on the first doc's path.
        let tickets: Vec<Ticket> = (0..24)
            .map(|i| {
                let mut toks = vec![0i32; 8];
                toks[0] = i as i32; // marker: which doc is this row
                server.submit(&one_hot(paths, (i as usize) % paths), toks).unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let resp = t.wait().expect("response");
            assert_eq!(resp.path, i % paths, "doc {i} answered by the wrong path");
            assert!(resp.tokens_scored > 0);
        }
        let report = server.shutdown();
        assert_eq!(report.served, 24);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.per_path_served, vec![8, 8, 8]);
        // The executors themselves saw each doc on its assigned path.
        for &(path, marker) in log.lock().unwrap().iter() {
            assert_eq!(
                marker as usize % paths,
                path,
                "doc {marker} executed on path {path}"
            );
        }
    }

    #[test]
    fn reject_on_full_backpressure() {
        let (execs, _log) = logging_fleet(1, 2, 4, Duration::from_millis(30));
        let cfg = ServeConfig {
            queue_cap: 2,
            reject_on_full: true,
            max_wait_ms: 1,
            ..Default::default()
        };
        let server = Server::start(&cfg, one_hot_router(1), execs);
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for _ in 0..50 {
            match server.submit_to(0, vec![0; 4]) {
                Ok(t) => accepted.push(t),
                Err(ServeError::Overloaded { path: 0 }) => rejected += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(rejected > 0, "50 instant submits must overflow a 2-slot queue");
        for t in accepted {
            assert!(t.wait().is_some(), "accepted requests are always answered");
        }
        let report = server.shutdown();
        assert_eq!(report.served + report.rejected, 50);
        assert_eq!(report.rejected as usize, rejected);
    }

    #[test]
    fn bad_request_and_shutdown_drain() {
        let (execs, _log) = logging_fleet(2, 4, 8, Duration::ZERO);
        let server = Server::start(&ServeConfig::default(), one_hot_router(2), execs);
        assert!(matches!(
            server.submit_to(0, vec![0; 5]),
            Err(ServeError::BadRequest { expect: 8, got: 5 })
        ));
        // out-of-range pre-routed path is an error, not a panic
        assert!(matches!(
            server.submit_to(7, vec![0; 8]),
            Err(ServeError::UnknownPath { path: 7, paths: 2 })
        ));
        let tickets: Vec<Ticket> = (0..9)
            .map(|i| server.submit_to(i % 2, vec![0; 8]).unwrap())
            .collect();
        // shutdown drains everything already admitted
        let report = server.shutdown();
        assert_eq!(report.served, 9);
        for t in tickets {
            assert!(t.wait().is_some());
        }
    }

    #[test]
    fn partial_batches_flush_on_deadline() {
        let (execs, _log) = logging_fleet(1, 8, 4, Duration::ZERO);
        let cfg = ServeConfig {
            max_wait_ms: 10,
            ..Default::default()
        };
        let server = Server::start(&cfg, one_hot_router(1), execs);
        // 3 docs never fill the 8-row batch; only the deadline flushes them.
        let tickets: Vec<Ticket> =
            (0..3).map(|_| server.submit_to(0, vec![0; 4]).unwrap()).collect();
        for t in tickets {
            let r = t.wait().expect("deadline flush");
            assert!(r.batch_fill <= 3);
        }
        let report = server.shutdown();
        assert_eq!(report.served, 3);
        assert!(report.mean_batch_fill <= 3.0);
    }
}
