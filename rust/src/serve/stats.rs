//! Shared serving telemetry: per-request latency, per-path load and queue
//! depth, micro-batch occupancy, throughput, and the self-healing plane's
//! health/redirect/shed/restart counters.
//!
//! One [`ServeStats`] is shared (Arc) between the admission front-end and
//! every path-server worker; recording is a short Mutex critical section.
//! Latency percentiles come from a bounded uniform reservoir (exact until
//! [`LATENCY_RESERVOIR`] samples, unbiased estimates after), sorted once
//! per snapshot; means are exact streaming (Welford) statistics.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::OnlineStats;

/// Latency samples kept for percentile estimation. Beyond this the
/// recorder switches to uniform reservoir sampling (Algorithm R), so
/// memory stays bounded on long-running servers while percentiles remain
/// unbiased estimates over the whole run.
const LATENCY_RESERVOIR: usize = 65_536;

/// Supervisor-maintained health of one path worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathHealth {
    /// Worker is draining its queue normally.
    Healthy,
    /// Worker panicked and is in its restart backoff.
    Restarting,
    /// Restart budget exhausted; the queue was drained with errors and
    /// admission no longer routes here.
    Down,
}

impl PathHealth {
    pub fn as_str(&self) -> &'static str {
        match self {
            PathHealth::Healthy => "healthy",
            PathHealth::Restarting => "restarting",
            PathHealth::Down => "down",
        }
    }
}

#[derive(Debug, Default, Clone)]
struct PathCounters {
    served: u64,
    rejected: u64,
    batches: u64,
    exec_errors: u64,
    /// Requests resolved with a ServeError by the worker/supervisor
    /// (executor failure, panic, path down) — loud, never hung.
    failed: u64,
    /// Requests routed here as primary but redirected AWAY because this
    /// path's breaker refused them.
    redirected: u64,
    /// Redirected requests dropped because this primary path's fallbacks
    /// could not take them within the shed deadline.
    shed: u64,
    /// Worker panics caught by the supervisor.
    panics: u64,
    /// Supervisor restarts completed (panics that came back).
    restarts: u64,
    max_depth: usize,
}

#[derive(Debug, Default)]
struct StatsInner {
    per_path: Vec<PathCounters>,
    health: Vec<PathHealth>,
    latencies_ms: Vec<f64>,
    /// Total latency samples seen (>= latencies_ms.len() once the
    /// reservoir is full).
    latency_seen: u64,
    /// xorshift64* state for reservoir replacement.
    rng_state: u64,
    latency: OnlineStats,
    queue_wait_ms: OnlineStats,
    batch_fill: OnlineStats,
    tokens_scored: u64,
}

impl StatsInner {
    /// Algorithm R: keep the first LATENCY_RESERVOIR samples, then
    /// replace a uniformly random slot with probability reservoir/seen.
    fn push_latency(&mut self, x: f64) {
        self.latency_seen += 1;
        if self.latencies_ms.len() < LATENCY_RESERVOIR {
            self.latencies_ms.push(x);
            return;
        }
        // xorshift64* — cheap, statistically fine for sampling slots.
        let mut s = self.rng_state;
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        self.rng_state = s;
        let j = (s.wrapping_mul(0x2545F4914F6CDD1D) >> 1) as usize % self.latency_seen as usize;
        if j < LATENCY_RESERVOIR {
            self.latencies_ms[j] = x;
        }
    }
}

pub struct ServeStats {
    started: Instant,
    inner: Mutex<StatsInner>,
}

impl ServeStats {
    pub fn new(paths: usize) -> Self {
        ServeStats {
            started: Instant::now(),
            inner: Mutex::new(StatsInner {
                per_path: vec![PathCounters::default(); paths],
                health: vec![PathHealth::Healthy; paths],
                latencies_ms: Vec::new(),
                latency_seen: 0,
                rng_state: 0x9E3779B97F4A7C15,
                latency: OnlineStats::new(),
                queue_wait_ms: OnlineStats::new(),
                batch_fill: OnlineStats::new(),
                tokens_scored: 0,
            }),
        }
    }

    /// Admission accepted a request; `depth` is the queue depth after the
    /// push (tracked as a high-water mark per path).
    pub fn record_enqueue(&self, path: usize, depth: usize) {
        let mut g = self.inner.lock().unwrap();
        let c = &mut g.per_path[path];
        c.max_depth = c.max_depth.max(depth);
    }

    /// Admission refused a request (queue full / park timeout).
    pub fn record_reject(&self, path: usize) {
        self.inner.lock().unwrap().per_path[path].rejected += 1;
    }

    /// A worker flushed a micro-batch of `fill` real documents.
    pub fn record_batch(&self, path: usize, fill: usize) {
        let mut g = self.inner.lock().unwrap();
        g.per_path[path].batches += 1;
        g.batch_fill.push(fill as f64);
    }

    /// A worker's forward call failed (error or panic); its documents were
    /// resolved with `ServeError::ExecFailed`.
    pub fn record_exec_error(&self, path: usize) {
        self.inner.lock().unwrap().per_path[path].exec_errors += 1;
    }

    /// `n` admitted requests on `path` were resolved with a ServeError
    /// instead of a score.
    pub fn record_failed(&self, path: usize, n: usize) {
        self.inner.lock().unwrap().per_path[path].failed += n as u64;
    }

    /// Degraded-mode routing moved a request whose primary was `from`
    /// onto fallback path `to`.
    pub fn record_redirect(&self, from: usize, _to: usize) {
        self.inner.lock().unwrap().per_path[from].redirected += 1;
    }

    /// A redirect for primary path `path` found no fallback capacity
    /// within the shed deadline and the request was dropped loudly.
    pub fn record_shed(&self, path: usize) {
        self.inner.lock().unwrap().per_path[path].shed += 1;
    }

    /// The supervisor caught a panic out of `path`'s worker.
    pub fn record_panic(&self, path: usize) {
        self.inner.lock().unwrap().per_path[path].panics += 1;
    }

    /// The supervisor restarted `path`'s worker after backoff.
    pub fn record_restart(&self, path: usize) {
        self.inner.lock().unwrap().per_path[path].restarts += 1;
    }

    /// Supervisor: publish `path`'s health transition.
    pub fn set_health(&self, path: usize, h: PathHealth) {
        self.inner.lock().unwrap().health[path] = h;
    }

    /// Admission: current health of `path` (Down paths are not routable).
    pub fn health(&self, path: usize) -> PathHealth {
        self.inner.lock().unwrap().health[path]
    }

    /// One request completed. `queue_wait_ms` is time spent queued before
    /// its batch was taken; `latency_ms` is end-to-end.
    pub fn record_response(
        &self,
        path: usize,
        latency_ms: f64,
        queue_wait_ms: f64,
        tokens_scored: usize,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.per_path[path].served += 1;
        g.push_latency(latency_ms);
        g.latency.push(latency_ms);
        g.queue_wait_ms.push(queue_wait_ms);
        g.tokens_scored += tokens_scored as u64;
    }

    /// Consistent snapshot of everything recorded so far. The Mutex is
    /// held only to copy out the raw state; the O(n log n) percentile
    /// sort (bounded by `LATENCY_RESERVOIR`) happens after the guard is
    /// dropped, so polling telemetry never stalls the serving threads.
    /// `per_path_breaker` is filled with the breakers' live states by
    /// `Server::report` (the stats object does not own the breakers).
    pub fn snapshot(&self) -> ServeReport {
        let g = self.inner.lock().unwrap();
        let wall_s = self.started.elapsed().as_secs_f64().max(1e-9);
        let per_path = g.per_path.clone();
        let health = g.health.clone();
        let mut lat = g.latencies_ms.clone();
        let tokens_scored = g.tokens_scored;
        let mean_ms = g.latency.mean();
        let mean_queue_wait_ms = g.queue_wait_ms.mean();
        let mean_batch_fill = g.batch_fill.mean();
        drop(g);

        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // interpolated percentile over the pre-sorted reservoir
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            let rank = (p / 100.0) * (lat.len() - 1) as f64;
            let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
            lat[lo] + (rank - lo as f64) * (lat[hi] - lat[lo])
        };
        ServeReport {
            served: per_path.iter().map(|c| c.served).sum(),
            rejected: per_path.iter().map(|c| c.rejected).sum(),
            exec_errors: per_path.iter().map(|c| c.exec_errors).sum(),
            failed: per_path.iter().map(|c| c.failed).sum(),
            redirected: per_path.iter().map(|c| c.redirected).sum(),
            shed: per_path.iter().map(|c| c.shed).sum(),
            panics: per_path.iter().map(|c| c.panics).sum(),
            restarts: per_path.iter().map(|c| c.restarts).sum(),
            batches: per_path.iter().map(|c| c.batches).sum(),
            tokens_scored,
            wall_s,
            tok_per_s: tokens_scored as f64 / wall_s,
            p50_ms: pct(50.0),
            p95_ms: pct(95.0),
            p99_ms: pct(99.0),
            mean_ms,
            mean_queue_wait_ms,
            mean_batch_fill,
            per_path_served: per_path.iter().map(|c| c.served).collect(),
            per_path_rejected: per_path.iter().map(|c| c.rejected).collect(),
            per_path_exec_errors: per_path.iter().map(|c| c.exec_errors).collect(),
            per_path_redirected: per_path.iter().map(|c| c.redirected).collect(),
            per_path_max_depth: per_path.iter().map(|c| c.max_depth).collect(),
            per_path_health: health,
            per_path_breaker: vec!["closed".into(); per_path.len()],
            per_path_trips: vec![0; per_path.len()],
        }
    }
}

/// Snapshot of serving telemetry (everything the CLI/bench reports).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub served: u64,
    pub rejected: u64,
    pub exec_errors: u64,
    /// Admitted requests resolved with an error (never hung).
    pub failed: u64,
    /// Requests redirected to a fallback path by degraded-mode routing.
    pub redirected: u64,
    /// Requests shed because no fallback had capacity in time.
    pub shed: u64,
    /// Worker panics caught by supervisors.
    pub panics: u64,
    /// Worker restarts completed by supervisors.
    pub restarts: u64,
    pub batches: u64,
    pub tokens_scored: u64,
    pub wall_s: f64,
    pub tok_per_s: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub mean_queue_wait_ms: f64,
    pub mean_batch_fill: f64,
    pub per_path_served: Vec<u64>,
    pub per_path_rejected: Vec<u64>,
    pub per_path_exec_errors: Vec<u64>,
    pub per_path_redirected: Vec<u64>,
    pub per_path_max_depth: Vec<usize>,
    pub per_path_health: Vec<PathHealth>,
    /// Live breaker state per path ("closed" / "open" / "half-open");
    /// filled by `Server::report`.
    pub per_path_breaker: Vec<String>,
    /// Lifetime breaker trips per path; filled by `Server::report`.
    pub per_path_trips: Vec<u64>,
}

impl ServeReport {
    /// Rows for `metrics::print_table` (["metric", "value"] header).
    pub fn rows(&self) -> Vec<Vec<String>> {
        vec![
            vec!["requests served".into(), self.served.to_string()],
            vec!["requests rejected".into(), self.rejected.to_string()],
            vec!["requests failed loudly".into(), self.failed.to_string()],
            vec!["requests redirected".into(), self.redirected.to_string()],
            vec!["requests shed".into(), self.shed.to_string()],
            vec![
                "worker panics/restarts".into(),
                format!("{}/{}", self.panics, self.restarts),
            ],
            vec!["micro-batches".into(), self.batches.to_string()],
            vec!["mean batch fill".into(), format!("{:.2}", self.mean_batch_fill)],
            vec!["latency p50".into(), format!("{:.2} ms", self.p50_ms)],
            vec!["latency p95".into(), format!("{:.2} ms", self.p95_ms)],
            vec!["latency p99".into(), format!("{:.2} ms", self.p99_ms)],
            vec!["latency mean".into(), format!("{:.2} ms", self.mean_ms)],
            vec![
                "queue wait mean".into(),
                format!("{:.2} ms", self.mean_queue_wait_ms),
            ],
            vec!["throughput".into(), format!("{:.0} tok/s", self.tok_per_s)],
            vec!["per-path load".into(), format!("{:?}", self.per_path_served)],
            vec![
                "per-path rejects".into(),
                format!("{:?}", self.per_path_rejected),
            ],
            vec![
                "per-path max depth".into(),
                format!("{:?}", self.per_path_max_depth),
            ],
            vec![
                "per-path health".into(),
                format!(
                    "{:?}",
                    self.per_path_health.iter().map(|h| h.as_str()).collect::<Vec<_>>()
                ),
            ],
            vec![
                "per-path breaker".into(),
                format!("{:?}", self.per_path_breaker),
            ],
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_does_not_panic() {
        let s = ServeStats::new(4);
        let r = s.snapshot();
        assert_eq!(r.served, 0);
        assert_eq!(r.p50_ms, 0.0);
        assert_eq!(r.per_path_served, vec![0, 0, 0, 0]);
        assert_eq!(r.per_path_health, vec![PathHealth::Healthy; 4]);
        assert!(!r.rows().is_empty());
    }

    #[test]
    fn percentiles_ordered_and_counts_add_up() {
        let s = ServeStats::new(2);
        for i in 0..100 {
            let path = i % 2;
            s.record_enqueue(path, i % 7);
            s.record_response(path, (i + 1) as f64, 0.5, 10);
        }
        s.record_reject(1);
        s.record_batch(0, 3);
        let r = s.snapshot();
        assert_eq!(r.served, 100);
        assert_eq!(r.per_path_served, vec![50, 50]);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.per_path_rejected, vec![0, 1]);
        assert_eq!(r.tokens_scored, 1000);
        assert!(r.p50_ms <= r.p95_ms && r.p95_ms <= r.p99_ms);
        assert!(r.p99_ms <= 100.0);
        assert!(r.tok_per_s > 0.0);
        assert_eq!(r.per_path_max_depth[0], 6);
        assert!((r.mean_batch_fill - 3.0).abs() < 1e-12);
    }

    #[test]
    fn self_healing_counters_roll_up() {
        let s = ServeStats::new(3);
        s.record_redirect(0, 1);
        s.record_redirect(0, 2);
        s.record_shed(0);
        s.record_panic(1);
        s.record_panic(1);
        s.record_restart(1);
        s.record_failed(1, 4);
        s.record_exec_error(1);
        s.set_health(1, PathHealth::Restarting);
        s.set_health(2, PathHealth::Down);
        assert_eq!(s.health(1), PathHealth::Restarting);
        let r = s.snapshot();
        assert_eq!(r.redirected, 2);
        assert_eq!(r.per_path_redirected, vec![2, 0, 0]);
        assert_eq!(r.shed, 1);
        assert_eq!(r.panics, 2);
        assert_eq!(r.restarts, 1);
        assert_eq!(r.failed, 4);
        assert_eq!(r.per_path_exec_errors, vec![0, 1, 0]);
        assert_eq!(
            r.per_path_health,
            vec![PathHealth::Healthy, PathHealth::Restarting, PathHealth::Down]
        );
        assert!(!r.rows().is_empty());
    }

    #[test]
    fn latency_reservoir_stays_bounded_with_exact_mean() {
        let s = ServeStats::new(1);
        let n = LATENCY_RESERVOIR + 10_000;
        for i in 0..n {
            s.record_response(0, (i % 1000) as f64, 0.0, 1);
        }
        let g = s.inner.lock().unwrap();
        assert_eq!(g.latencies_ms.len(), LATENCY_RESERVOIR);
        assert_eq!(g.latency_seen, n as u64);
        drop(g);
        let r = s.snapshot();
        assert_eq!(r.served, n as u64);
        // mean is exact (streaming; ~497.9 because n is not a multiple of
        // the 0..999 cycle), percentiles sampled but in-range
        assert!((r.mean_ms - 497.85).abs() < 0.1, "mean {}", r.mean_ms);
        assert!(r.p50_ms >= 0.0 && r.p99_ms <= 999.0);
        assert!(r.p50_ms <= r.p95_ms && r.p95_ms <= r.p99_ms);
    }
}
