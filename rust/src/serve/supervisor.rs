//! Path-worker supervision: every path server runs its drain loop under
//! `catch_unwind` and is restarted with capped exponential backoff when
//! its executor panics.
//!
//! The supervision contract (the serving counterpart of the coordinator's
//! monitor/respawn loop) is: **an admitted ticket always resolves.**
//!
//! * A batch whose forward call returns an error or panics resolves every
//!   ticket in it with `Err(ServeError::ExecFailed)` — the panic is caught
//!   at the forward-call boundary while the worker still owns the batch,
//!   so no waiter can be stranded by an unwinding executor.
//! * After a panic the supervisor marks the path `Restarting`, sleeps the
//!   backoff (doubling per consecutive panic, capped), records the restart
//!   and re-enters the drain loop with the same executor. Any successful
//!   batch resets the backoff ladder.
//! * With `max_consecutive_panics > 0`, a worker that keeps panicking
//!   with no successful batch in between is declared `Down`: its queue is
//!   closed and drained, resolving every queued ticket with
//!   `Err(ServeError::WorkerDown)`, and admission stops routing to it.
//!
//! Health transitions are published through [`ServeStats`] so admission
//! (degraded-mode routing) and telemetry see them; batch outcomes are
//! reported to the path's [`CircuitBreaker`] so error bursts and latency
//! spikes trip it even when nothing panics.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::SupervisorConfig;
use crate::serve::batcher::{pad_batch_into, BoundedQueue};
use crate::serve::breaker::CircuitBreaker;
use crate::serve::request::{ServeError, ServeRequest, ServeResponse};
use crate::serve::server::PathExecutor;
use crate::serve::stats::{PathHealth, ServeStats};
use crate::warn_;

/// Why one incarnation of the drain loop ended.
enum DrainExit {
    /// Queue closed and drained — normal shutdown.
    Drained,
    /// The executor panicked on a batch (already resolved with errors).
    /// `after_success` is true when this incarnation completed at least
    /// one batch first, which resets the supervisor's panic budget.
    Panicked { after_success: bool },
}

/// Run one path worker under supervision until its queue is closed and
/// drained, or the restart budget is exhausted. This is the closure body
/// `Server::start` schedules on the thread pool; it must never unwind
/// (the pool's `join` treats a panicked worker as fatal).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_supervised<E: PathExecutor>(
    path: usize,
    mut exec: E,
    queue: Arc<BoundedQueue<ServeRequest>>,
    stats: Arc<ServeStats>,
    breaker: Arc<CircuitBreaker>,
    sup: SupervisorConfig,
    max_batch: usize,
    max_wait: Duration,
    idle: Duration,
) {
    let initial = Duration::from_millis(sup.backoff_ms.max(1));
    let cap = Duration::from_millis(sup.backoff_max_ms.max(sup.backoff_ms).max(1));
    let mut backoff = initial;
    let mut consecutive = 0usize;
    loop {
        // Outer guard: defense in depth for panics outside the forward
        // boundary (batcher/stats bugs) — the worker thread itself must
        // survive anything.
        let exit = catch_unwind(AssertUnwindSafe(|| {
            drain_loop(
                path, &mut exec, &queue, &stats, &breaker, max_batch, max_wait, idle,
            )
        }));
        match exit {
            Ok(DrainExit::Drained) => return,
            Ok(DrainExit::Panicked { after_success }) => {
                if after_success {
                    consecutive = 0;
                    backoff = initial;
                }
            }
            // Panic outside the forward guard: nothing is known about
            // progress, so the panic budget keeps counting up.
            Err(_) => {}
        }
        consecutive += 1;
        stats.record_panic(path);
        if sup.max_consecutive_panics > 0 && consecutive >= sup.max_consecutive_panics {
            warn_!(
                "serve",
                "path {path} worker DOWN after {consecutive} consecutive panics; draining queue with errors"
            );
            stats.set_health(path, PathHealth::Down);
            fail_remaining(path, &queue, &stats);
            return;
        }
        stats.set_health(path, PathHealth::Restarting);
        std::thread::sleep(backoff);
        backoff = (backoff * 2).min(cap);
        stats.record_restart(path);
        stats.set_health(path, PathHealth::Healthy);
    }
}

/// One incarnation of the drain loop. Panics from `exec.forward` are
/// caught HERE, while this frame still owns the batch, so every ticket in
/// a panicked batch resolves with `ExecFailed` before the worker unwinds
/// to the supervisor.
#[allow(clippy::too_many_arguments)]
fn drain_loop<E: PathExecutor>(
    path: usize,
    exec: &mut E,
    queue: &BoundedQueue<ServeRequest>,
    stats: &ServeStats,
    breaker: &CircuitBreaker,
    max_batch: usize,
    max_wait: Duration,
    idle: Duration,
) -> DrainExit {
    let mut after_success = false;
    // Flattened [batch, seq] token buffer, reused across every batch this
    // incarnation drains — steady-state padding allocates nothing.
    let mut toks: Vec<i32> = Vec::new();
    loop {
        let batch = match queue.pop_batch(max_batch, max_wait, idle) {
            None => return DrainExit::Drained,
            Some(b) if b.is_empty() => continue, // idle tick
            Some(b) => b,
        };
        let taken = Instant::now();
        let fill = batch.len();
        let rows: Vec<&[i32]> = batch.iter().map(|r| r.tokens.as_slice()).collect();
        pad_batch_into(&rows, exec.batch(), &mut toks);
        stats.record_batch(path, fill);
        let forwarded = catch_unwind(AssertUnwindSafe(|| exec.forward(&toks, fill)));
        // Batch execution time feeds the breaker's latency trip: a wedged
        // executor that "succeeds" slowly is as sick as a failing one.
        let exec_ms = taken.elapsed().as_secs_f64() * 1e3;
        match forwarded {
            Ok(Ok(scored)) if scored.len() == fill => {
                breaker.record_success(exec_ms);
                after_success = true;
                for (req, (nll, ntok)) in batch.into_iter().zip(scored) {
                    let wait_ms =
                        taken.saturating_duration_since(req.accepted_at).as_secs_f64() * 1e3;
                    let latency_ms = req.accepted_at.elapsed().as_secs_f64() * 1e3;
                    stats.record_response(path, latency_ms, wait_ms, ntok);
                    // A gone client is not a server error; drop silently.
                    let _ = req.tx.send(Ok(ServeResponse {
                        id: req.id,
                        path,
                        nll,
                        tokens_scored: ntok,
                        latency_ms,
                        batch_fill: fill,
                    }));
                }
            }
            Ok(Ok(scored)) => {
                // A short/long result would silently drop tail requests in
                // the zip above — treat it as a batch-level failure.
                warn_!(
                    "serve",
                    "path {path} executor returned {} results for {fill}-doc batch",
                    scored.len()
                );
                fail_batch(path, batch, stats, breaker, exec_ms);
            }
            Ok(Err(e)) => {
                warn_!("serve", "path {path} forward failed on {fill}-doc batch: {e:#}");
                fail_batch(path, batch, stats, breaker, exec_ms);
            }
            Err(_) => {
                warn_!("serve", "path {path} executor PANICKED on {fill}-doc batch");
                fail_batch(path, batch, stats, breaker, exec_ms);
                return DrainExit::Panicked { after_success };
            }
        }
    }
}

/// Resolve every ticket of a failed batch loudly and feed the breaker.
fn fail_batch(
    path: usize,
    batch: Vec<ServeRequest>,
    stats: &ServeStats,
    breaker: &CircuitBreaker,
    exec_ms: f64,
) {
    stats.record_exec_error(path);
    stats.record_failed(path, batch.len());
    breaker.record_failure(exec_ms);
    for req in batch {
        req.fail(ServeError::ExecFailed { path });
    }
}

/// Down-path teardown: close the queue (admission now fails fast) and
/// resolve everything still queued with `WorkerDown`.
fn fail_remaining(path: usize, queue: &BoundedQueue<ServeRequest>, stats: &ServeStats) {
    queue.close();
    while let Some(batch) = queue.pop_batch(64, Duration::ZERO, Duration::ZERO) {
        if batch.is_empty() {
            break;
        }
        stats.record_failed(path, batch.len());
        for req in batch {
            req.fail(ServeError::WorkerDown { path });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BreakerConfig;
    use crate::serve::request::{admit, Ticket};
    use crate::testkit::install_quiet_panic_hook;

    /// Deterministic sick executor: panics its first `panics` forwards,
    /// then errors its next `errors` forwards, then succeeds.
    struct FlakyExec {
        batch: usize,
        seq: usize,
        panics: usize,
        errors: usize,
    }

    impl PathExecutor for FlakyExec {
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq(&self) -> usize {
            self.seq
        }
        fn forward(&mut self, _toks: &[i32], rows: usize) -> anyhow::Result<Vec<(f64, usize)>> {
            if self.panics > 0 {
                self.panics -= 1;
                panic!("chaos-inject: FlakyExec scripted panic");
            }
            if self.errors > 0 {
                self.errors -= 1;
                anyhow::bail!("FlakyExec scripted error");
            }
            Ok((0..rows).map(|_| (1.0, self.seq - 1)).collect())
        }
    }

    /// Queue `n` single-doc requests, close the queue, and run the
    /// supervisor inline (no threads — fully deterministic order).
    fn run_inline(
        exec: FlakyExec,
        n: usize,
        sup: SupervisorConfig,
    ) -> (Vec<Result<ServeResponse, ServeError>>, Arc<ServeStats>) {
        install_quiet_panic_hook();
        let queue = Arc::new(BoundedQueue::new(n.max(1)));
        let stats = Arc::new(ServeStats::new(1));
        let breaker = Arc::new(CircuitBreaker::new(BreakerConfig {
            enabled: false,
            ..Default::default()
        }));
        let seq = exec.seq;
        let tickets: Vec<Ticket> = (0..n)
            .map(|i| {
                let (req, t) = admit(i as u64, 0, vec![0i32; seq]);
                queue.try_push(req).unwrap();
                t
            })
            .collect();
        queue.close();
        run_supervised(
            0,
            exec,
            Arc::clone(&queue),
            Arc::clone(&stats),
            breaker,
            sup,
            1, // one doc per batch: scripted fault sequence maps 1:1 to requests
            Duration::ZERO,
            Duration::ZERO,
        );
        (tickets.into_iter().map(|t| t.wait()).collect(), stats)
    }

    fn fast_sup(max_consecutive_panics: usize) -> SupervisorConfig {
        SupervisorConfig {
            backoff_ms: 1,
            backoff_max_ms: 4,
            max_consecutive_panics,
        }
    }

    #[test]
    fn panicked_batch_resolves_loudly_and_worker_restarts() {
        let exec = FlakyExec { batch: 1, seq: 4, panics: 1, errors: 0 };
        let (results, stats) = run_inline(exec, 3, fast_sup(0));
        assert_eq!(results[0], Err(ServeError::ExecFailed { path: 0 }));
        assert!(results[1].is_ok(), "served after restart: {:?}", results[1]);
        assert!(results[2].is_ok());
        let r = stats.snapshot();
        assert_eq!(r.panics, 1);
        assert_eq!(r.restarts, 1);
        assert_eq!(r.failed, 1);
        assert_eq!(r.served, 2);
        assert_eq!(stats.health(0), PathHealth::Healthy);
    }

    #[test]
    fn exec_error_resolves_every_ticket_without_restart() {
        // Satellite audit: an executor ERROR (not panic) must also resolve
        // its batch with ServeError, and must not burn the restart budget.
        let exec = FlakyExec { batch: 1, seq: 4, panics: 0, errors: 2 };
        let (results, stats) = run_inline(exec, 4, fast_sup(0));
        assert_eq!(results[0], Err(ServeError::ExecFailed { path: 0 }));
        assert_eq!(results[1], Err(ServeError::ExecFailed { path: 0 }));
        assert!(results[2].is_ok() && results[3].is_ok());
        let r = stats.snapshot();
        assert_eq!(r.panics, 0);
        assert_eq!(r.restarts, 0);
        assert_eq!(r.exec_errors, 2);
        assert_eq!(r.failed, 2);
        assert_eq!(r.served, 2);
    }

    #[test]
    fn restart_budget_exhaustion_marks_down_and_drains_queue() {
        let exec = FlakyExec { batch: 1, seq: 4, panics: 99, errors: 0 };
        let (results, stats) = run_inline(exec, 4, fast_sup(2));
        // two panicked batches burn the budget; the rest drain as WorkerDown
        assert_eq!(results[0], Err(ServeError::ExecFailed { path: 0 }));
        assert_eq!(results[1], Err(ServeError::ExecFailed { path: 0 }));
        assert_eq!(results[2], Err(ServeError::WorkerDown { path: 0 }));
        assert_eq!(results[3], Err(ServeError::WorkerDown { path: 0 }));
        let r = stats.snapshot();
        assert_eq!(r.panics, 2);
        assert_eq!(r.restarts, 1, "only the first panic restarts; the second downs");
        assert_eq!(r.failed, 4);
        assert_eq!(stats.health(0), PathHealth::Down);
    }

    #[test]
    fn successful_batch_resets_the_panic_budget() {
        // panic, success, panic, success... with a budget of 2: never Down,
        // because a success intervenes between panics.
        install_quiet_panic_hook();
        struct AlternatingExec {
            calls: usize,
        }
        impl PathExecutor for AlternatingExec {
            fn batch(&self) -> usize {
                1
            }
            fn seq(&self) -> usize {
                4
            }
            fn forward(&mut self, _t: &[i32], rows: usize) -> anyhow::Result<Vec<(f64, usize)>> {
                self.calls += 1;
                if self.calls % 2 == 1 {
                    panic!("chaos-inject: alternating panic");
                }
                Ok((0..rows).map(|_| (1.0, 3)).collect())
            }
        }
        let queue = Arc::new(BoundedQueue::new(8));
        let stats = Arc::new(ServeStats::new(1));
        let breaker = Arc::new(CircuitBreaker::new(BreakerConfig {
            enabled: false,
            ..Default::default()
        }));
        let tickets: Vec<Ticket> = (0..6)
            .map(|i| {
                let (req, t) = admit(i, 0, vec![0i32; 4]);
                queue.try_push(req).unwrap();
                t
            })
            .collect();
        queue.close();
        run_supervised(
            0,
            AlternatingExec { calls: 0 },
            Arc::clone(&queue),
            Arc::clone(&stats),
            breaker,
            fast_sup(2),
            1,
            Duration::ZERO,
            Duration::ZERO,
        );
        let results: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
        // odd calls panic → requests 0,2,4 fail; 1,3,5 serve
        for (i, r) in results.iter().enumerate() {
            if i % 2 == 0 {
                assert_eq!(*r, Err(ServeError::ExecFailed { path: 0 }), "req {i}");
            } else {
                assert!(r.is_ok(), "req {i}: {r:?}");
            }
        }
        let r = stats.snapshot();
        assert_eq!(r.panics, 3);
        assert_eq!(r.restarts, 3, "every panic restarted; budget never hit");
        assert_eq!(stats.health(0), PathHealth::Healthy);
    }
}
