//! Serving request/response types (paper §2.6: each query is routed to
//! ONE path and served by that path's server alone).
//!
//! A request is a single document: its token window plus the path the
//! admission router chose for it. Responses travel back to the submitting
//! client over a per-request mpsc channel wrapped in a [`Ticket`], so the
//! path-server workers never block on slow clients.
//!
//! Every admitted ticket resolves LOUDLY: with a [`ServeResponse`] on
//! success, or a [`ServeError`] when the executor failed/panicked or the
//! path went down. A bare channel disconnect (server torn down without
//! draining) surfaces as `Err(ServeError::Closed)` — a waiter can never
//! distinguish "lost" from "slow" by hanging.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// One admitted document, queued on its assigned path's server.
pub struct ServeRequest {
    pub id: u64,
    /// Token window, exactly `seq` tokens (the admission front-end
    /// validates the length; the batcher only pads whole rows).
    pub tokens: Vec<i32>,
    /// Path chosen for THIS document at admission — the router's choice,
    /// or the runner-up when degraded-mode routing redirected it. Never
    /// inherited from a batch neighbour.
    pub path: usize,
    /// Admission timestamp; end-to-end latency is measured from here.
    pub accepted_at: Instant,
    pub(crate) tx: Sender<Result<ServeResponse, ServeError>>,
}

impl ServeRequest {
    /// Resolve this ticket with an error (executor failure, path down).
    /// A gone client is not a server error; the send result is dropped.
    pub(crate) fn fail(self, err: ServeError) {
        let _ = self.tx.send(Err(err));
    }
}

/// Scoring result for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeResponse {
    pub id: u64,
    /// Path that actually executed the document.
    pub path: usize,
    /// Summed negative log-likelihood over the scored targets.
    pub nll: f64,
    /// Number of target tokens scored (past the routing prefix).
    pub tokens_scored: usize,
    /// End-to-end latency (admission -> response), milliseconds.
    pub latency_ms: f64,
    /// Real documents that shared the executed micro-batch.
    pub batch_fill: usize,
}

/// Client-side handle for one submitted request.
pub struct Ticket {
    pub id: u64,
    /// Path the request was routed to (known at admission; equals the
    /// responding path).
    pub path: usize,
    rx: Receiver<Result<ServeResponse, ServeError>>,
}

impl Ticket {
    /// Block until the request resolves. Every admitted request resolves:
    /// `Ok` with its score, or `Err` with the loud reason it was not
    /// scored (`ExecFailed`, `WorkerDown`, or `Closed` if the server was
    /// torn down without draining).
    pub fn wait(self) -> Result<ServeResponse, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::Closed))
    }

    /// Bounded wait; `None` means the request has not resolved yet.
    pub fn wait_timeout(&self, d: Duration) -> Option<Result<ServeResponse, ServeError>> {
        self.rx.recv_timeout(d).ok()
    }
}

/// Build the (request, ticket) pair for one admitted document.
pub fn admit(id: u64, path: usize, tokens: Vec<i32>) -> (ServeRequest, Ticket) {
    let (tx, rx) = channel();
    (
        ServeRequest {
            id,
            tokens,
            path,
            accepted_at: Instant::now(),
            tx,
        },
        Ticket { id, path, rx },
    )
}

/// Why admission refused a request, or why an admitted request was not
/// scored.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The assigned path's queue is full (reject-on-full policy), or did
    /// not drain within the admission timeout (block policy).
    Overloaded { path: usize },
    /// The server is shutting down.
    Closed,
    /// Token window has the wrong length for the compiled sequence shape.
    BadRequest { expect: usize, got: usize },
    /// Pre-routed path id with no path server behind it (router and
    /// executor fleet disagree on the path space).
    UnknownPath { path: usize, paths: usize },
    /// The path's circuit breaker is open and no fallback path could take
    /// the request (`path` is the router's primary choice).
    CircuitOpen { path: usize },
    /// The executor failed or panicked on the batch carrying this
    /// request; the supervisor resolved every affected ticket with this.
    ExecFailed { path: usize },
    /// The path's worker exhausted its restart budget; its queue was
    /// drained with this error and admission stopped routing to it.
    WorkerDown { path: usize },
    /// Degraded-mode redirect could not enqueue on the fallback path
    /// within the shed deadline (fallback saturated): load was shed.
    Shed { path: usize },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { path } => write!(f, "path {path} queue overloaded"),
            ServeError::Closed => write!(f, "server closed"),
            ServeError::BadRequest { expect, got } => {
                write!(f, "token window length {got} != compiled seq {expect}")
            }
            ServeError::UnknownPath { path, paths } => {
                write!(f, "path {path} has no server (serving {paths} paths)")
            }
            ServeError::CircuitOpen { path } => {
                write!(f, "path {path} circuit open and no fallback available")
            }
            ServeError::ExecFailed { path } => {
                write!(f, "path {path} executor failed on this batch")
            }
            ServeError::WorkerDown { path } => {
                write!(f, "path {path} worker down (restart budget exhausted)")
            }
            ServeError::Shed { path } => {
                write!(f, "redirected load shed: fallback path {path} saturated")
            }
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_roundtrip() {
        let (req, ticket) = admit(7, 2, vec![1, 2, 3]);
        assert_eq!(ticket.id, 7);
        assert_eq!(ticket.path, 2);
        req.tx
            .send(Ok(ServeResponse {
                id: req.id,
                path: req.path,
                nll: 1.5,
                tokens_scored: 3,
                latency_ms: 0.1,
                batch_fill: 1,
            }))
            .unwrap();
        let resp = ticket.wait().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.path, 2);
        assert!((resp.nll - 1.5).abs() < 1e-12);
    }

    #[test]
    fn dropped_request_resolves_closed_not_hung() {
        let (req, ticket) = admit(1, 0, vec![]);
        drop(req); // server torn down before scoring
        assert_eq!(ticket.wait(), Err(ServeError::Closed));
    }

    #[test]
    fn failed_request_carries_its_error() {
        let (req, ticket) = admit(2, 3, vec![]);
        req.fail(ServeError::ExecFailed { path: 3 });
        assert_eq!(ticket.wait(), Err(ServeError::ExecFailed { path: 3 }));
    }

    #[test]
    fn wait_timeout_none_means_pending() {
        let (req, ticket) = admit(4, 0, vec![]);
        assert!(ticket.wait_timeout(Duration::from_millis(5)).is_none());
        req.fail(ServeError::WorkerDown { path: 0 });
        assert_eq!(
            ticket.wait_timeout(Duration::from_millis(100)),
            Some(Err(ServeError::WorkerDown { path: 0 }))
        );
    }

    #[test]
    fn error_display() {
        assert_eq!(
            ServeError::Overloaded { path: 3 }.to_string(),
            "path 3 queue overloaded"
        );
        assert_eq!(
            ServeError::BadRequest { expect: 8, got: 4 }.to_string(),
            "token window length 4 != compiled seq 8"
        );
        assert_eq!(
            ServeError::CircuitOpen { path: 1 }.to_string(),
            "path 1 circuit open and no fallback available"
        );
        assert_eq!(
            ServeError::ExecFailed { path: 2 }.to_string(),
            "path 2 executor failed on this batch"
        );
        assert_eq!(
            ServeError::WorkerDown { path: 5 }.to_string(),
            "path 5 worker down (restart budget exhausted)"
        );
        assert_eq!(
            ServeError::Shed { path: 4 }.to_string(),
            "redirected load shed: fallback path 4 saturated"
        );
    }
}
