//! Serving request/response types (paper §2.6: each query is routed to
//! ONE path and served by that path's server alone).
//!
//! A request is a single document: its token window plus the path the
//! admission router chose for it. Responses travel back to the submitting
//! client over a per-request mpsc channel wrapped in a [`Ticket`], so the
//! path-server workers never block on slow clients.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// One admitted document, queued on its assigned path's server.
pub struct ServeRequest {
    pub id: u64,
    /// Token window, exactly `seq` tokens (the admission front-end
    /// validates the length; the batcher only pads whole rows).
    pub tokens: Vec<i32>,
    /// Path chosen for THIS document by `router::assign` at admission —
    /// never inherited from a batch neighbour.
    pub path: usize,
    /// Admission timestamp; end-to-end latency is measured from here.
    pub accepted_at: Instant,
    pub(crate) tx: Sender<ServeResponse>,
}

/// Scoring result for one request.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: u64,
    /// Path that actually executed the document.
    pub path: usize,
    /// Summed negative log-likelihood over the scored targets.
    pub nll: f64,
    /// Number of target tokens scored (past the routing prefix).
    pub tokens_scored: usize,
    /// End-to-end latency (admission -> response), milliseconds.
    pub latency_ms: f64,
    /// Real documents that shared the executed micro-batch.
    pub batch_fill: usize,
}

/// Client-side handle for one submitted request.
pub struct Ticket {
    pub id: u64,
    /// Path the request was routed to (known at admission).
    pub path: usize,
    rx: Receiver<ServeResponse>,
}

impl Ticket {
    /// Block until the response arrives. Returns `None` if the server was
    /// shut down (or its worker failed) before this request was scored.
    pub fn wait(self) -> Option<ServeResponse> {
        self.rx.recv().ok()
    }

    /// Bounded wait.
    pub fn wait_timeout(&self, d: Duration) -> Option<ServeResponse> {
        self.rx.recv_timeout(d).ok()
    }
}

/// Build the (request, ticket) pair for one admitted document.
pub fn admit(id: u64, path: usize, tokens: Vec<i32>) -> (ServeRequest, Ticket) {
    let (tx, rx) = channel();
    (
        ServeRequest {
            id,
            tokens,
            path,
            accepted_at: Instant::now(),
            tx,
        },
        Ticket { id, path, rx },
    )
}

/// Why admission refused a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The assigned path's queue is full (reject-on-full policy), or did
    /// not drain within the admission timeout (block policy).
    Overloaded { path: usize },
    /// The server is shutting down.
    Closed,
    /// Token window has the wrong length for the compiled sequence shape.
    BadRequest { expect: usize, got: usize },
    /// Pre-routed path id with no path server behind it (router and
    /// executor fleet disagree on the path space).
    UnknownPath { path: usize, paths: usize },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { path } => write!(f, "path {path} queue overloaded"),
            ServeError::Closed => write!(f, "server closed"),
            ServeError::BadRequest { expect, got } => {
                write!(f, "token window length {got} != compiled seq {expect}")
            }
            ServeError::UnknownPath { path, paths } => {
                write!(f, "path {path} has no server (serving {paths} paths)")
            }
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_roundtrip() {
        let (req, ticket) = admit(7, 2, vec![1, 2, 3]);
        assert_eq!(ticket.id, 7);
        assert_eq!(ticket.path, 2);
        req.tx
            .send(ServeResponse {
                id: req.id,
                path: req.path,
                nll: 1.5,
                tokens_scored: 3,
                latency_ms: 0.1,
                batch_fill: 1,
            })
            .unwrap();
        let resp = ticket.wait().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.path, 2);
        assert!((resp.nll - 1.5).abs() < 1e-12);
    }

    #[test]
    fn dropped_request_yields_none() {
        let (req, ticket) = admit(1, 0, vec![]);
        drop(req); // worker died / server shut down before scoring
        assert!(ticket.wait().is_none());
    }

    #[test]
    fn error_display() {
        assert_eq!(
            ServeError::Overloaded { path: 3 }.to_string(),
            "path 3 queue overloaded"
        );
        assert_eq!(
            ServeError::BadRequest { expect: 8, got: 4 }.to_string(),
            "token window length 4 != compiled seq 8"
        );
    }
}
