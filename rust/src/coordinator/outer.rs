//! Sharded outer-optimization executors (paper §3.3, Figure 7).
//!
//! Modules are sharded across executor threads; each executor subscribes
//! to the checkpoint DB and, **as each path checkpoint arrives** (online
//! parameter-gradient averaging — no waiting for the full phase), extracts
//! the module slices it owns, accumulates `theta(l,e)^{t-1} -
//! theta(l,e)^t_i` weighted by shard size (loss reweighing, §2.7), and
//! once a module has heard from all `P_{l,e}` of its paths applies the
//! Nesterov outer update (Algorithm 1 lines 13-14) with norm rescaling.
//!
//! "As a consequence, the overall model is never materialized in a single
//! location but always split across several servers" — here: each module's
//! global copy lives in exactly one executor's shard of the
//! [`ModuleStore`], and completed-module notifications let the next
//! phase's tasks start before the whole phase finishes averaging.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::config::DilocoConfig;
use crate::coordinator::db::{CheckpointDb, CkptRow};
use crate::optim::{rescale_factor, Nesterov, OuterAccumulator};
use crate::params::checkpoint::Checkpoint;
use crate::topology::{ModuleId, ModuleStore, Topology};

/// Notification that a module finished its outer update for a phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModuleDone {
    pub phase: usize,
    pub module: ModuleId,
}

/// Round-robin module sharding across `executors` (paper Figure 7).
pub fn shard_modules(topo: &Topology, executors: usize) -> Vec<Vec<ModuleId>> {
    let mut shards = vec![Vec::new(); executors.max(1)];
    for (i, m) in topo.all_modules().into_iter().enumerate() {
        shards[i % executors.max(1)].push(m);
    }
    shards
}

/// One executor's phase-scoped state.
struct ExecState {
    acc: HashMap<ModuleId, OuterAccumulator>,
    done: HashMap<ModuleId, bool>,
}

/// Configuration shared by all executors of a run.
pub struct OuterConfig {
    pub diloco: DilocoConfig,
    /// Shard sizes for loss reweighing (index = path id).
    pub shard_sizes: Vec<usize>,
}

/// The executor loop: consumes path-checkpoint rows for `phase`, returns
/// when all owned modules are updated. Designed to be run on a thread per
/// executor shard.
#[allow(clippy::too_many_arguments)]
pub fn executor_loop(
    topo: &Topology,
    store: &Mutex<ModuleStore>,
    opt: &mut Nesterov,
    owned: &[ModuleId],
    cfg: &OuterConfig,
    phase: usize,
    rx: &Receiver<CkptRow>,
    done_tx: &Sender<ModuleDone>,
) -> Result<()> {
    if owned.is_empty() {
        return Ok(());
    }
    let mut state = ExecState {
        acc: HashMap::new(),
        done: owned.iter().map(|&m| (m, false)).collect(),
    };
    // Modules with zero expected contributions can't occur: every module
    // has P_le >= 1 paths by construction.
    let mut remaining = owned.len();
    while remaining > 0 {
        let row = rx.recv().context("db notification channel closed")?;
        if row.kind != "path" || row.phase != phase {
            continue;
        }
        let ck = Checkpoint::load(&row.file)
            .with_context(|| format!("executor loading {}", row.file.display()))?;
        let theta_after = ck.get("theta").context("ckpt missing theta")?;
        let w = if cfg.diloco.loss_reweigh {
            cfg.shard_sizes.get(row.path_id).copied().unwrap_or(1).max(1) as f64
        } else {
            1.0
        };
        let path_modules = topo.modules_of_path(row.path_id);
        for m in path_modules {
            if !state.done.contains_key(&m) || state.done[&m] {
                continue;
            }
            let after = topo.extract(m.level, theta_after);
            let (delta, expected) = {
                let store_g = store.lock().unwrap();
                let before = store_g.get(m);
                let delta: Vec<f32> =
                    before.iter().zip(&after).map(|(b, a)| b - a).collect();
                (delta, topo.paths_through(m))
            };
            let acc = state
                .acc
                .entry(m)
                .or_insert_with(|| OuterAccumulator::new(delta.len()));
            acc.add(&delta, w);
            if acc.contributions() == expected {
                let mut g = acc.average();
                let scale = rescale_factor(topo, m, cfg.diloco.norm_rescale);
                if scale != 1.0 {
                    g.iter_mut().for_each(|x| *x *= scale);
                }
                {
                    let mut store_g = store.lock().unwrap();
                    opt.step(m, store_g.get_mut(m), &g);
                }
                state.done.insert(m, true);
                remaining -= 1;
                let _ = done_tx.send(ModuleDone { phase, module: m });
            }
        }
    }
    Ok(())
}

/// Run one phase's outer optimization with `executors` sharded executor
/// threads, consuming checkpoints as they appear in `db`. Blocks until
/// every module is updated; returns the number of modules updated.
///
/// `opts` carries each executor's persistent Nesterov state across phases
/// (velocity must survive phase boundaries).
#[allow(clippy::too_many_arguments)]
pub fn run_phase_outer(
    topo: &Arc<Topology>,
    store: &Arc<Mutex<ModuleStore>>,
    opts: &mut [Nesterov],
    shards: &[Vec<ModuleId>],
    cfg: &OuterConfig,
    phase: usize,
    db: &Arc<CheckpointDb>,
    done_tx: &Sender<ModuleDone>,
) -> Result<usize> {
    // Subscribe before replaying existing rows so nothing is missed.
    let subs: Vec<Receiver<CkptRow>> = shards
        .iter()
        .map(|_| {
            let (tx, rx) = channel();
            db.subscribe(tx.clone());
            // replay rows already present (tasks that finished early)
            for row in db.rows_since(0) {
                let _ = tx.send(row);
            }
            rx
        })
        .collect();
    let total: usize = shards.iter().map(|s| s.len()).sum();
    std::thread::scope(|s| -> Result<()> {
        let mut joins = Vec::new();
        for ((owned, rx), opt) in shards.iter().zip(subs.into_iter()).zip(opts.iter_mut()) {
            let topo = Arc::clone(topo);
            let store = Arc::clone(store);
            let done_tx = done_tx.clone();
            joins.push(s.spawn(move || {
                executor_loop(&topo, &store, opt, owned, cfg, phase, &rx, &done_tx)
            }));
        }
        for j in joins {
            j.join().expect("executor panicked")?;
        }
        Ok(())
    })?;
    Ok(total)
}

/// Naive (non-sharded, non-online) outer update used as the §3.3 baseline
/// in benches: wait for ALL checkpoints, then average and update serially.
pub fn naive_phase_outer(
    topo: &Topology,
    store: &Mutex<ModuleStore>,
    opt: &mut Nesterov,
    cfg: &OuterConfig,
    phase: usize,
    db: &CheckpointDb,
) -> Result<usize> {
    // gather everything first (the inefficiency under test)
    let rows = db.query(phase, "path");
    let ckpts: Vec<(usize, Checkpoint)> = rows
        .iter()
        .map(|r| Ok((r.path_id, Checkpoint::load(&r.file)?)))
        .collect::<Result<_>>()?;
    let mut n = 0;
    for m in topo.all_modules() {
        let mut acc = OuterAccumulator::new(topo.levels[m.level].size);
        for (path_id, ck) in &ckpts {
            if topo.expert_of(*path_id, m.level) != m.expert {
                continue;
            }
            let theta_after = ck.get("theta").context("theta")?;
            let after = topo.extract(m.level, theta_after);
            let store_g = store.lock().unwrap();
            let before = store_g.get(m);
            let delta: Vec<f32> = before.iter().zip(&after).map(|(b, a)| b - a).collect();
            drop(store_g);
            let w = if cfg.diloco.loss_reweigh {
                cfg.shard_sizes.get(*path_id).copied().unwrap_or(1).max(1) as f64
            } else {
                1.0
            };
            acc.add(&delta, w);
        }
        if acc.contributions() == 0 {
            continue;
        }
        let mut g = acc.average();
        let scale = rescale_factor(topo, m, cfg.diloco.norm_rescale);
        g.iter_mut().for_each(|x| *x *= scale);
        let mut store_g = store.lock().unwrap();
        opt.step(m, store_g.get_mut(m), &g);
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologySpec;
    use crate::params::manifest::Manifest;
    use crate::util::json::Json;

    fn setup() -> (Arc<Topology>, Arc<Mutex<ModuleStore>>, Vec<f32>) {
        let j = crate::params::manifest::tests::fake_manifest_json(4, 8);
        let man = Manifest::from_json(&Json::parse(&j).unwrap()).unwrap();
        let topo = Arc::new(Topology::build(&man, &TopologySpec::grid(vec![2, 2])));
        let theta: Vec<f32> = (0..man.total_params).map(|i| (i % 97) as f32 * 0.01).collect();
        let store = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        (topo, store, theta)
    }

    fn save_path_ckpt(dir: &std::path::Path, phase: usize, path: usize, theta: Vec<f32>) -> CkptRow {
        let file = dir.join(format!("p{phase}-path{path}.dpc"));
        Checkpoint::new().with("theta", theta).save(&file).unwrap();
        CkptRow {
            rowid: 0,
            phase,
            path_id: path,
            kind: "path".into(),
            file,
            step: 0,
            loss: 1.0,
        }
    }

    #[test]
    fn sharding_covers_all_modules() {
        let (topo, _, _) = setup();
        let shards = shard_modules(&topo, 3);
        let mut all: Vec<ModuleId> = shards.concat();
        all.sort();
        let mut expect = topo.all_modules();
        expect.sort();
        assert_eq!(all, expect);
    }

    #[test]
    fn online_sharded_matches_naive() {
        // Both implementations must produce identical module stores.
        let (topo, store_a, theta) = setup();
        let store_b = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        let dir = std::env::temp_dir().join(format!("dipaco-outer-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        // fake per-path results: theta + path-dependent perturbation
        let db = Arc::new(CheckpointDb::new());
        let mut rows = Vec::new();
        for p in 0..topo.paths {
            let after: Vec<f32> = theta
                .iter()
                .enumerate()
                .map(|(i, &v)| v + 0.001 * (p as f32 + 1.0) * ((i % 7) as f32 - 3.0))
                .collect();
            rows.push(save_path_ckpt(&dir, 0, p, after));
        }
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![10, 20, 30, 40],
        };

        // naive on store_b
        let dbb = CheckpointDb::new();
        for r in &rows {
            dbb.insert(r.clone());
        }
        let mut opt_b = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        naive_phase_outer(&topo, &store_b, &mut opt_b, &cfg, 0, &dbb).unwrap();

        // online sharded on store_a — rows inserted concurrently
        let shards = shard_modules(&topo, 2);
        let mut opts: Vec<Nesterov> = (0..2)
            .map(|_| Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum))
            .collect();
        let (done_tx, done_rx) = channel();
        let db2 = Arc::clone(&db);
        let rows2 = rows.clone();
        let feeder = std::thread::spawn(move || {
            for r in rows2 {
                std::thread::sleep(std::time::Duration::from_millis(2));
                db2.insert(r);
            }
        });
        let n = run_phase_outer(&topo, &store_a, &mut opts, &shards, &cfg, 0, &db, &done_tx)
            .unwrap();
        feeder.join().unwrap();
        assert_eq!(n, topo.all_modules().len());
        // every module got a done notification
        let mut dones = 0;
        while done_rx.try_recv().is_ok() {
            dones += 1;
        }
        assert_eq!(dones, n);

        let a = store_a.lock().unwrap();
        let b = store_b.lock().unwrap();
        for m in topo.all_modules() {
            let va = a.get(m);
            let vb = b.get(m);
            for (x, y) in va.iter().zip(vb) {
                assert!((x - y).abs() < 1e-5, "module {m} diverged: {x} vs {y}");
            }
        }
    }

    #[test]
    fn update_moves_toward_worker_params() {
        // With lr>0 and a consistent delta direction, the store moves
        // toward (not away from) the workers' new parameters.
        let (topo, store, theta) = setup();
        let dir = std::env::temp_dir().join(format!("dipaco-outer2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let db = Arc::new(CheckpointDb::new());
        for p in 0..topo.paths {
            // all workers move +0.1 everywhere
            let after: Vec<f32> = theta.iter().map(|&v| v + 0.1).collect();
            db.insert(save_path_ckpt(&dir, 0, p, after));
        }
        let cfg = OuterConfig {
            diloco: DilocoConfig {
                loss_reweigh: false,
                norm_rescale: false,
                ..Default::default()
            },
            shard_sizes: vec![1; topo.paths],
        };
        let shards = shard_modules(&topo, 1);
        let mut opts = vec![Nesterov::new(0.7, 0.9)];
        let (tx, _rx) = channel();
        run_phase_outer(&topo, &store, &mut opts, &shards, &cfg, 0, &db, &tx).unwrap();
        let g = store.lock().unwrap();
        for m in topo.all_modules() {
            let before = topo.extract(m.level, &theta);
            for (x, b) in g.get(m).iter().zip(&before) {
                // delta = before-after = -0.1; nesterov step: p -= lr*(1+mu)*(-0.1) -> +0.133
                assert!(x > b, "module {m} did not move toward workers");
            }
        }
    }
}
