//! Sharded outer-optimization executors (paper §3.3, Figure 7).
//!
//! Modules are sharded across executor threads; each executor subscribes
//! to the checkpoint DB and, **as each path checkpoint arrives** (online
//! parameter-gradient averaging — no waiting for the full phase), fetches
//! **only the `delta:L{l}E{e}` sections of the modules it owns** from the
//! DPC2 file (the worker already shipped `theta^{t-1} - theta^t_i` per
//! module, so no store read is needed to form the outer gradient),
//! accumulates them weighted by shard size (loss reweighing, §2.7), and
//! once a module has heard from all `P_{l,e}` of its paths applies the
//! Nesterov outer update (Algorithm 1 lines 13-14) with norm rescaling.
//!
//! Per-executor I/O is O(bytes of owned modules × paths through them) —
//! not O(total_params × paths) — which is what lets "the overall model
//! [be] never materialized in a single location but always split across
//! several servers": each module's global copy lives in exactly one
//! executor's shard of the [`ModuleStore`], and completed-module
//! notifications let the next phase's tasks start before the whole phase
//! finishes averaging.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::config::DilocoConfig;
use crate::coordinator::db::{CheckpointDb, CkptRow};
use crate::optim::{rescale_factor, Nesterov, OuterAccumulator};
use crate::params::checkpoint::{Checkpoint, SectionReader};
use crate::topology::{ModuleId, ModuleStore, Topology};
use crate::util::pool::{Pool, PooledBuf};

/// Notification that a module finished its outer update for a phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModuleDone {
    pub phase: usize,
    pub module: ModuleId,
}

/// Round-robin module sharding across `executors` (paper Figure 7).
pub fn shard_modules(topo: &Topology, executors: usize) -> Vec<Vec<ModuleId>> {
    let mut shards = vec![Vec::new(); executors.max(1)];
    for (i, m) in topo.all_modules().into_iter().enumerate() {
        shards[i % executors.max(1)].push(m);
    }
    shards
}

/// Shared I/O accounting across a phase's executors: checkpoint sections
/// fetched and their payload bytes. The owned-sections tests and
/// `bench_ckpt` assert on these to prove reads scale with module size,
/// not `total_params`.
#[derive(Debug, Default)]
pub struct OuterIoStats {
    pub sections_read: AtomicU64,
    pub payload_bytes_read: AtomicU64,
}

impl OuterIoStats {
    pub fn snapshot(&self) -> (u64, u64) {
        (
            self.sections_read.load(Ordering::Relaxed),
            self.payload_bytes_read.load(Ordering::Relaxed),
        )
    }
}

/// Configuration shared by all executors of a run.
#[derive(Default)]
pub struct OuterConfig {
    pub diloco: DilocoConfig,
    /// Shard sizes for loss reweighing (index = path id).
    pub shard_sizes: Vec<usize>,
    /// Cross-executor I/O accounting (atomics; shared by reference).
    pub io: OuterIoStats,
    /// Delta-buffer pool shared by the run's executors: steady-state
    /// phases reduce every module without transient allocations.
    pub pool: Arc<Pool<f32>>,
}

/// The executor loop: consumes path-checkpoint rows for `phase`, returns
/// when all owned modules are updated. Designed to be run on a thread per
/// executor shard.
#[allow(clippy::too_many_arguments)]
pub fn executor_loop(
    topo: &Topology,
    store: &Mutex<ModuleStore>,
    opt: &mut Nesterov,
    owned: &[ModuleId],
    cfg: &OuterConfig,
    phase: usize,
    rx: &Receiver<CkptRow>,
    done_tx: &Sender<ModuleDone>,
) -> Result<()> {
    if owned.is_empty() {
        return Ok(());
    }
    // Per-module buffered contributions: (path id, delta, weight). The
    // f32 accumulation in `OuterAccumulator` is order-sensitive, and under
    // faults (retries, stragglers, reordered publication) rows arrive in a
    // run-dependent order — so contributions are buffered and reduced in
    // path-id order once the quorum is complete, making the outer update
    // bit-identical regardless of arrival order. Transient memory is the
    // same O(size x P_le) bytes the accumulator would have read anyway —
    // and the buffers come from (and return to) `cfg.pool`, so after the
    // first phase warms the pool, reduction allocates nothing.
    let mut acc: HashMap<ModuleId, Vec<(usize, PooledBuf<f32>, f64)>> = HashMap::new();
    let mut done: HashMap<ModuleId, bool> = owned.iter().map(|&m| (m, false)).collect();
    // Double-delivery guard: `run_phase_outer` subscribes and then replays
    // existing rows, so a row inserted between the two can arrive twice;
    // accumulating it twice overshoots `expected` and deadlocks the phase.
    let mut seen: HashSet<(usize, usize)> = HashSet::new();
    // Modules with zero expected contributions can't occur: every module
    // has P_le >= 1 paths by construction.
    let mut remaining = owned.len();
    // Quorum-reduction state reused across modules: one accumulator and
    // one averaged-gradient buffer per executor, reset per module.
    let mut racc = OuterAccumulator::new(0);
    let mut g: Vec<f32> = Vec::new();
    while remaining > 0 {
        let row = rx.recv().context("db notification channel closed")?;
        if row.kind != "path" || row.phase != phase {
            continue;
        }
        if !seen.insert((row.phase, row.path_id)) {
            continue; // duplicate delivery of this path's checkpoint
        }
        // Sections we must fetch: owned, unfinished modules this path
        // traverses. The topology decides; the row's `modules` metadata
        // must agree — a path row missing a required section would hang
        // the phase if skipped silently, so fail loudly instead.
        let wanted: Vec<ModuleId> = topo
            .modules_of_path(row.path_id)
            .into_iter()
            .filter(|m| done.get(m) == Some(&false)) // owned and not finished
            .collect();
        if wanted.is_empty() {
            continue; // nothing of ours in this checkpoint — no file I/O
        }
        // Empty metadata = unknown (e.g. a DB reloaded from pre-DPC2
        // state; nothing in the live pipeline produces it) — probe the
        // file and let the section read below error loudly if the file
        // predates the delta-section exchange. Resuming a phase across
        // the format upgrade is not supported; the failure is explicit,
        // never a silent wrong answer.
        if !row.modules.is_empty() {
            if let Some(missing) = wanted.iter().copied().find(|m| !row.modules.contains(m)) {
                anyhow::bail!(
                    "checkpoint row (phase {}, path {}) lacks section metadata for owned \
                     module {missing} — file {}",
                    row.phase,
                    row.path_id,
                    row.file.display()
                );
            }
        }
        let w = if cfg.diloco.loss_reweigh {
            cfg.shard_sizes.get(row.path_id).copied().unwrap_or(1).max(1) as f64
        } else {
            1.0
        };
        // Zero-copy open: sections are checksummed and decoded straight
        // from the mapped file image (buffered fallback inside).
        let mut reader = SectionReader::open_mapped(&row.file)
            .with_context(|| format!("executor opening {}", row.file.display()))?;
        for m in wanted {
            let mut delta = Pool::take(&cfg.pool, 0);
            reader
                .read_into(&m.delta_section(), &mut delta)
                .with_context(|| format!("executor reading {} of {}", m, row.file.display()))?;
            cfg.io.sections_read.fetch_add(1, Ordering::Relaxed);
            let expected = topo.paths_through(m);
            let size = delta.len();
            let buf = acc.entry(m).or_default();
            buf.push((row.path_id, delta, w));
            if buf.len() == expected {
                let mut contribs = acc.remove(&m).unwrap();
                contribs.sort_by_key(|&(p, _, _)| p);
                racc.reset(size);
                for (_, d, cw) in &contribs {
                    racc.add(d, *cw);
                }
                racc.average_into(&mut g);
                let scale = rescale_factor(topo, m, cfg.diloco.norm_rescale);
                if scale != 1.0 {
                    g.iter_mut().for_each(|x| *x *= scale);
                }
                {
                    let mut store_g = store.lock().unwrap();
                    opt.step(m, store_g.get_mut(m), &g);
                }
                done.insert(m, true);
                remaining -= 1;
                let _ = done_tx.send(ModuleDone { phase, module: m });
                // `contribs` drops here, returning its buffers to the pool.
            }
        }
        // The reader's own counter is authoritative: for a legacy DPC1
        // fallback it reports the whole-file read, which a per-section
        // sum would understate.
        cfg.io
            .payload_bytes_read
            .fetch_add(reader.bytes_read(), Ordering::Relaxed);
    }
    Ok(())
}

/// Run one phase's outer optimization with `executors` sharded executor
/// threads, consuming checkpoints as they appear in `db`. Blocks until
/// every module is updated; returns the number of modules updated.
///
/// `opts` carries each executor's persistent Nesterov state across phases
/// (velocity must survive phase boundaries).
#[allow(clippy::too_many_arguments)]
pub fn run_phase_outer(
    topo: &Arc<Topology>,
    store: &Arc<Mutex<ModuleStore>>,
    opts: &mut [Nesterov],
    shards: &[Vec<ModuleId>],
    cfg: &OuterConfig,
    phase: usize,
    db: &Arc<CheckpointDb>,
    done_tx: &Sender<ModuleDone>,
) -> Result<usize> {
    // Subscribe before replaying existing rows so nothing is missed; rows
    // landing in between may be delivered twice, which `executor_loop`
    // dedups by (phase, path). Replaying only this phase's rows keeps the
    // replay O(paths), not O(all rows ever).
    let subs: Vec<Receiver<CkptRow>> = shards
        .iter()
        .map(|_| {
            let (tx, rx) = channel();
            db.subscribe(tx.clone());
            // replay rows already present (tasks that finished early)
            for row in db.query(phase, "path") {
                let _ = tx.send(row);
            }
            rx
        })
        .collect();
    let total: usize = shards.iter().map(|s| s.len()).sum();
    std::thread::scope(|s| -> Result<()> {
        let mut joins = Vec::new();
        for ((owned, rx), opt) in shards.iter().zip(subs.into_iter()).zip(opts.iter_mut()) {
            let topo = Arc::clone(topo);
            let store = Arc::clone(store);
            let done_tx = done_tx.clone();
            joins.push(s.spawn(move || {
                executor_loop(&topo, &store, opt, owned, cfg, phase, &rx, &done_tx)
            }));
        }
        for j in joins {
            j.join().expect("executor panicked")?;
        }
        Ok(())
    })?;
    Ok(total)
}

/// Naive (non-sharded, non-online) outer update used as the §3.3 baseline
/// in benches: wait for ALL checkpoints, load each one IN FULL, then
/// average and update serially.
pub fn naive_phase_outer(
    topo: &Topology,
    store: &Mutex<ModuleStore>,
    opt: &mut Nesterov,
    cfg: &OuterConfig,
    phase: usize,
    db: &CheckpointDb,
) -> Result<usize> {
    // gather everything first (the inefficiency under test)
    let rows = db.query(phase, "path");
    let ckpts: Vec<(CkptRow, Checkpoint)> = rows
        .into_iter()
        .map(|r| {
            let ck = Checkpoint::load(&r.file)?;
            Ok((r, ck))
        })
        .collect::<Result<_>>()?;
    let mut n = 0;
    for m in topo.all_modules() {
        let mut acc = OuterAccumulator::new(topo.levels[m.level].size);
        for (row, ck) in &ckpts {
            // topology decides which paths feed this module; a traversing
            // path's checkpoint missing the section errors loudly below
            if topo.expert_of(row.path_id, m.level) != m.expert {
                continue;
            }
            let delta = ck
                .get(&m.delta_section())
                .with_context(|| format!("ckpt missing section for module {m}"))?;
            let w = if cfg.diloco.loss_reweigh {
                cfg.shard_sizes.get(row.path_id).copied().unwrap_or(1).max(1) as f64
            } else {
                1.0
            };
            acc.add(delta, w);
        }
        if acc.contributions() == 0 {
            continue;
        }
        let mut g = acc.average();
        let scale = rescale_factor(topo, m, cfg.diloco.norm_rescale);
        g.iter_mut().for_each(|x| *x *= scale);
        let mut store_g = store.lock().unwrap();
        opt.step(m, store_g.get_mut(m), &g);
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologySpec;
    use crate::params::manifest::Manifest;
    use crate::util::json::Json;

    fn setup() -> (Arc<Topology>, Arc<Mutex<ModuleStore>>, Vec<f32>) {
        let j = crate::params::manifest::tests::fake_manifest_json(4, 8);
        let man = Manifest::from_json(&Json::parse(&j).unwrap()).unwrap();
        let topo = Arc::new(Topology::build(&man, &TopologySpec::grid(vec![2, 2])));
        let theta: Vec<f32> = (0..man.total_params).map(|i| (i % 97) as f32 * 0.01).collect();
        let store = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        (topo, store, theta)
    }

    /// Worker-style sectioned checkpoint: one delta section per traversed
    /// module (before - after), plus module metadata on the row.
    fn save_path_ckpt(
        dir: &std::path::Path,
        topo: &Topology,
        phase: usize,
        path: usize,
        before: &[f32],
        after: &[f32],
    ) -> CkptRow {
        let file = dir.join(format!("p{phase}-path{path}.dpc"));
        let (ck, modules) = topo.delta_checkpoint(path, before, after);
        ck.with("loss", vec![1.0]).save(&file).unwrap();
        CkptRow {
            rowid: 0,
            phase,
            path_id: path,
            kind: "path".into(),
            file,
            step: 0,
            loss: 1.0,
            modules,
        }
    }

    fn perturbed_after(theta: &[f32], p: usize) -> Vec<f32> {
        theta
            .iter()
            .enumerate()
            .map(|(i, &v)| v + 0.001 * (p as f32 + 1.0) * ((i % 7) as f32 - 3.0))
            .collect()
    }

    #[test]
    fn sharding_covers_all_modules() {
        let (topo, _, _) = setup();
        let shards = shard_modules(&topo, 3);
        let mut all: Vec<ModuleId> = shards.concat();
        all.sort();
        let mut expect = topo.all_modules();
        expect.sort();
        assert_eq!(all, expect);
    }

    #[test]
    fn online_sharded_matches_naive() {
        // Both implementations must produce identical module stores.
        let (topo, store_a, theta) = setup();
        let store_b = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        let dir = std::env::temp_dir().join(format!("dipaco-outer-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        // fake per-path results: theta + path-dependent perturbation
        let db = Arc::new(CheckpointDb::new());
        let mut rows = Vec::new();
        for p in 0..topo.paths {
            let after = perturbed_after(&theta, p);
            rows.push(save_path_ckpt(&dir, &topo, 0, p, &theta, &after));
        }
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![10, 20, 30, 40],
            ..Default::default()
        };

        // naive on store_b
        let dbb = CheckpointDb::new();
        for r in &rows {
            dbb.insert(r.clone());
        }
        let mut opt_b = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        naive_phase_outer(&topo, &store_b, &mut opt_b, &cfg, 0, &dbb).unwrap();

        // online sharded on store_a — rows inserted concurrently
        let shards = shard_modules(&topo, 2);
        let mut opts: Vec<Nesterov> = (0..2)
            .map(|_| Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum))
            .collect();
        let (done_tx, done_rx) = channel();
        let db2 = Arc::clone(&db);
        let rows2 = rows.clone();
        let feeder = std::thread::spawn(move || {
            for r in rows2 {
                std::thread::sleep(std::time::Duration::from_millis(2));
                db2.insert(r);
            }
        });
        let n = run_phase_outer(&topo, &store_a, &mut opts, &shards, &cfg, 0, &db, &done_tx)
            .unwrap();
        feeder.join().unwrap();
        assert_eq!(n, topo.all_modules().len());
        // every module got a done notification
        let mut dones = 0;
        while done_rx.try_recv().is_ok() {
            dones += 1;
        }
        assert_eq!(dones, n);

        let a = store_a.lock().unwrap();
        let b = store_b.lock().unwrap();
        for m in topo.all_modules() {
            let va = a.get(m);
            let vb = b.get(m);
            for (x, y) in va.iter().zip(vb) {
                assert!((x - y).abs() < 1e-5, "module {m} diverged: {x} vs {y}");
            }
        }
    }

    #[test]
    fn update_moves_toward_worker_params() {
        // With lr>0 and a consistent delta direction, the store moves
        // toward (not away from) the workers' new parameters.
        let (topo, store, theta) = setup();
        let dir = std::env::temp_dir().join(format!("dipaco-outer2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let db = Arc::new(CheckpointDb::new());
        for p in 0..topo.paths {
            // all workers move +0.1 everywhere
            let after: Vec<f32> = theta.iter().map(|&v| v + 0.1).collect();
            db.insert(save_path_ckpt(&dir, &topo, 0, p, &theta, &after));
        }
        let cfg = OuterConfig {
            diloco: DilocoConfig {
                loss_reweigh: false,
                norm_rescale: false,
                ..Default::default()
            },
            shard_sizes: vec![1; topo.paths],
            ..Default::default()
        };
        let shards = shard_modules(&topo, 1);
        let mut opts = vec![Nesterov::new(0.7, 0.9)];
        let (tx, _rx) = channel();
        run_phase_outer(&topo, &store, &mut opts, &shards, &cfg, 0, &db, &tx).unwrap();
        let g = store.lock().unwrap();
        for m in topo.all_modules() {
            let before = topo.extract(m.level, &theta);
            for (x, b) in g.get(m).iter().zip(&before) {
                // delta = before-after = -0.1; nesterov step: p -= lr*(1+mu)*(-0.1) -> +0.133
                assert!(x > b, "module {m} did not move toward workers");
            }
        }
    }

    #[test]
    fn duplicate_deliveries_are_deduped() {
        // Regression test for the subscribe/replay double-delivery bug:
        // a row delivered twice must be accumulated ONCE — before the
        // dedup, contributions overshot `expected` and the phase hung.
        let (topo, store, theta) = setup();
        let store_ref = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        let dir = std::env::temp_dir().join(format!("dipaco-outer3-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![10, 20, 30, 40],
            ..Default::default()
        };
        let dbb = CheckpointDb::new();
        let mut rows = Vec::new();
        for p in 0..topo.paths {
            let after = perturbed_after(&theta, p);
            rows.push(save_path_ckpt(&dir, &topo, 0, p, &theta, &after));
        }
        for r in &rows {
            dbb.insert(r.clone());
        }
        let mut opt_ref = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        naive_phase_outer(&topo, &store_ref, &mut opt_ref, &cfg, 0, &dbb).unwrap();

        // one executor owning everything; every row delivered TWICE
        let owned = topo.all_modules();
        let (tx, rx) = channel();
        for r in &rows {
            tx.send(r.clone()).unwrap();
            tx.send(r.clone()).unwrap();
        }
        drop(tx); // a deadlock would surface as a channel-closed error
        let mut opt = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        let (done_tx, _done_rx) = channel();
        executor_loop(&topo, &store, &mut opt, &owned, &cfg, 0, &rx, &done_tx).unwrap();

        let a = store.lock().unwrap();
        let b = store_ref.lock().unwrap();
        for m in topo.all_modules() {
            for (x, y) in a.get(m).iter().zip(b.get(m)) {
                assert!(
                    (x - y).abs() < 1e-6,
                    "module {m} double-accumulated: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn executor_reads_only_owned_sections() {
        // Byte/section accounting: an executor must fetch exactly the
        // sections of modules it owns — O(owned bytes), not O(total).
        let (topo, store, theta) = setup();
        let dir = std::env::temp_dir().join(format!("dipaco-outer4-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let rows: Vec<CkptRow> = (0..topo.paths)
            .map(|p| {
                let after = perturbed_after(&theta, p);
                save_path_ckpt(&dir, &topo, 0, p, &theta, &after)
            })
            .collect();
        let shards = shard_modules(&topo, 2);
        let full_bytes: u64 = rows
            .iter()
            .map(|r| std::fs::metadata(&r.file).unwrap().len())
            .sum();
        let mut total_section_bytes = 0u64;
        for owned in &shards {
            let cfg = OuterConfig {
                diloco: DilocoConfig::default(),
                shard_sizes: vec![1; topo.paths],
                ..Default::default()
            };
            let (tx, rx) = channel();
            for r in &rows {
                tx.send(r.clone()).unwrap();
            }
            let mut opt = Nesterov::new(0.7, 0.9);
            let (done_tx, _done_rx) = channel();
            executor_loop(&topo, &store, &mut opt, owned, &cfg, 0, &rx, &done_tx).unwrap();

            // expected: per row, exactly the owned modules it carries
            let owned_set: std::collections::HashSet<ModuleId> = owned.iter().copied().collect();
            let mut want_sections = 0u64;
            let mut want_bytes = 0u64;
            for r in &rows {
                for m in r.modules.iter().filter(|m| owned_set.contains(*m)) {
                    want_sections += 1;
                    want_bytes += 4 * topo.levels[m.level].size as u64;
                }
            }
            let (sections, bytes) = cfg.io.snapshot();
            assert_eq!(sections, want_sections);
            assert_eq!(bytes, want_bytes);
            // each executor reads strictly less than loading every file
            assert!(
                bytes < full_bytes,
                "owned-section reads ({bytes}) must stay below full loads ({full_bytes})"
            );
            total_section_bytes += bytes;
        }
        // across all shards, every delta payload is read exactly once —
        // the phase total is size(m) x paths_through(m), independent of
        // executor count (the old pipeline scaled with it)
        let want_total: u64 = topo
            .all_modules()
            .iter()
            .map(|&m| 4 * (topo.levels[m.level].size * topo.paths_through(m)) as u64)
            .sum();
        assert_eq!(total_section_bytes, want_total);
        assert!(total_section_bytes < full_bytes);
    }
}
