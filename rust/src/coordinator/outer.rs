//! Sharded outer-optimization executors (paper §3.3, Figure 7).
//!
//! Modules are sharded across executor threads; each executor subscribes
//! to the checkpoint DB and, **as each path checkpoint arrives** (online
//! parameter-gradient averaging — no waiting for the full phase), fetches
//! **only the `delta:L{l}E{e}` sections of the modules it owns** from the
//! DPC2 file (the worker already shipped `theta^{t-1} - theta^t_i` per
//! module, so no store read is needed to form the outer gradient),
//! accumulates them weighted by shard size (loss reweighing, §2.7), and
//! once a module has heard from all `P_{l,e}` of its paths applies the
//! Nesterov outer update (Algorithm 1 lines 13-14) with norm rescaling.
//!
//! Streaming outer sync (DESIGN.md "Streaming outer sync"): workers may
//! publish per-module-group rows (`kind = "path:g{i}"`) as soon as a
//! group's inner steps finish, so reduction overlaps the tail of the
//! inner phase; sections may be quantized under [`DeltaCodec`]; and a
//! straggler grace window ([`OuterConfig::grace`]) lets a module apply
//! eagerly with the contributions that made it — every missing
//! `(path, module)` contribution is *declared late* and handed back so
//! the phase driver can merge it into the NEXT phase's accumulation
//! ([`OuterConfig::carry_in`]) instead of gating this one.
//!
//! Per-executor I/O is O(bytes of owned modules × paths through them) —
//! not O(total_params × paths) — which is what lets "the overall model
//! [be] never materialized in a single location but always split across
//! several servers": each module's global copy lives in exactly one
//! executor's shard of the [`ModuleStore`], and completed-module
//! notifications let the next phase's tasks start before the whole phase
//! finishes averaging.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{DeltaCodec, DilocoConfig};
use crate::coordinator::db::{CheckpointDb, CkptRow};
use crate::optim::{rescale_factor, Nesterov, OuterAccumulator};
use crate::params::checkpoint::{decode_delta_into, Checkpoint};
use crate::topology::{ModuleId, ModuleStore, Topology};
use crate::util::pool::{Pool, PooledBuf};

/// Notification that a module finished its outer update for a phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModuleDone {
    pub phase: usize,
    pub module: ModuleId,
}

/// Round-robin module sharding across `executors` (paper Figure 7).
pub fn shard_modules(topo: &Topology, executors: usize) -> Vec<Vec<ModuleId>> {
    let mut shards = vec![Vec::new(); executors.max(1)];
    for (i, m) in topo.all_modules().into_iter().enumerate() {
        shards[i % executors.max(1)].push(m);
    }
    shards
}

/// Shared I/O accounting across a phase's executors: checkpoint sections
/// fetched and their payload bytes. The owned-sections tests and
/// `bench_ckpt` assert on these to prove reads scale with module size,
/// not `total_params` — so accounting must be exact on every exit path,
/// including mid-row read failures.
#[derive(Debug, Default)]
pub struct OuterIoStats {
    pub sections_read: AtomicU64,
    pub payload_bytes_read: AtomicU64,
}

impl OuterIoStats {
    pub fn snapshot(&self) -> (u64, u64) {
        (
            self.sections_read.load(Ordering::Relaxed),
            self.payload_bytes_read.load(Ordering::Relaxed),
        )
    }
}

/// A straggler's contribution carried from the previous phase: applied to
/// the NEXT phase's accumulation for its module, with the same weight the
/// executor would have used in its own phase. `delta` is already decoded
/// (plain f32), so carry is codec-independent.
#[derive(Debug, Clone)]
pub struct LateContrib {
    pub path: usize,
    pub module: ModuleId,
    pub delta: Vec<f32>,
    pub weight: f64,
}

/// What one phase's outer optimization produced.
#[derive(Debug)]
pub struct OuterPhaseReport {
    /// Modules resolved this phase (every owned module — with or without
    /// an update).
    pub modules_updated: usize,
    /// `(path, module)` contributions that did NOT make this phase's
    /// quorums — declared-late paths plus grace-window timeouts — sorted.
    /// The phase driver collects them (see [`collect_late_contribs`]) and
    /// feeds them into the next phase's [`OuterConfig::carry_in`].
    pub late: Vec<(usize, ModuleId)>,
}

/// Configuration shared by all executors of a run.
#[derive(Default)]
pub struct OuterConfig {
    pub diloco: DilocoConfig,
    /// Shard sizes for loss reweighing (index = path id).
    pub shard_sizes: Vec<usize>,
    /// Cross-executor I/O accounting (atomics; shared by reference).
    pub io: OuterIoStats,
    /// Delta-buffer pool shared by the run's executors: steady-state
    /// phases reduce every module without transient allocations.
    pub pool: Arc<Pool<f32>>,
    /// Wire codec for delta sections (must match what workers encode).
    pub codec: DeltaCodec,
    /// Straggler grace window: once armed, an executor that has not
    /// resolved all owned modules by the deadline applies each unfinished
    /// module with the contributions that arrived and declares the rest
    /// late, instead of blocking the phase forever. `None` = wait
    /// indefinitely (the pre-streaming behavior).
    pub grace: Option<Duration>,
    /// `(phase, path)` pairs declared late up front (chaos scenarios, or
    /// a scheduler that already knows a worker is gone): the path's rows
    /// are skipped in its phase and its contributions are reported late.
    pub declared_late: Vec<(usize, usize)>,
    /// Contributions carried over from the previous phase's stragglers;
    /// each joins its module's quorum as one extra expected contribution.
    pub carry_in: Vec<LateContrib>,
    /// Section exchange plane executors read through. `None` = the local
    /// shared-filesystem plane (map the DPC2 file), byte-identical to
    /// the pre-transport behavior.
    pub transport: Option<Arc<dyn crate::transport::SectionTransport>>,
}

impl OuterConfig {
    fn weight_of(&self, path: usize) -> f64 {
        if self.diloco.loss_reweigh {
            self.shard_sizes.get(path).copied().unwrap_or(1).max(1) as f64
        } else {
            1.0
        }
    }
}

/// One buffered contribution: (path id, carried-from-previous-phase,
/// delta, weight). Reduction sorts by `(path, carried)` so the f32
/// accumulation order is a pure function of the contribution set, never
/// of arrival order.
type Contrib = (usize, bool, PooledBuf<f32>, f64);

/// Apply module `m`'s outer update if its buffered contributions meet
/// `quorum` (normal operation passes the expected count; grace expiry
/// passes whatever arrived). A quorum of zero resolves the module with
/// NO update — it still counts as done and notifies, so the phase can
/// complete when every contribution of a module was declared late.
/// Returns whether the module resolved.
#[allow(clippy::too_many_arguments)]
fn try_finish_module(
    topo: &Topology,
    store: &Mutex<ModuleStore>,
    opt: &mut Nesterov,
    cfg: &OuterConfig,
    phase: usize,
    m: ModuleId,
    quorum: usize,
    acc: &mut HashMap<ModuleId, Vec<Contrib>>,
    racc: &mut OuterAccumulator,
    g: &mut Vec<f32>,
    done: &mut HashMap<ModuleId, bool>,
    remaining: &mut usize,
    done_tx: &Sender<ModuleDone>,
) -> bool {
    if done.get(&m) != Some(&false) {
        return false;
    }
    let have = acc.get(&m).map_or(0, |v| v.len());
    if have < quorum {
        return false;
    }
    if have > 0 {
        let mut contribs = acc.remove(&m).unwrap();
        contribs.sort_by_key(|c| (c.0, c.1));
        let size = contribs[0].2.len();
        racc.reset(size);
        for (_, _, d, cw) in &contribs {
            racc.add(d, *cw);
        }
        racc.average_into(g);
        let scale = rescale_factor(topo, m, cfg.diloco.norm_rescale);
        if scale != 1.0 {
            g.iter_mut().for_each(|x| *x *= scale);
        }
        let mut store_g = store.lock().unwrap();
        opt.step(m, store_g.get_mut(m), g);
        // `contribs` drops here, returning its buffers to the pool.
    }
    done.insert(m, true);
    *remaining -= 1;
    let _ = done_tx.send(ModuleDone { phase, module: m });
    true
}

/// The executor loop: consumes path-checkpoint rows for `phase`, returns
/// when all owned modules are resolved. Designed to be run on a thread
/// per executor shard. Returns the `(path, module)` contributions that
/// missed this phase (declared-late paths and grace-window timeouts).
#[allow(clippy::too_many_arguments)]
pub fn executor_loop(
    topo: &Topology,
    store: &Mutex<ModuleStore>,
    opt: &mut Nesterov,
    owned: &[ModuleId],
    cfg: &OuterConfig,
    phase: usize,
    rx: &Receiver<CkptRow>,
    done_tx: &Sender<ModuleDone>,
) -> Result<Vec<(usize, ModuleId)>> {
    if owned.is_empty() {
        return Ok(Vec::new());
    }
    let late_set: HashSet<usize> = cfg
        .declared_late
        .iter()
        .filter(|&&(ph, _)| ph == phase)
        .map(|&(_, p)| p)
        .collect();
    // Per-module buffered contributions. The f32 accumulation in
    // `OuterAccumulator` is order-sensitive, and under faults (retries,
    // stragglers, reordered publication) rows arrive in a run-dependent
    // order — so contributions are buffered and reduced in (path,
    // carried) order once the quorum is complete, making the outer update
    // bit-identical regardless of arrival order. Transient memory is the
    // same O(size x P_le) bytes the accumulator would have read anyway —
    // and the buffers come from (and return to) `cfg.pool`, so after the
    // first phase warms the pool, reduction allocates nothing.
    let mut acc: HashMap<ModuleId, Vec<Contrib>> = HashMap::new();
    let mut done: HashMap<ModuleId, bool> = owned.iter().map(|&m| (m, false)).collect();
    // Double-delivery guard: `run_phase_outer` subscribes and then replays
    // existing rows, so a row inserted between the two can arrive twice;
    // accumulating it twice overshoots the quorum. Keyed by (path, kind)
    // because a staggered worker legitimately publishes several rows per
    // path — one per module group.
    let mut seen: HashSet<(usize, String)> = HashSet::new();
    // Expected contributions per owned module: its paths, minus the ones
    // declared late for this phase, plus carried-over stragglers.
    let mut expected: HashMap<ModuleId, usize> = HashMap::new();
    let mut late_out: Vec<(usize, ModuleId)> = Vec::new();
    for &m in owned {
        let paths = topo.paths_of_module(m);
        let late_here = paths.iter().filter(|p| late_set.contains(p)).count();
        expected.insert(m, topo.paths_through(m) - late_here);
        // Declared-late contributions are late by fiat, whether or not
        // the reduced quorum completes — the next phase must pick them up.
        for p in paths {
            if late_set.contains(&p) {
                late_out.push((p, m));
            }
        }
    }
    let mut remaining = owned.len();
    // Quorum-reduction state reused across modules: one accumulator and
    // one averaged-gradient buffer per executor, reset per module.
    let mut racc = OuterAccumulator::new(0);
    let mut g: Vec<f32> = Vec::new();
    // Wire scratch: sections decode out of this under `cfg.codec`.
    let mut wire: Vec<f32> = Vec::new();
    // Seed carried-over contributions, then resolve any module whose
    // quorum is already satisfiable (fully-carried, or zero expected
    // after declared-late removal → resolved with no update).
    for c in &cfg.carry_in {
        if done.get(&c.module) != Some(&false) {
            continue; // another shard owns it (or it isn't in this topology)
        }
        let mut buf = Pool::take(&cfg.pool, 0);
        buf.extend_from_slice(&c.delta);
        acc.entry(c.module).or_default().push((c.path, true, buf, c.weight));
        *expected.get_mut(&c.module).unwrap() += 1;
    }
    for &m in owned {
        let q = expected[&m];
        try_finish_module(
            topo, store, opt, cfg, phase, m, q, &mut acc, &mut racc, &mut g, &mut done,
            &mut remaining, done_tx,
        );
    }
    // Deadline armed at loop entry: an executor past it resolves
    // everything it can and declares the rest late.
    let deadline = cfg.grace.map(|g| Instant::now() + g);
    while remaining > 0 {
        let row = if let Some(deadline) = deadline {
            match rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => {
                    for &m in owned {
                        if done.get(&m) != Some(&false) {
                            continue;
                        }
                        // Fresh (non-carried) contributions that arrived;
                        // every other non-declared path of m is timing-late.
                        let fresh: HashSet<usize> = acc
                            .get(&m)
                            .map(|v| v.iter().filter(|c| !c.1).map(|c| c.0).collect())
                            .unwrap_or_default();
                        for p in topo.paths_of_module(m) {
                            if !late_set.contains(&p) && !fresh.contains(&p) {
                                late_out.push((p, m));
                            }
                        }
                        let have = acc.get(&m).map_or(0, |v| v.len());
                        try_finish_module(
                            topo, store, opt, cfg, phase, m, have, &mut acc, &mut racc,
                            &mut g, &mut done, &mut remaining, done_tx,
                        );
                    }
                    break;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("db notification channel closed")
                }
            }
        } else {
            rx.recv().context("db notification channel closed")?
        };
        let streamed = row.kind.starts_with("path:g");
        if (row.kind != "path" && !streamed) || row.phase != phase {
            continue;
        }
        if late_set.contains(&row.path_id) {
            continue; // declared late: merges into the NEXT phase instead
        }
        if !seen.insert((row.path_id, row.kind.clone())) {
            continue; // duplicate delivery of this checkpoint row
        }
        // Sections we must fetch: owned, unfinished modules this row
        // carries. For a streamed group row the row's metadata IS the
        // group (the topology can't know the worker's group split), so
        // empty metadata there is a hard error; for a whole-path row the
        // topology decides and the metadata must agree — a path row
        // missing a required section would hang the phase if skipped
        // silently, so fail loudly instead.
        let wanted: Vec<ModuleId> = if streamed {
            if row.modules.is_empty() {
                anyhow::bail!(
                    "streamed checkpoint row (phase {}, path {}, kind {}) has no module \
                     metadata — file {}",
                    row.phase,
                    row.path_id,
                    row.kind,
                    row.file.display()
                );
            }
            row.modules
                .iter()
                .copied()
                .filter(|m| done.get(m) == Some(&false))
                .collect()
        } else {
            let wanted: Vec<ModuleId> = topo
                .modules_of_path(row.path_id)
                .into_iter()
                .filter(|m| done.get(m) == Some(&false)) // owned and not finished
                .collect();
            // Empty metadata = unknown (e.g. a DB reloaded from pre-DPC2
            // state; nothing in the live pipeline produces it) — probe the
            // file and let the section read below error loudly if the file
            // predates the delta-section exchange. Resuming a phase across
            // the format upgrade is not supported; the failure is explicit,
            // never a silent wrong answer.
            if !row.modules.is_empty() {
                if let Some(missing) = wanted.iter().copied().find(|m| !row.modules.contains(m)) {
                    anyhow::bail!(
                        "checkpoint row (phase {}, path {}) lacks section metadata for owned \
                         module {missing} — file {}",
                        row.phase,
                        row.path_id,
                        row.file.display()
                    );
                }
            }
            wanted
        };
        if wanted.is_empty() {
            continue; // nothing of ours in this checkpoint — no file I/O
        }
        let w = cfg.weight_of(row.path_id);
        // Open through the exchange plane. Local = zero-copy map of the
        // DPC2 file (sections checksummed and decoded straight from the
        // image, buffered fallback inside); TCP = the sections this
        // file's publish pushed to the executors' stores.
        let mut reader = crate::transport::open_source(cfg.transport.as_deref(), &row.file)
            .with_context(|| format!("executor opening {}", row.file.display()))?;
        // A legacy DPC1 fallback reads the whole file at open; count it
        // immediately so no later exit path can lose it. (DPC2 backends
        // report 0 here and accrue per verified section below.)
        cfg.io
            .payload_bytes_read
            .fetch_add(reader.bytes_read(), Ordering::Relaxed);
        for m in wanted {
            // Watermark accounting: take the reader's counter before and
            // after, and record the delta BEFORE propagating any error —
            // a mid-row failure must not lose the bytes already verified.
            let before = reader.bytes_read();
            let res = reader.read_into(&m.delta_section(), &mut wire);
            cfg.io
                .payload_bytes_read
                .fetch_add(reader.bytes_read() - before, Ordering::Relaxed);
            res.with_context(|| format!("executor reading {} of {}", m, row.file.display()))?;
            cfg.io.sections_read.fetch_add(1, Ordering::Relaxed);
            let mut delta = Pool::take(&cfg.pool, 0);
            decode_delta_into(cfg.codec, &wire, &mut delta)
                .with_context(|| format!("executor decoding {} of {}", m, row.file.display()))?;
            acc.entry(m).or_default().push((row.path_id, false, delta, w));
            let q = expected[&m];
            try_finish_module(
                topo, store, opt, cfg, phase, m, q, &mut acc, &mut racc, &mut g, &mut done,
                &mut remaining, done_tx,
            );
        }
    }
    late_out.sort();
    late_out.dedup();
    Ok(late_out)
}

/// Run one phase's outer optimization with `executors` sharded executor
/// threads, consuming checkpoints as they appear in `db`. Blocks until
/// every module is resolved (or the grace window expires); returns the
/// per-phase report including contributions declared late.
///
/// `opts` carries each executor's persistent Nesterov state across phases
/// (velocity must survive phase boundaries).
#[allow(clippy::too_many_arguments)]
pub fn run_phase_outer(
    topo: &Arc<Topology>,
    store: &Arc<Mutex<ModuleStore>>,
    opts: &mut [Nesterov],
    shards: &[Vec<ModuleId>],
    cfg: &OuterConfig,
    phase: usize,
    db: &Arc<CheckpointDb>,
    done_tx: &Sender<ModuleDone>,
) -> Result<OuterPhaseReport> {
    // Subscribe before replaying existing rows so nothing is missed; rows
    // landing in between may be delivered twice, which `executor_loop`
    // dedups by (path, kind). Replaying the "path" prefix picks up both
    // whole-path rows and streamed group rows ("path:g{i}"), but not
    // "eval" rows. Replaying only this phase's rows keeps the replay
    // O(paths), not O(all rows ever).
    let subs: Vec<Receiver<CkptRow>> = shards
        .iter()
        .map(|_| {
            let (tx, rx) = channel();
            db.subscribe(tx.clone());
            // replay rows already present (tasks that finished early)
            for row in db.query_prefix(phase, "path") {
                let _ = tx.send(row);
            }
            rx
        })
        .collect();
    let total: usize = shards.iter().map(|s| s.len()).sum();
    let mut late: Vec<(usize, ModuleId)> = Vec::new();
    std::thread::scope(|s| -> Result<()> {
        let mut joins = Vec::new();
        for ((owned, rx), opt) in shards.iter().zip(subs.into_iter()).zip(opts.iter_mut()) {
            let topo = Arc::clone(topo);
            let store = Arc::clone(store);
            let done_tx = done_tx.clone();
            joins.push(s.spawn(move || {
                executor_loop(&topo, &store, opt, owned, cfg, phase, &rx, &done_tx)
            }));
        }
        for j in joins {
            late.extend(j.join().expect("executor panicked")?);
        }
        Ok(())
    })?;
    // Shards own disjoint modules, so the merged list is already unique;
    // sort so the report is deterministic regardless of shard count.
    late.sort();
    Ok(OuterPhaseReport {
        modules_updated: total,
        late,
    })
}

/// Fetch the deltas a phase declared late, once the phase's rows have all
/// been published (the phase driver calls this after `wait_idle`, when
/// every worker — however late — has written its rows). Each becomes a
/// [`LateContrib`] for the next phase's `carry_in`. Reads are accounted
/// into `cfg.io` like any other executor read.
pub fn collect_late_contribs(
    topo: &Topology,
    db: &CheckpointDb,
    cfg: &OuterConfig,
    phase: usize,
    late: &[(usize, ModuleId)],
) -> Result<Vec<LateContrib>> {
    if late.is_empty() {
        return Ok(Vec::new());
    }
    let rows = db.query_prefix(phase, "path");
    let mut wire: Vec<f32> = Vec::new();
    let mut out = Vec::with_capacity(late.len());
    for &(p, m) in late {
        // The row that carries this module: a streamed group row listing
        // it in metadata, or a whole-path row (empty metadata = legacy
        // probe, same as the executor's rule).
        let row = rows
            .iter()
            .find(|r| {
                r.path_id == p
                    && (r.modules.contains(&m) || (r.kind == "path" && r.modules.is_empty()))
            })
            .with_context(|| {
                format!("late path {p}: no published row carries module {m} (phase {phase})")
            })?;
        let mut reader = crate::transport::open_source(cfg.transport.as_deref(), &row.file)
            .with_context(|| format!("late-merge opening {}", row.file.display()))?;
        cfg.io
            .payload_bytes_read
            .fetch_add(reader.bytes_read(), Ordering::Relaxed);
        let before = reader.bytes_read();
        let res = reader.read_into(&m.delta_section(), &mut wire);
        cfg.io
            .payload_bytes_read
            .fetch_add(reader.bytes_read() - before, Ordering::Relaxed);
        res.with_context(|| format!("late-merge reading {} of {}", m, row.file.display()))?;
        cfg.io.sections_read.fetch_add(1, Ordering::Relaxed);
        let mut delta = Vec::new();
        decode_delta_into(cfg.codec, &wire, &mut delta)
            .with_context(|| format!("late-merge decoding {} of {}", m, row.file.display()))?;
        out.push(LateContrib {
            path: p,
            module: m,
            delta,
            weight: cfg.weight_of(p),
        });
    }
    Ok(out)
}

/// Naive (non-sharded, non-online) outer update used as the §3.3 baseline
/// in benches: wait for ALL checkpoints, load each one IN FULL, then
/// average and update serially. F32-codec, phase-synchronous only — it is
/// the baseline the streaming path is measured against.
pub fn naive_phase_outer(
    topo: &Topology,
    store: &Mutex<ModuleStore>,
    opt: &mut Nesterov,
    cfg: &OuterConfig,
    phase: usize,
    db: &CheckpointDb,
) -> Result<usize> {
    // gather everything first (the inefficiency under test)
    let rows = db.query(phase, "path");
    let ckpts: Vec<(CkptRow, Checkpoint)> = rows
        .into_iter()
        .map(|r| {
            let ck = Checkpoint::load(&r.file)?;
            Ok((r, ck))
        })
        .collect::<Result<_>>()?;
    let mut n = 0;
    for m in topo.all_modules() {
        let mut acc = OuterAccumulator::new(topo.levels[m.level].size);
        for (row, ck) in &ckpts {
            // topology decides which paths feed this module; a traversing
            // path's checkpoint missing the section errors loudly below
            if topo.expert_of(row.path_id, m.level) != m.expert {
                continue;
            }
            let delta = ck
                .get(&m.delta_section())
                .with_context(|| format!("ckpt missing section for module {m}"))?;
            let w = cfg.weight_of(row.path_id);
            acc.add(delta, w);
        }
        if acc.contributions() == 0 {
            continue;
        }
        let mut g = acc.average();
        let scale = rescale_factor(topo, m, cfg.diloco.norm_rescale);
        g.iter_mut().for_each(|x| *x *= scale);
        let mut store_g = store.lock().unwrap();
        opt.step(m, store_g.get_mut(m), &g);
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologySpec;
    use crate::params::manifest::Manifest;
    use crate::util::json::Json;

    fn setup() -> (Arc<Topology>, Arc<Mutex<ModuleStore>>, Vec<f32>) {
        let j = crate::params::manifest::tests::fake_manifest_json(4, 8);
        let man = Manifest::from_json(&Json::parse(&j).unwrap()).unwrap();
        let topo = Arc::new(Topology::build(&man, &TopologySpec::grid(vec![2, 2])));
        let theta: Vec<f32> = (0..man.total_params).map(|i| (i % 97) as f32 * 0.01).collect();
        let store = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        (topo, store, theta)
    }

    /// Worker-style sectioned checkpoint: one delta section per traversed
    /// module (before - after), plus module metadata on the row.
    fn save_path_ckpt(
        dir: &std::path::Path,
        topo: &Topology,
        phase: usize,
        path: usize,
        before: &[f32],
        after: &[f32],
    ) -> CkptRow {
        let file = dir.join(format!("p{phase}-path{path}.dpc"));
        let (ck, modules) = topo.delta_checkpoint(path, before, after);
        ck.with("loss", vec![1.0]).save(&file).unwrap();
        CkptRow {
            rowid: 0,
            phase,
            path_id: path,
            kind: "path".into(),
            file,
            step: 0,
            loss: 1.0,
            modules,
        }
    }

    fn perturbed_after(theta: &[f32], p: usize) -> Vec<f32> {
        theta
            .iter()
            .enumerate()
            .map(|(i, &v)| v + 0.001 * (p as f32 + 1.0) * ((i % 7) as f32 - 3.0))
            .collect()
    }

    fn assert_stores_close(topo: &Topology, a: &ModuleStore, b: &ModuleStore, tol: f32) {
        for m in topo.all_modules() {
            for (x, y) in a.get(m).iter().zip(b.get(m)) {
                assert!((x - y).abs() < tol, "module {m} diverged: {x} vs {y}");
            }
        }
    }

    #[test]
    fn sharding_covers_all_modules() {
        let (topo, _, _) = setup();
        let shards = shard_modules(&topo, 3);
        let mut all: Vec<ModuleId> = shards.concat();
        all.sort();
        let mut expect = topo.all_modules();
        expect.sort();
        assert_eq!(all, expect);
    }

    #[test]
    fn online_sharded_matches_naive() {
        // Both implementations must produce identical module stores.
        let (topo, store_a, theta) = setup();
        let store_b = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        let dir = std::env::temp_dir().join(format!("dipaco-outer-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        // fake per-path results: theta + path-dependent perturbation
        let db = Arc::new(CheckpointDb::new());
        let mut rows = Vec::new();
        for p in 0..topo.paths {
            let after = perturbed_after(&theta, p);
            rows.push(save_path_ckpt(&dir, &topo, 0, p, &theta, &after));
        }
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![10, 20, 30, 40],
            ..Default::default()
        };

        // naive on store_b
        let dbb = CheckpointDb::new();
        for r in &rows {
            dbb.insert(r.clone());
        }
        let mut opt_b = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        naive_phase_outer(&topo, &store_b, &mut opt_b, &cfg, 0, &dbb).unwrap();

        // online sharded on store_a — rows inserted concurrently
        let shards = shard_modules(&topo, 2);
        let mut opts: Vec<Nesterov> = (0..2)
            .map(|_| Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum))
            .collect();
        let (done_tx, done_rx) = channel();
        let db2 = Arc::clone(&db);
        let rows2 = rows.clone();
        let feeder = std::thread::spawn(move || {
            for r in rows2 {
                std::thread::sleep(std::time::Duration::from_millis(2));
                db2.insert(r);
            }
        });
        let report =
            run_phase_outer(&topo, &store_a, &mut opts, &shards, &cfg, 0, &db, &done_tx).unwrap();
        feeder.join().unwrap();
        let n = report.modules_updated;
        assert_eq!(n, topo.all_modules().len());
        assert!(report.late.is_empty());
        // every module got a done notification
        let mut dones = 0;
        while done_rx.try_recv().is_ok() {
            dones += 1;
        }
        assert_eq!(dones, n);

        let a = store_a.lock().unwrap();
        let b = store_b.lock().unwrap();
        assert_stores_close(&topo, &a, &b, 1e-5);
    }

    #[test]
    fn update_moves_toward_worker_params() {
        // With lr>0 and a consistent delta direction, the store moves
        // toward (not away from) the workers' new parameters.
        let (topo, store, theta) = setup();
        let dir = std::env::temp_dir().join(format!("dipaco-outer2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let db = Arc::new(CheckpointDb::new());
        for p in 0..topo.paths {
            // all workers move +0.1 everywhere
            let after: Vec<f32> = theta.iter().map(|&v| v + 0.1).collect();
            db.insert(save_path_ckpt(&dir, &topo, 0, p, &theta, &after));
        }
        let cfg = OuterConfig {
            diloco: DilocoConfig {
                loss_reweigh: false,
                norm_rescale: false,
                ..Default::default()
            },
            shard_sizes: vec![1; topo.paths],
            ..Default::default()
        };
        let shards = shard_modules(&topo, 1);
        let mut opts = vec![Nesterov::new(0.7, 0.9)];
        let (tx, _rx) = channel();
        run_phase_outer(&topo, &store, &mut opts, &shards, &cfg, 0, &db, &tx).unwrap();
        let g = store.lock().unwrap();
        for m in topo.all_modules() {
            let before = topo.extract(m.level, &theta);
            for (x, b) in g.get(m).iter().zip(&before) {
                // delta = before-after = -0.1; nesterov step: p -= lr*(1+mu)*(-0.1) -> +0.133
                assert!(x > b, "module {m} did not move toward workers");
            }
        }
    }

    #[test]
    fn duplicate_deliveries_are_deduped() {
        // Regression test for the subscribe/replay double-delivery bug:
        // a row delivered twice must be accumulated ONCE — before the
        // dedup, contributions overshot the quorum and the phase hung.
        let (topo, store, theta) = setup();
        let store_ref = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        let dir = std::env::temp_dir().join(format!("dipaco-outer3-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![10, 20, 30, 40],
            ..Default::default()
        };
        let dbb = CheckpointDb::new();
        let mut rows = Vec::new();
        for p in 0..topo.paths {
            let after = perturbed_after(&theta, p);
            rows.push(save_path_ckpt(&dir, &topo, 0, p, &theta, &after));
        }
        for r in &rows {
            dbb.insert(r.clone());
        }
        let mut opt_ref = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        naive_phase_outer(&topo, &store_ref, &mut opt_ref, &cfg, 0, &dbb).unwrap();

        // one executor owning everything; every row delivered TWICE
        let owned = topo.all_modules();
        let (tx, rx) = channel();
        for r in &rows {
            tx.send(r.clone()).unwrap();
            tx.send(r.clone()).unwrap();
        }
        drop(tx); // a deadlock would surface as a channel-closed error
        let mut opt = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        let (done_tx, _done_rx) = channel();
        executor_loop(&topo, &store, &mut opt, &owned, &cfg, 0, &rx, &done_tx).unwrap();

        let a = store.lock().unwrap();
        let b = store_ref.lock().unwrap();
        assert_stores_close(&topo, &a, &b, 1e-6);
    }

    #[test]
    fn executor_reads_only_owned_sections() {
        // Byte/section accounting: an executor must fetch exactly the
        // sections of modules it owns — O(owned bytes), not O(total).
        let (topo, store, theta) = setup();
        let dir = std::env::temp_dir().join(format!("dipaco-outer4-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let rows: Vec<CkptRow> = (0..topo.paths)
            .map(|p| {
                let after = perturbed_after(&theta, p);
                save_path_ckpt(&dir, &topo, 0, p, &theta, &after)
            })
            .collect();
        let shards = shard_modules(&topo, 2);
        let full_bytes: u64 = rows
            .iter()
            .map(|r| std::fs::metadata(&r.file).unwrap().len())
            .sum();
        let mut total_section_bytes = 0u64;
        for owned in &shards {
            let cfg = OuterConfig {
                diloco: DilocoConfig::default(),
                shard_sizes: vec![1; topo.paths],
                ..Default::default()
            };
            let (tx, rx) = channel();
            for r in &rows {
                tx.send(r.clone()).unwrap();
            }
            let mut opt = Nesterov::new(0.7, 0.9);
            let (done_tx, _done_rx) = channel();
            executor_loop(&topo, &store, &mut opt, owned, &cfg, 0, &rx, &done_tx).unwrap();

            // expected: per row, exactly the owned modules it carries
            let owned_set: std::collections::HashSet<ModuleId> = owned.iter().copied().collect();
            let mut want_sections = 0u64;
            let mut want_bytes = 0u64;
            for r in &rows {
                for m in r.modules.iter().filter(|m| owned_set.contains(*m)) {
                    want_sections += 1;
                    want_bytes += 4 * topo.levels[m.level].size as u64;
                }
            }
            let (sections, bytes) = cfg.io.snapshot();
            assert_eq!(sections, want_sections);
            assert_eq!(bytes, want_bytes);
            // each executor reads strictly less than loading every file
            assert!(
                bytes < full_bytes,
                "owned-section reads ({bytes}) must stay below full loads ({full_bytes})"
            );
            total_section_bytes += bytes;
        }
        // across all shards, every delta payload is read exactly once —
        // the phase total is size(m) x paths_through(m), independent of
        // executor count (the old pipeline scaled with it)
        let want_total: u64 = topo
            .all_modules()
            .iter()
            .map(|&m| 4 * (topo.levels[m.level].size * topo.paths_through(m)) as u64)
            .sum();
        assert_eq!(total_section_bytes, want_total);
        assert!(total_section_bytes < full_bytes);
    }

    #[test]
    fn declared_late_path_skips_phase_and_reports_pairs() {
        // A declared-late path's rows are skipped, its modules apply at
        // reduced quorum (== naive over the remaining paths), and every
        // (late path, module) pair is reported for next-phase carry.
        let (topo, store, theta) = setup();
        let store_ref = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        let dir = std::env::temp_dir().join(format!("dipaco-outer5-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let rows: Vec<CkptRow> = (0..topo.paths)
            .map(|p| save_path_ckpt(&dir, &topo, 0, p, &theta, &perturbed_after(&theta, p)))
            .collect();
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![1; topo.paths],
            declared_late: vec![(0, 1)],
            ..Default::default()
        };

        // reference: naive over everything EXCEPT path 1
        let dbb = CheckpointDb::new();
        for r in rows.iter().filter(|r| r.path_id != 1) {
            dbb.insert(r.clone());
        }
        let mut opt_ref = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        naive_phase_outer(&topo, &store_ref, &mut opt_ref, &cfg, 0, &dbb).unwrap();

        // executor gets ALL rows, including the declared-late path's
        let owned = topo.all_modules();
        let (tx, rx) = channel();
        for r in &rows {
            tx.send(r.clone()).unwrap();
        }
        drop(tx);
        let mut opt = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        let (done_tx, _done_rx) = channel();
        let late = executor_loop(&topo, &store, &mut opt, &owned, &cfg, 0, &rx, &done_tx).unwrap();

        let mut want: Vec<(usize, ModuleId)> =
            topo.modules_of_path(1).into_iter().map(|m| (1, m)).collect();
        want.sort();
        assert_eq!(late, want);
        let a = store.lock().unwrap();
        let b = store_ref.lock().unwrap();
        assert_stores_close(&topo, &a, &b, 1e-6);
    }

    #[test]
    fn grace_expiry_applies_partial_quorum_and_reports_timing_late() {
        // With a grace window armed and one path never publishing, the
        // executor resolves every module with the contributions that made
        // it (== naive over the arrived paths) and reports the missing
        // (path, module) pairs instead of hanging.
        let (topo, store, theta) = setup();
        let store_ref = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        let dir = std::env::temp_dir().join(format!("dipaco-outer6-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let straggler = topo.paths - 1;
        let rows: Vec<CkptRow> = (0..topo.paths)
            .filter(|&p| p != straggler)
            .map(|p| save_path_ckpt(&dir, &topo, 0, p, &theta, &perturbed_after(&theta, p)))
            .collect();
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![1; topo.paths],
            grace: Some(Duration::from_millis(50)),
            ..Default::default()
        };

        let dbb = CheckpointDb::new();
        for r in &rows {
            dbb.insert(r.clone());
        }
        let mut opt_ref = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        naive_phase_outer(&topo, &store_ref, &mut opt_ref, &cfg, 0, &dbb).unwrap();

        let owned = topo.all_modules();
        let (tx, rx) = channel();
        for r in &rows {
            tx.send(r.clone()).unwrap();
        }
        // NOTE: tx stays alive — the executor must exit via the grace
        // deadline, not via a disconnected channel.
        let mut opt = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        let (done_tx, done_rx) = channel();
        let late = executor_loop(&topo, &store, &mut opt, &owned, &cfg, 0, &rx, &done_tx).unwrap();
        drop(tx);

        let mut want: Vec<(usize, ModuleId)> = topo
            .modules_of_path(straggler)
            .into_iter()
            .map(|m| (straggler, m))
            .collect();
        want.sort();
        assert_eq!(late, want);
        // every module still resolved (and notified), none hung
        let mut dones = 0;
        while done_rx.try_recv().is_ok() {
            dones += 1;
        }
        assert_eq!(dones, topo.all_modules().len());
        let a = store.lock().unwrap();
        let b = store_ref.lock().unwrap();
        assert_stores_close(&topo, &a, &b, 1e-6);
    }

    #[test]
    fn carried_contribution_joins_next_phase_quorum() {
        // A LateContrib carried into phase 1 raises its module's quorum
        // by one and is reduced in (path, carried) order — verified
        // against a hand-built accumulation. A carry for a module this
        // executor does not own is ignored.
        let (topo, store, theta) = setup();
        let dir = std::env::temp_dir().join(format!("dipaco-outer7-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let m = *topo.modules_of_path(2).first().unwrap();
        let size = topo.levels[m.level].size;
        let carry_delta: Vec<f32> = (0..size).map(|i| 0.01 * ((i % 5) as f32 - 2.0)).collect();
        let foreign = *topo
            .all_modules()
            .iter()
            .find(|&&x| x != m)
            .unwrap();
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![1; topo.paths],
            carry_in: vec![
                LateContrib {
                    path: 2,
                    module: m,
                    delta: carry_delta.clone(),
                    weight: 1.0,
                },
                LateContrib {
                    path: 0,
                    module: foreign,
                    delta: vec![0.5; topo.levels[foreign.level].size],
                    weight: 1.0,
                },
            ],
            ..Default::default()
        };

        let rows: Vec<CkptRow> = (0..topo.paths)
            .map(|p| save_path_ckpt(&dir, &topo, 1, p, &theta, &perturbed_after(&theta, p)))
            .collect();
        let owned = vec![m];
        let (tx, rx) = channel();
        for r in &rows {
            tx.send(r.clone()).unwrap();
        }
        drop(tx); // without the carry the quorum would miss by one and bail here
        let mut opt = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        let (done_tx, _done_rx) = channel();
        let late =
            executor_loop(&topo, &store, &mut opt, &owned, &cfg, 1, &rx, &done_tx).unwrap();
        assert!(late.is_empty());

        // hand-built reference: fresh contributions in path order, with
        // the carried one slotted after fresh path 2 ((path, carried) order)
        let mut entries: Vec<(usize, bool, Vec<f32>)> = topo
            .paths_of_module(m)
            .into_iter()
            .map(|p| {
                let (ck, _) = topo.delta_checkpoint(p, &theta, &perturbed_after(&theta, p));
                (p, false, ck.get(&m.delta_section()).unwrap().to_vec())
            })
            .collect();
        entries.push((2, true, carry_delta));
        entries.sort_by_key(|e| (e.0, e.1));
        let mut racc = OuterAccumulator::new(0);
        racc.reset(size);
        for e in &entries {
            racc.add(&e.2, 1.0);
        }
        let mut g = Vec::new();
        racc.average_into(&mut g);
        let scale = rescale_factor(&topo, m, cfg.diloco.norm_rescale);
        if scale != 1.0 {
            g.iter_mut().for_each(|x| *x *= scale);
        }
        let store_ref = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta)));
        let mut opt_ref = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
        {
            let mut sg = store_ref.lock().unwrap();
            opt_ref.step(m, sg.get_mut(m), &g);
        }
        let a = store.lock().unwrap();
        let b = store_ref.lock().unwrap();
        for (x, y) in a.get(m).iter().zip(b.get(m)) {
            assert_eq!(x, y, "carried reduction must be bit-identical to reference");
        }
    }

    #[test]
    fn failed_row_accounts_bytes_already_read() {
        // Satellite regression: a mid-row section-read failure must not
        // lose the bytes already verified from that row. The checkpoint
        // below carries only the FIRST module's section while the row
        // metadata claims all of them, so the second read errors after
        // one successful section.
        let (topo, store, theta) = setup();
        let dir = std::env::temp_dir().join(format!("dipaco-outer8-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mods = topo.modules_of_path(0);
        assert!(mods.len() >= 2);
        let first = mods[0];
        let (ck_full, modules) = topo.delta_checkpoint(0, &theta, &perturbed_after(&theta, 0));
        let name = first.delta_section();
        let data = ck_full.get(&name).unwrap();
        let file = dir.join("partial.dpc");
        crate::params::checkpoint::save_sections(&file, &[(&name, data)]).unwrap();
        let row = CkptRow {
            rowid: 0,
            phase: 0,
            path_id: 0,
            kind: "path".into(),
            file,
            step: 0,
            loss: 1.0,
            modules,
        };
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![1; topo.paths],
            ..Default::default()
        };
        let owned = topo.all_modules();
        let (tx, rx) = channel();
        tx.send(row).unwrap();
        drop(tx);
        let mut opt = Nesterov::new(0.7, 0.9);
        let (done_tx, _done_rx) = channel();
        let err = executor_loop(&topo, &store, &mut opt, &owned, &cfg, 0, &rx, &done_tx)
            .unwrap_err();
        assert!(format!("{err:#}").contains("executor reading"));
        let (sections, bytes) = cfg.io.snapshot();
        assert_eq!(sections, 1, "only the successful read counts as a section");
        assert_eq!(
            bytes,
            4 * topo.levels[first.level].size as u64,
            "bytes verified before the failure must be accounted"
        );
    }

    #[test]
    fn legacy_dpc1_row_accounts_whole_file_at_open() {
        // A DPC1 fallback reads the entire file at open; the accounting
        // must record that immediately (not only after the row's loop),
        // and per-section watermark deltas add nothing on top.
        let (topo, store, theta) = setup();
        let dir = std::env::temp_dir().join(format!("dipaco-outer9-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (ck, modules) = topo.delta_checkpoint(0, &theta, &perturbed_after(&theta, 0));
        let file = dir.join("legacy.dpc");
        ck.save_dpc1(&file).unwrap();
        let file_len = std::fs::metadata(&file).unwrap().len();
        let row = CkptRow {
            rowid: 0,
            phase: 0,
            path_id: 0,
            kind: "path".into(),
            file,
            step: 0,
            loss: 1.0,
            modules,
        };
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![1; topo.paths],
            ..Default::default()
        };
        // own just one module so only one section is consumed
        let owned = vec![topo.modules_of_path(0)[0]];
        let (tx, rx) = channel();
        tx.send(row).unwrap();
        drop(tx);
        let mut opt = Nesterov::new(0.7, 0.9);
        let (done_tx, _done_rx) = channel();
        // the owned module's quorum needs more paths than the one row
        // sent, so the loop ends on the closed channel — AFTER the row
        // (and its whole-file legacy read) was processed and accounted
        let err = executor_loop(&topo, &store, &mut opt, &owned, &cfg, 0, &rx, &done_tx);
        assert!(err.is_err());
        let (sections, bytes) = cfg.io.snapshot();
        assert_eq!(sections, 1);
        assert_eq!(bytes, file_len, "legacy open accounts the whole file");
    }
}
