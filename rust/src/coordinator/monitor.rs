//! Job status monitor (paper §3, green box in Figure 6): "a monitoring
//! worker periodically checks the health of workers and task queue servers,
//! and restarts them if they become unresponsive."
//!
//! Here: a thread that each tick (a) reclaims expired task leases and
//! (b) compares live worker heartbeats against the pool's target size,
//! respawning replacements for crashed workers.
//!
//! The tick wait is a condvar park, not a `thread::sleep`: `stop()`
//! interrupts it immediately, so coordinator teardown no longer pays up
//! to a full tick per monitor (the old sleep made a 30 s tick a 30 s
//! shutdown stall).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::worker::WorkerPool;
use crate::info;

/// Interruptible stop flag: `wait_tick` parks on the condvar for up to
/// one tick; `raise` flips the flag and wakes every parked waiter now.
struct StopSignal {
    stopped: Mutex<bool>,
    cv: Condvar,
}

impl StopSignal {
    fn new() -> Self {
        StopSignal {
            stopped: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    /// Park for up to `tick` or until `raise()`. Returns true when it is
    /// time to stop.
    fn wait_tick(&self, tick: Duration) -> bool {
        let mut stopped = self.stopped.lock().unwrap();
        let deadline = std::time::Instant::now() + tick;
        while !*stopped {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, _) = self.cv.wait_timeout(stopped, deadline - now).unwrap();
            stopped = g;
        }
        true
    }

    fn raise(&self) {
        *self.stopped.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

pub struct Monitor {
    stop: Arc<StopSignal>,
    handle: Option<JoinHandle<()>>,
    pub respawns: Arc<AtomicU64>,
    pub reclaims: Arc<AtomicU64>,
}

impl Monitor {
    pub fn start(pool: Arc<WorkerPool>, tick: Duration) -> Monitor {
        let stop = Arc::new(StopSignal::new());
        let respawns = Arc::new(AtomicU64::new(0));
        let reclaims = Arc::new(AtomicU64::new(0));
        let stop2 = Arc::clone(&stop);
        let respawns2 = Arc::clone(&respawns);
        let reclaims2 = Arc::clone(&reclaims);
        let handle = std::thread::Builder::new()
            .name("monitor".into())
            .spawn(move || {
                while !stop2.wait_tick(tick) {
                    let ctx = pool.ctx();
                    // (a) requeue tasks whose workers died holding a lease
                    let n = ctx.queue.reclaim_expired();
                    if n > 0 {
                        reclaims2.fetch_add(n as u64, Ordering::Relaxed);
                        let qs = ctx.queue.stats();
                        info!(
                            "monitor",
                            "reclaimed {n} expired leases (lifetime: {} reclaimed, {} buried)",
                            qs.reclaimed,
                            qs.buried
                        );
                    }
                    // (b) resurrect crashed workers
                    if ctx.shutting_down.load(Ordering::Relaxed) {
                        continue;
                    }
                    let live = ctx.live_workers();
                    if live < pool.target_workers {
                        let need = pool.target_workers - live;
                        for _ in 0..need {
                            pool.spawn_worker(false);
                        }
                        respawns2.fetch_add(need as u64, Ordering::Relaxed);
                        info!("monitor", "respawned {need} workers ({live} live)");
                    }
                }
            })
            .expect("spawn monitor");
        Monitor {
            stop,
            handle: Some(handle),
            respawns,
            reclaims,
        }
    }

    pub fn stop(mut self) {
        self.stop.raise();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Monitor {
    fn drop(&mut self) {
        self.stop.raise();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn stop_interrupts_tick_wait_immediately() {
        // Regression (ISSUE 10): the monitor loop used to start with
        // std::thread::sleep(tick), so stop() blocked on join for up to
        // a full tick. With the condvar park, stop latency must be tiny
        // even against a tick far longer than any acceptable shutdown.
        let sig = Arc::new(StopSignal::new());
        let sig2 = Arc::clone(&sig);
        let parked = std::thread::spawn(move || {
            let t0 = Instant::now();
            assert!(sig2.wait_tick(Duration::from_secs(30)), "raise must win");
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(30));
        let t0 = Instant::now();
        sig.raise();
        let waited = parked.join().unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(250),
            "stop latency {:?} not << tick",
            t0.elapsed()
        );
        assert!(waited < Duration::from_secs(1), "parked thread waited {waited:?}");
    }

    #[test]
    fn wait_tick_times_out_when_not_stopped() {
        let sig = StopSignal::new();
        let t0 = Instant::now();
        assert!(!sig.wait_tick(Duration::from_millis(20)));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn raise_before_wait_returns_immediately() {
        let sig = StopSignal::new();
        sig.raise();
        let t0 = Instant::now();
        assert!(sig.wait_tick(Duration::from_secs(30)));
        assert!(t0.elapsed() < Duration::from_millis(100));
    }
}
