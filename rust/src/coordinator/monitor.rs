//! Job status monitor (paper §3, green box in Figure 6): "a monitoring
//! worker periodically checks the health of workers and task queue servers,
//! and restarts them if they become unresponsive."
//!
//! Here: a thread that each tick (a) reclaims expired task leases and
//! (b) compares live worker heartbeats against the pool's target size,
//! respawning replacements for crashed workers.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::worker::WorkerPool;
use crate::info;

pub struct Monitor {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    pub respawns: Arc<AtomicU64>,
    pub reclaims: Arc<AtomicU64>,
}

impl Monitor {
    pub fn start(pool: Arc<WorkerPool>, tick: Duration) -> Monitor {
        let stop = Arc::new(AtomicBool::new(false));
        let respawns = Arc::new(AtomicU64::new(0));
        let reclaims = Arc::new(AtomicU64::new(0));
        let stop2 = Arc::clone(&stop);
        let respawns2 = Arc::clone(&respawns);
        let reclaims2 = Arc::clone(&reclaims);
        let handle = std::thread::Builder::new()
            .name("monitor".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    let ctx = pool.ctx();
                    // (a) requeue tasks whose workers died holding a lease
                    let n = ctx.queue.reclaim_expired();
                    if n > 0 {
                        reclaims2.fetch_add(n as u64, Ordering::Relaxed);
                        let qs = ctx.queue.stats();
                        info!(
                            "monitor",
                            "reclaimed {n} expired leases (lifetime: {} reclaimed, {} buried)",
                            qs.reclaimed,
                            qs.buried
                        );
                    }
                    // (b) resurrect crashed workers
                    if ctx.shutting_down.load(Ordering::Relaxed) {
                        continue;
                    }
                    let live = ctx.live_workers();
                    if live < pool.target_workers {
                        let need = pool.target_workers - live;
                        for _ in 0..need {
                            pool.spawn_worker(false);
                        }
                        respawns2.fetch_add(need as u64, Ordering::Relaxed);
                        info!("monitor", "respawned {need} workers ({live} live)");
                    }
                }
            })
            .expect("spawn monitor");
        Monitor {
            stop,
            handle: Some(handle),
            respawns,
            reclaims,
        }
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Monitor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
