//! Phase orchestration — Algorithm 1 end to end over the §3 infrastructure.
//!
//! Per outer step t: assemble each path's parameters from the module
//! store (into a reused buffer — the full model is materialized only
//! transiently, per path, never held for the whole phase), enqueue one
//! training task per path (workers may be fewer than paths — the queue
//! then serves multiple *rounds*, paper §3.4), run the sharded
//! outer-optimization executors concurrently so module averages
//! accumulate online as per-module delta sections land, and finish when
//! every module's outer update is applied. Worker-local AdamW state
//! chains through `opt_in`/`opt_out` files — the coordinator never
//! re-reads it. Evaluation tasks for early stopping ride the same queue
//! (Figure 6).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{DilocoConfig, RunConfig};
use crate::coordinator::db::CheckpointDb;
use crate::coordinator::outer::{
    collect_late_contribs, run_phase_outer, shard_modules, LateContrib, OuterConfig, OuterIoStats,
};
use crate::coordinator::queue::TaskQueue;
use crate::coordinator::task::{Task, TrainTask};
use crate::coordinator::worker::{WorkerCtx, WorkerPool};
use crate::data::corpus::Corpus;
use crate::data::dataset::Sharding;
use crate::info;
use crate::optim::Nesterov;
use crate::params::checkpoint;
use crate::runtime::engine::Engine;
use crate::topology::{ModuleStore, Topology};
use crate::util::pool::Pool as BufPool;
use crate::util::threadpool::parallel_map;

/// Result of one phase.
#[derive(Debug, Clone)]
pub struct PhaseStats {
    pub phase: usize,
    pub mean_train_loss: f64,
    pub wallclock_s: f64,
    pub outer_update_s: f64,
    pub requeues: u64,
    /// Checkpoint sections the outer executors fetched this phase.
    pub outer_sections_read: u64,
    /// Payload bytes those fetches served — O(module size × paths-through),
    /// not O(total_params × paths × executors).
    pub outer_bytes_read: u64,
    /// `(path, module)` contributions that missed this phase's quorums
    /// (straggler grace window) and were carried into the next phase.
    pub late_merged: usize,
}

pub struct DipacoRun {
    pub engine: Arc<Engine>,
    pub corpus: Arc<Corpus>,
    pub sharding: Arc<Sharding>,
    pub topo: Arc<Topology>,
    pub store: Arc<Mutex<ModuleStore>>,
    pub diloco: DilocoConfig,
    pub run: RunConfig,
    pub rundir: PathBuf,
    pub early_stop: bool,

    queue: Arc<TaskQueue>,
    pub db: Arc<CheckpointDb>,
    pool: Arc<WorkerPool>,
    /// Section exchange plane shared by publishers (workers) and readers
    /// (outer executors): local filesystem by default, the TCP plane when
    /// `run.transport.mode` asks for it.
    transport: Arc<dyn crate::transport::SectionTransport>,
    outer_opts: Vec<Nesterov>,
    executor_shards: Vec<Vec<crate::topology::ModuleId>>,
    next_task_id: u64,
    /// Per-path pointer to the worker-local AdamW state file written by
    /// the latest completed phase (paths keep their moments like DiLoCo
    /// workers do; the state itself never passes through the coordinator).
    opt_files: HashMap<usize, PathBuf>,
    /// Pool of assembly buffers (`total_params` floats each): the
    /// data-parallel assembly fan-out holds at most `assembly_threads`
    /// at once, all reused phase over phase.
    assemble_pool: Arc<BufPool<f32>>,
    /// Delta-buffer pool for the outer executors, persistent across
    /// phases so steady-state reduction allocates nothing.
    outer_pool: Arc<BufPool<f32>>,
    /// Straggler contributions declared late by the previous phase,
    /// waiting to join the next phase's accumulation (streaming outer
    /// sync's late-merge; empty unless `run.straggler_grace_ms` > 0).
    pending_carry: Vec<LateContrib>,
    pub stats: Vec<PhaseStats>,
}

impl DipacoRun {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        engine: Arc<Engine>,
        corpus: Arc<Corpus>,
        sharding: Arc<Sharding>,
        topo: Arc<Topology>,
        base_theta: &[f32],
        diloco: DilocoConfig,
        run: RunConfig,
        rundir: PathBuf,
        early_stop: bool,
    ) -> Result<DipacoRun> {
        std::fs::create_dir_all(&rundir)?;
        assert_eq!(
            sharding.shards.len(),
            topo.paths,
            "one shard per path (paper §2.4)"
        );
        let store = Arc::new(Mutex::new(ModuleStore::from_base(&topo, base_theta)));
        let queue = Arc::new(TaskQueue::new(std::time::Duration::from_millis(
            run.lease_ms,
        )));
        let db = Arc::new(CheckpointDb::new());
        let executor_shards = shard_modules(&topo, run.outer_executors);
        // The exchange plane is built from the SAME shard list the
        // executors run over, so rendezvous ownership and executor
        // accumulation cannot drift apart.
        let transport: Arc<dyn crate::transport::SectionTransport> = match run.transport.mode {
            crate::config::TransportMode::Local => {
                Arc::new(crate::transport::local::LocalTransport)
            }
            crate::config::TransportMode::Tcp => crate::transport::tcp::TcpExchange::start(
                &executor_shards,
                run.transport.clone(),
                None,
            )
            .context("starting TCP section exchange plane")?,
        };
        let mut ctx = WorkerCtx::new(
            Arc::clone(&engine),
            Arc::clone(&queue),
            Arc::clone(&db),
            Arc::clone(&corpus),
            Arc::clone(&sharding),
            Arc::clone(&topo),
            diloco.clone(),
            run.clone(),
            early_stop,
        );
        Arc::get_mut(&mut ctx)
            .expect("worker ctx is unshared before spawn")
            .transport = Arc::clone(&transport);
        let pool = WorkerPool::spawn(ctx, run.workers, run.backup_workers);
        let outer_opts = (0..executor_shards.len())
            .map(|_| Nesterov::new(diloco.outer_lr, diloco.outer_momentum))
            .collect();
        Ok(DipacoRun {
            engine,
            corpus,
            sharding,
            topo,
            store,
            diloco,
            run,
            rundir,
            early_stop,
            queue,
            db,
            pool,
            transport,
            outer_opts,
            executor_shards,
            next_task_id: 1,
            opt_files: HashMap::new(),
            assemble_pool: BufPool::new(8),
            outer_pool: BufPool::new(256),
            pending_carry: Vec::new(),
            stats: Vec::new(),
        })
    }

    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    pub fn queue(&self) -> &Arc<TaskQueue> {
        &self.queue
    }

    /// Run one outer phase (Algorithm 1 lines 3-16).
    pub fn run_phase(&mut self, phase: usize) -> Result<PhaseStats> {
        let t0 = Instant::now();
        let requeues_before = self.queue.stats().requeues;
        let phase_dir = self.rundir.join(format!("phase{phase}"));
        std::fs::create_dir_all(&phase_dir)?;

        // ---- assemble per-path inputs from the current global modules ----
        // Theta only: AdamW state chains through worker-local opt files.
        let opt_dir = self.rundir.join("opt");
        std::fs::create_dir_all(&opt_dir)?;
        // Assemble + write every path's input checkpoint, data-parallel
        // across `run.assembly_threads`: outputs are independent files,
        // buffers come from the pool, and the store lock is taken ONCE
        // for the whole fan-out (assembly only reads modules). Results
        // come back in path order, so task ids stay deterministic.
        let paths: Vec<usize> = (0..self.topo.paths).collect();
        let topo = &self.topo;
        let assemble_pool = &self.assemble_pool;
        let phase_dir_ref = &phase_dir;
        let ckpt_ins: Vec<PathBuf> = {
            let store = self.store.lock().unwrap();
            let store: &ModuleStore = &store;
            parallel_map(&paths, self.run.assembly_threads.max(1), |&path| {
                let mut buf = BufPool::take(assemble_pool, 0);
                topo.assemble_into(store, path, &mut buf);
                let ckpt_in = phase_dir_ref.join(format!("path{path}.in.dpc"));
                checkpoint::save_sections(&ckpt_in, &[("theta", buf.as_slice())])?;
                Ok(ckpt_in)
            })
            .into_iter()
            .collect::<Result<_>>()?
        };
        let mut tasks = Vec::with_capacity(self.topo.paths);
        for (path, ckpt_in) in ckpt_ins.into_iter().enumerate() {
            let opt_out = opt_dir.join(format!("path{path}.t{phase}.opt.dpc"));
            // None on the path's first phase (worker starts from zero
            // moments); otherwise the previous phase's state file.
            let opt_in = self.opt_files.insert(path, opt_out.clone());
            tasks.push(Task::Train(TrainTask {
                id: self.next_task_id,
                phase,
                path,
                steps: self.diloco.inner_steps,
                start_step: phase * self.diloco.inner_steps,
                ckpt_in,
                ckpt_out: phase_dir.join(format!("path{path}.out.dpc")),
                opt_in,
                opt_out,
            }));
            self.next_task_id += 1;
        }
        // A closed queue here means shutdown raced phase start; surface
        // it as a typed error instead of silently dropping the phase.
        self.queue
            .push_all(tasks)
            .with_context(|| format!("phase {phase}: task queue closed (shutdown in progress)"))?;

        // ---- outer executors consume per-module delta sections online ----
        let outer_t0 = Instant::now();
        let cfg = OuterConfig {
            diloco: self.diloco.clone(),
            shard_sizes: self.sharding.sizes(),
            io: OuterIoStats::default(),
            pool: Arc::clone(&self.outer_pool),
            codec: self.run.delta_codec,
            grace: (self.run.straggler_grace_ms > 0)
                .then(|| std::time::Duration::from_millis(self.run.straggler_grace_ms)),
            declared_late: Vec::new(), // production lateness is timing-based
            carry_in: std::mem::take(&mut self.pending_carry),
            transport: Some(Arc::clone(&self.transport)),
        };
        let (done_tx, _done_rx) = channel();
        let report = run_phase_outer(
            &self.topo,
            &self.store,
            &mut self.outer_opts,
            &self.executor_shards,
            &cfg,
            phase,
            &self.db,
            &done_tx,
        )?;
        let outer_update_s = outer_t0.elapsed().as_secs_f64();

        // drain outstanding eval tasks before closing the phase books —
        // by idle, even declared-late workers have published their rows
        self.queue
            .wait_idle(std::time::Duration::from_millis(10));

        // Late-merge: pick up the straggler deltas the executors timed
        // out on; they join the NEXT phase's accumulation (their reads
        // count into this phase's I/O, snapshotted below).
        if !report.late.is_empty() {
            self.pending_carry =
                collect_late_contribs(&self.topo, &self.db, &cfg, phase, &report.late)?;
        }
        let (io_sections, io_bytes) = cfg.io.snapshot();

        // Mean train loss over final per-path rows: under staggered
        // publication a path reports several rows ("path:g{i}"), so take
        // each path's highest-step row (its end-of-phase running mean).
        let rows = self.db.query_prefix(phase, "path");
        let mut per_path: HashMap<usize, (usize, f32)> = HashMap::new();
        for r in &rows {
            let e = per_path.entry(r.path_id).or_insert((r.step, r.loss));
            if r.step >= e.0 {
                *e = (r.step, r.loss);
            }
        }
        let mean_train_loss = per_path.values().map(|&(_, l)| l as f64).sum::<f64>()
            / per_path.len().max(1) as f64;
        let stats = PhaseStats {
            phase,
            mean_train_loss,
            wallclock_s: t0.elapsed().as_secs_f64(),
            outer_update_s,
            requeues: self.queue.stats().requeues - requeues_before,
            outer_sections_read: io_sections,
            outer_bytes_read: io_bytes,
            late_merged: report.late.len(),
        };
        info!(
            "phases",
            "phase {phase}: loss={:.4} wall={:.1}s outer={:.2}s requeues={} \
             exec_io={}sec/{}KiB late={}",
            stats.mean_train_loss,
            stats.wallclock_s,
            stats.outer_update_s,
            stats.requeues,
            stats.outer_sections_read,
            stats.outer_bytes_read / 1024,
            stats.late_merged
        );
        self.stats.push(stats.clone());
        Ok(stats)
    }

    /// Run `phases` outer steps.
    pub fn run(&mut self, phases: usize) -> Result<()> {
        for t in 0..phases {
            self.run_phase(t)?;
        }
        Ok(())
    }

    /// Current global parameters of a path (post outer updates).
    pub fn path_theta(&self, path: usize) -> Vec<f32> {
        self.store.lock().unwrap().assemble(&self.topo, path)
    }

    /// All path parameter vectors (for evaluation).
    pub fn all_path_thetas(&self) -> HashMap<usize, Vec<f32>> {
        (0..self.topo.paths).map(|p| (p, self.path_theta(p))).collect()
    }

    /// Early-stopped parameters per path (best holdout checkpoint if
    /// early stopping was enabled and beat the final params).
    pub fn early_stopped_thetas(&self) -> Result<HashMap<usize, Vec<f32>>> {
        let best = self.pool.ctx().best.lock().unwrap().clone();
        let mut out = HashMap::new();
        for p in 0..self.topo.paths {
            if let Some((_, ckpt)) = best.get(&p) {
                let theta = checkpoint::load_section(ckpt, "theta")
                    .with_context(|| format!("best checkpoint for path {p}"))?;
                out.insert(p, theta);
            } else {
                out.insert(p, self.path_theta(p));
            }
        }
        Ok(out)
    }

    /// Shut down workers and the queue.
    pub fn shutdown(&self) {
        self.pool.shutdown();
    }
}

impl Drop for DipacoRun {
    fn drop(&mut self) {
        self.pool.shutdown();
    }
}
