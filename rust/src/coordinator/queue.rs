//! Fault-tolerant task queue (paper §3.1–3.2).
//!
//! Producer-consumer with **leases**: `lease()` hands a task to a worker
//! and starts a deadline; if the worker completes in time the task
//! retires, otherwise (`worker failure or preemption`) the task returns to
//! the queue for reassignment — "the fault-tolerant task queue server
//! would return the task from the unavailable worker back to the task
//! queue before reassigning it to another available worker".
//!
//! The queue also checkpoints its own state to JSON (§3.1: "the task queue
//! server also periodically checkpoints the current task queue, making it
//! possible to recover from server failures or preemptions").
//!
//! Delivery guarantee: at-least-once handout, exactly-once *retirement* —
//! `complete()`/`ack()` on an expired/reassigned lease generation is
//! rejected (and counted in [`QueueStats::stale_completes`]), so a
//! resurrected zombie worker cannot double-retire a task. (Effects of
//! zombie side-work are idempotent: checkpoint writes are atomic renames
//! keyed by task, and the DB dedups by (phase, path).)
//!
//! Multi-host semantics (the ARW orchestrator `Queue` shape, ROADMAP
//! item 2): consumers may `nack` a lease with an optional `retry_after`
//! backoff — the task is not re-leasable before the delay elapses;
//! producers may attach a client-supplied **idempotency key**
//! ([`TaskQueue::push_idem`]) so a redelivered publish enqueues exactly
//! once; and tasks are split across **priority lanes** — eval/carry
//! work rides the express lane and can never starve behind a phase's
//! train backlog. Closing the queue is a typed condition, not a panic:
//! `push`/`push_all` return [`QueueClosed`] and publishers treat it as a
//! clean drain.
//!
//! Poison-task containment: with [`TaskQueue::with_max_attempts`] a task
//! that keeps failing is moved to a terminal *dead-letter* list after its
//! Nth lease instead of requeueing forever — `wait_idle` (which parks on
//! the queue's condvar, not a sleep poll) then returns instead of
//! spinning on a task that can never retire. The default (`new`) keeps
//! the paper's retry-forever behavior.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::task::Task;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LeaseId {
    pub task_id: u64,
    pub generation: u64,
}

/// Typed rejection for a publish that races [`TaskQueue::close`].
/// Callers treat it as a clean drain (shutdown is in progress; the work
/// is intentionally dropped) — before this existed, the race was an
/// `assert!` that panicked the whole coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueClosed;

impl std::fmt::Display for QueueClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("queue closed")
    }
}

impl std::error::Error for QueueClosed {}

#[derive(Debug)]
struct InFlight {
    task: Task,
    generation: u64,
    deadline: Instant,
    #[allow(dead_code)]
    worker: String,
}

/// A queued task plus its earliest re-lease time (set by
/// `nack(.., retry_after)`); `None` = leasable immediately.
#[derive(Debug)]
struct Pending {
    task: Task,
    not_before: Option<Instant>,
}

#[derive(Debug, Default)]
struct Inner {
    /// Express lane: eval/carry tasks, always drained before `bulk`.
    express: VecDeque<Pending>,
    /// Bulk lane: the phase's train backlog.
    bulk: VecDeque<Pending>,
    in_flight: HashMap<u64, InFlight>,
    generations: HashMap<u64, u64>,
    /// Client-supplied idempotency keys already accepted (push_idem).
    idem_seen: HashSet<String>,
    dead: Vec<Task>,
    completed: u64,
    requeues: u64,
    reclaimed: u64,
    buried: u64,
    stale_completes: u64,
    idem_dropped: u64,
    closed: bool,
}

pub struct TaskQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    lease_duration: Duration,
    /// Max leases per task before it is dead-lettered; 0 = retry forever.
    max_attempts: u64,
}

#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    pub pending: usize,
    pub in_flight: usize,
    pub completed: u64,
    pub requeues: u64,
    pub dead: usize,
    /// Cumulative leases recovered from *expired* workers (preemption /
    /// crash; explicit `fail()` is not a reclaim). Survives checkpoints.
    pub reclaimed: u64,
    /// Cumulative tasks moved to the terminal dead-letter list after
    /// exhausting `max_attempts`. Survives checkpoints.
    pub buried: u64,
    /// Cumulative `complete()`/`fail()`/`nack()` calls rejected because
    /// the lease generation was stale (zombie double-retire attempts).
    /// Previously these returned `false` with no trace. Survives
    /// checkpoints.
    pub stale_completes: u64,
    /// Cumulative pushes dropped by idempotency-key dedup (redelivered
    /// publishes). Survives checkpoints.
    pub idem_dropped: u64,
}

impl TaskQueue {
    pub fn new(lease_duration: Duration) -> Self {
        Self::with_max_attempts(lease_duration, 0)
    }

    /// A queue that dead-letters a task after `max_attempts` leases
    /// (each handout — initial or after expiry/failure — counts as one
    /// attempt). `max_attempts == 0` retries forever, like [`Self::new`].
    pub fn with_max_attempts(lease_duration: Duration, max_attempts: u64) -> Self {
        TaskQueue {
            inner: Mutex::new(Inner::default()),
            cv: Condvar::new(),
            lease_duration,
            max_attempts,
        }
    }

    /// Route a task to its lane: eval (and any future carry/control work)
    /// rides express; train work rides bulk.
    fn enqueue_locked(g: &mut Inner, task: Task, not_before: Option<Instant>) {
        let entry = Pending { task, not_before };
        match &entry.task {
            Task::Eval(_) => g.express.push_back(entry),
            Task::Train(_) => g.bulk.push_back(entry),
        }
    }

    pub fn push(&self, task: Task) -> Result<(), QueueClosed> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(QueueClosed);
        }
        Self::enqueue_locked(&mut g, task, None);
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    pub fn push_all<I: IntoIterator<Item = Task>>(&self, tasks: I) -> Result<(), QueueClosed> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(QueueClosed);
        }
        for t in tasks {
            Self::enqueue_locked(&mut g, t, None);
        }
        drop(g);
        self.cv.notify_all();
        Ok(())
    }

    /// Push with a client-supplied idempotency key: a redelivered publish
    /// (same key) is dropped instead of double-enqueueing. Returns
    /// `Ok(true)` if the task was enqueued, `Ok(false)` if the key was
    /// already seen. Keys survive queue checkpoints, so dedup holds
    /// across a server restart too.
    pub fn push_idem(&self, task: Task, idem_key: &str) -> Result<bool, QueueClosed> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(QueueClosed);
        }
        if !g.idem_seen.insert(idem_key.to_string()) {
            g.idem_dropped += 1;
            return Ok(false);
        }
        Self::enqueue_locked(&mut g, task, None);
        drop(g);
        self.cv.notify_one();
        Ok(true)
    }

    /// Pop the first *ready* entry, express lane first. A delayed entry
    /// (nack backoff still running) is skipped without blocking ready
    /// entries behind it; once the queue is closed, delays are void (the
    /// drain must finish).
    fn pop_ready_locked(g: &mut Inner, now: Instant) -> Option<Task> {
        let closed = g.closed;
        let pop = |lane: &mut VecDeque<Pending>| -> Option<Task> {
            let i = lane
                .iter()
                .position(|p| closed || p.not_before.map_or(true, |t| t <= now))?;
            lane.remove(i).map(|p| p.task)
        };
        pop(&mut g.express).or_else(|| pop(&mut g.bulk))
    }

    /// Earliest `not_before` across both lanes (wake-up hint while every
    /// pending entry is still delayed).
    fn next_ready_locked(g: &Inner) -> Option<Instant> {
        g.express
            .iter()
            .chain(g.bulk.iter())
            .filter_map(|p| p.not_before)
            .min()
    }

    /// Blocking lease with timeout. Reclaims expired leases opportunistically.
    /// Returns None on timeout or when the queue is closed and drained.
    pub fn lease(&self, worker: &str, timeout: Duration) -> Option<(LeaseId, Task)> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            Self::reclaim_locked(&mut g, self.max_attempts);
            let now = Instant::now();
            if let Some(task) = Self::pop_ready_locked(&mut g, now) {
                let task_id = task.id();
                let generation = g.generations.entry(task_id).or_insert(0);
                *generation += 1;
                let generation = *generation;
                g.in_flight.insert(
                    task_id,
                    InFlight {
                        task: task.clone(),
                        generation,
                        deadline: Instant::now() + self.lease_duration,
                        worker: worker.to_string(),
                    },
                );
                return Some((LeaseId { task_id, generation }, task));
            }
            if g.closed {
                return None;
            }
            if now >= deadline {
                return None;
            }
            // Wake early enough to reclaim the next expiring lease or
            // redeliver the next nack-delayed task.
            let mut wait = deadline - now;
            if let Some(next_exp) = g.in_flight.values().map(|f| f.deadline).min() {
                let until_exp = next_exp.saturating_duration_since(now) + Duration::from_millis(1);
                wait = wait.min(until_exp);
            }
            if let Some(next_ready) = Self::next_ready_locked(&g) {
                let until_ready =
                    next_ready.saturating_duration_since(now) + Duration::from_millis(1);
                wait = wait.min(until_ready);
            }
            let (g2, _) = self.cv.wait_timeout(g, wait).unwrap();
            g = g2;
        }
    }

    /// Retire a leased task. Rejected (false) if the lease expired and the
    /// task was reassigned — the exactly-once retirement guard.
    pub fn complete(&self, lease: LeaseId) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.in_flight.get(&lease.task_id) {
            Some(f) if f.generation == lease.generation => {
                g.in_flight.remove(&lease.task_id);
                g.completed += 1;
                drop(g);
                self.cv.notify_all();
                true
            }
            _ => {
                g.stale_completes += 1;
                false
            }
        }
    }

    /// ARW-queue alias for [`Self::complete`]: acknowledge and retire.
    pub fn ack(&self, lease: LeaseId) -> bool {
        self.complete(lease)
    }

    /// Explicitly fail a lease (graceful preemption): requeue immediately
    /// (or dead-letter once the task's attempts are exhausted).
    pub fn fail(&self, lease: LeaseId) -> bool {
        self.nack(lease, None)
    }

    /// Negative-acknowledge a lease: the task returns to its lane —
    /// immediately, or not before `retry_after` elapses (the redelivery
    /// backoff a failing consumer asks for). Counts as an attempt exactly
    /// like `fail`; a stale generation is rejected (false) and counted.
    pub fn nack(&self, lease: LeaseId, retry_after: Option<Duration>) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.in_flight.get(&lease.task_id) {
            Some(f) if f.generation == lease.generation => {
                let f = g.in_flight.remove(&lease.task_id).unwrap();
                let not_before = retry_after.map(|d| Instant::now() + d);
                Self::requeue_or_bury(&mut g, self.max_attempts, f, not_before);
                drop(g);
                // notify_all: a burial may be exactly what lets a
                // wait_idle() parked on the condvar return
                self.cv.notify_all();
                true
            }
            _ => {
                g.stale_completes += 1;
                false
            }
        }
    }

    /// Requeue a failed/expired lease — unless the task has used up
    /// `max_attempts` leases (generation counts handouts), in which case
    /// it moves to the terminal dead-letter list.
    fn requeue_or_bury(g: &mut Inner, max_attempts: u64, f: InFlight, not_before: Option<Instant>) {
        if max_attempts > 0 && f.generation >= max_attempts {
            g.dead.push(f.task);
            g.buried += 1;
        } else {
            Self::enqueue_locked(g, f.task, not_before);
            g.requeues += 1;
        }
    }

    fn reclaim_locked(g: &mut Inner, max_attempts: u64) {
        let now = Instant::now();
        let expired: Vec<u64> = g
            .in_flight
            .iter()
            .filter(|(_, f)| f.deadline <= now)
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            let f = g.in_flight.remove(&id).unwrap();
            g.reclaimed += 1;
            Self::requeue_or_bury(g, max_attempts, f, None);
        }
    }

    /// Reclaim expired leases now (the monitor calls this periodically).
    /// Returns the number of tasks moved (requeued or dead-lettered).
    pub fn reclaim_expired(&self) -> usize {
        let mut g = self.inner.lock().unwrap();
        let before = g.reclaimed;
        Self::reclaim_locked(&mut g, self.max_attempts);
        let n = (g.reclaimed - before) as usize;
        if n > 0 {
            drop(g);
            self.cv.notify_all();
        }
        n
    }

    /// Close the queue: workers drain what's left then get None; further
    /// pushes return [`QueueClosed`].
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_idle(&self) -> bool {
        let g = self.inner.lock().unwrap();
        g.express.is_empty() && g.bulk.is_empty() && g.in_flight.is_empty()
    }

    /// Block until every pushed task has been retired (completed or
    /// dead-lettered). Parks on the queue's condvar — completions,
    /// failures, and burials wake it immediately — with `poll` as the
    /// re-check ceiling and the next lease expiry as an early wake-up.
    pub fn wait_idle(&self, poll: Duration) {
        let mut g = self.inner.lock().unwrap();
        loop {
            Self::reclaim_locked(&mut g, self.max_attempts);
            if g.express.is_empty() && g.bulk.is_empty() && g.in_flight.is_empty() {
                return;
            }
            let mut wait = poll;
            let now = Instant::now();
            if let Some(next_exp) = g.in_flight.values().map(|f| f.deadline).min() {
                wait = wait.min(next_exp.saturating_duration_since(now) + Duration::from_millis(1));
            }
            let (g2, _) = self.cv.wait_timeout(g, wait).unwrap();
            g = g2;
        }
    }

    pub fn stats(&self) -> QueueStats {
        let g = self.inner.lock().unwrap();
        QueueStats {
            pending: g.express.len() + g.bulk.len(),
            in_flight: g.in_flight.len(),
            completed: g.completed,
            requeues: g.requeues,
            dead: g.dead.len(),
            reclaimed: g.reclaimed,
            buried: g.buried,
            stale_completes: g.stale_completes,
            idem_dropped: g.idem_dropped,
        }
    }

    /// Tasks that exhausted their attempts (terminal; never redelivered).
    pub fn dead_tasks(&self) -> Vec<Task> {
        self.inner.lock().unwrap().dead.clone()
    }

    /// Queue-state checkpoint (paper §3.1). Tasks only, not leases —
    /// leases are lost on server failure and the tasks return to pending.
    /// Nack backoffs are advisory and likewise not persisted (a restored
    /// task is immediately leasable, like a reclaimed one). Lanes are
    /// re-derived from task kind on restore.
    pub fn checkpoint_state(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let encode = |t: &Task| -> Json {
            match t {
                Task::Train(t) => Json::obj(vec![
                    ("kind", Json::str("train")),
                    ("id", Json::num(t.id as f64)),
                    ("phase", Json::num(t.phase as f64)),
                    ("path", Json::num(t.path as f64)),
                    ("steps", Json::num(t.steps as f64)),
                    ("start_step", Json::num(t.start_step as f64)),
                    ("ckpt_in", Json::str(t.ckpt_in.to_string_lossy())),
                    ("ckpt_out", Json::str(t.ckpt_out.to_string_lossy())),
                    // empty string = None (path's first phase)
                    (
                        "opt_in",
                        Json::str(
                            t.opt_in
                                .as_ref()
                                .map(|p| p.to_string_lossy().into_owned())
                                .unwrap_or_default(),
                        ),
                    ),
                    ("opt_out", Json::str(t.opt_out.to_string_lossy())),
                ]),
                Task::Eval(t) => Json::obj(vec![
                    ("kind", Json::str("eval")),
                    ("id", Json::num(t.id as f64)),
                    ("phase", Json::num(t.phase as f64)),
                    ("path", Json::num(t.path as f64)),
                    ("ckpt", Json::str(t.ckpt.to_string_lossy())),
                ]),
            }
        };
        Json::obj(vec![
            (
                "pending",
                Json::arr(
                    g.express
                        .iter()
                        .chain(g.bulk.iter())
                        .map(|p| encode(&p.task)),
                ),
            ),
            (
                "in_flight",
                Json::arr(g.in_flight.values().map(|f| encode(&f.task))),
            ),
            ("dead", Json::arr(g.dead.iter().map(encode))),
            ("completed", Json::num(g.completed as f64)),
            ("max_attempts", Json::num(self.max_attempts as f64)),
            ("reclaimed", Json::num(g.reclaimed as f64)),
            ("buried", Json::num(g.buried as f64)),
            ("stale_completes", Json::num(g.stale_completes as f64)),
            ("idem_dropped", Json::num(g.idem_dropped as f64)),
            // accepted idempotency keys: without these a redelivered
            // publish would double-enqueue across a server restart
            ("idem", {
                let mut keys: Vec<&String> = g.idem_seen.iter().collect();
                keys.sort();
                Json::arr(keys.into_iter().map(|k| Json::str(k.clone())))
            }),
            // per-task attempt counts: without these a poison task's
            // dead-letter budget would reset on every server restart
            ("generations", {
                let mut gens: Vec<(u64, u64)> =
                    g.generations.iter().map(|(&id, &n)| (id, n)).collect();
                gens.sort_unstable();
                Json::arr(gens.into_iter().map(|(id, n)| {
                    Json::arr([Json::num(id as f64), Json::num(n as f64)])
                }))
            }),
        ])
    }

    /// Rebuild a queue from a state checkpoint: pending + previously
    /// in-flight tasks all return to pending (leases don't survive).
    pub fn restore(state: &Json, lease_duration: Duration) -> anyhow::Result<TaskQueue> {
        use crate::coordinator::task::{EvalTask, TrainTask};
        use anyhow::Context;
        let max_attempts = state
            .get("max_attempts")
            .and_then(|v| v.as_usize())
            .unwrap_or(0) as u64;
        let q = TaskQueue::with_max_attempts(lease_duration, max_attempts);
        let decode = |j: &Json| -> anyhow::Result<Task> {
            let kind = j.req("kind")?.as_str().unwrap_or("");
            let id = j.req("id")?.as_usize().unwrap_or(0) as u64;
            let phase = j.req("phase")?.as_usize().unwrap_or(0);
            let path = j.req("path")?.as_usize().unwrap_or(0);
            Ok(match kind {
                "train" => Task::Train(TrainTask {
                    id,
                    phase,
                    path,
                    steps: j.req("steps")?.as_usize().unwrap_or(0),
                    start_step: j.req("start_step")?.as_usize().unwrap_or(0),
                    ckpt_in: j.req("ckpt_in")?.as_str().unwrap_or("").into(),
                    ckpt_out: j.req("ckpt_out")?.as_str().unwrap_or("").into(),
                    opt_in: j
                        .get("opt_in")
                        .and_then(|v| v.as_str())
                        .filter(|s| !s.is_empty())
                        .map(|s| s.into()),
                    opt_out: j
                        .get("opt_out")
                        .and_then(|v| v.as_str())
                        .unwrap_or("")
                        .into(),
                }),
                "eval" => Task::Eval(EvalTask {
                    id,
                    phase,
                    path,
                    ckpt: j.req("ckpt")?.as_str().unwrap_or("").into(),
                }),
                // A corrupted or future-format checkpoint must not be
                // silently coerced into an eval task with default fields.
                _ => anyhow::bail!("unrecognized task kind {kind:?} in queue checkpoint"),
            })
        };
        for key in ["pending", "in_flight"] {
            if let Some(arr) = state.get(key).and_then(|a| a.as_arr()) {
                for j in arr {
                    q.push(decode(j)?)
                        .expect("freshly restored queue is open");
                }
            }
        }
        // dead-lettered tasks stay terminal across a server restart
        if let Some(arr) = state.get("dead").and_then(|a| a.as_arr()) {
            let mut dead = Vec::new();
            for j in arr {
                dead.push(decode(j)?);
            }
            q.inner.lock().unwrap().dead = dead;
        }
        // cumulative fault counters survive the restart; checkpoints
        // written before these counters existed restore them as 0
        {
            let mut g = q.inner.lock().unwrap();
            g.reclaimed = state
                .get("reclaimed")
                .and_then(|v| v.as_usize())
                .unwrap_or(0) as u64;
            g.buried = state.get("buried").and_then(|v| v.as_usize()).unwrap_or(0) as u64;
            g.stale_completes = state
                .get("stale_completes")
                .and_then(|v| v.as_usize())
                .unwrap_or(0) as u64;
            g.idem_dropped = state
                .get("idem_dropped")
                .and_then(|v| v.as_usize())
                .unwrap_or(0) as u64;
            // accepted idempotency keys survive the restart (dedup must
            // hold across hosts AND across server incarnations)
            if let Some(arr) = state.get("idem").and_then(|a| a.as_arr()) {
                for k in arr {
                    if let Some(s) = k.as_str() {
                        g.idem_seen.insert(s.to_string());
                    }
                }
            }
            // attempt counts survive the restart, so a poison task cannot
            // mint a fresh max_attempts budget by crashing the server;
            // pre-generations checkpoints restore with empty counts
            if let Some(arr) = state.get("generations").and_then(|a| a.as_arr()) {
                for pair in arr {
                    let pair = pair.as_arr().context("generations entry not a pair")?;
                    anyhow::ensure!(pair.len() == 2, "generations entry not a pair");
                    let id = pair[0].as_usize().context("generations task id")? as u64;
                    let n = pair[1].as_usize().context("generations count")? as u64;
                    g.generations.insert(id, n);
                }
            }
        }
        Ok(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{EvalTask, TrainTask};

    fn train_task(id: u64) -> Task {
        Task::Train(TrainTask {
            id,
            phase: 0,
            path: id as usize,
            steps: 10,
            start_step: 0,
            ckpt_in: "in.dpc".into(),
            ckpt_out: "out.dpc".into(),
            opt_in: Some("prev.opt.dpc".into()),
            opt_out: "next.opt.dpc".into(),
        })
    }

    fn eval_task(id: u64) -> Task {
        Task::Eval(EvalTask {
            id,
            phase: 0,
            path: id as usize,
            ckpt: "e.dpc".into(),
        })
    }

    #[test]
    fn fifo_lease_complete() {
        let q = TaskQueue::new(Duration::from_secs(10));
        q.push(train_task(1)).unwrap();
        q.push(train_task(2)).unwrap();
        let (l1, t1) = q.lease("w0", Duration::from_millis(10)).unwrap();
        assert_eq!(t1.id(), 1);
        assert!(q.complete(l1));
        let (l2, t2) = q.lease("w0", Duration::from_millis(10)).unwrap();
        assert_eq!(t2.id(), 2);
        assert!(q.complete(l2));
        assert!(q.is_idle());
        assert_eq!(q.stats().completed, 2);
    }

    #[test]
    fn expired_lease_requeues() {
        let q = TaskQueue::new(Duration::from_millis(20));
        q.push(train_task(1)).unwrap();
        let (l, _) = q.lease("w0", Duration::from_millis(10)).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        // another worker picks up the same task after expiry
        let (l2, t) = q.lease("w1", Duration::from_millis(100)).unwrap();
        assert_eq!(t.id(), 1);
        // zombie completion is rejected; new lease completes fine
        assert!(!q.complete(l));
        assert!(q.complete(l2));
        assert_eq!(q.stats().requeues, 1);
        assert_eq!(q.stats().completed, 1);
        assert_eq!(q.stats().reclaimed, 1, "expiry recovery counts as a reclaim");
        assert_eq!(q.stats().buried, 0);
        // the zombie's rejected retirement is observable, not silent
        assert_eq!(q.stats().stale_completes, 1);
    }

    #[test]
    fn explicit_fail_requeues_immediately() {
        let q = TaskQueue::new(Duration::from_secs(10));
        q.push(train_task(7)).unwrap();
        let (l, _) = q.lease("w0", Duration::from_millis(10)).unwrap();
        assert!(q.fail(l));
        let (l2, t) = q.lease("w1", Duration::from_millis(10)).unwrap();
        assert_eq!(t.id(), 7);
        assert!(q.complete(l2));
        // a graceful fail() is NOT a reclaim — the worker spoke up itself
        assert_eq!(q.stats().reclaimed, 0);
        assert_eq!(q.stats().requeues, 1);
    }

    #[test]
    fn close_unblocks_lease() {
        let q = std::sync::Arc::new(TaskQueue::new(Duration::from_secs(10)));
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.lease("w0", Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn push_after_close_is_typed_rejection_not_panic() {
        // Regression (ISSUE 10): push/push_all used to assert!(!closed),
        // panicking the whole coordinator when a late publish raced
        // close(). Now the race is a typed Err the publisher drains on.
        let q = std::sync::Arc::new(TaskQueue::new(Duration::from_secs(10)));
        let q2 = std::sync::Arc::clone(&q);
        // a publisher thread racing close(): pushes until rejected
        let publisher = std::thread::spawn(move || {
            let mut accepted = 0u64;
            for i in 0.. {
                match q2.push(train_task(i)) {
                    Ok(()) => accepted += 1,
                    Err(QueueClosed) => return accepted, // clean drain
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            unreachable!()
        });
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        let accepted = publisher.join().expect("publisher must not panic");
        // everything accepted before the close is still drainable
        let mut drained = 0u64;
        while let Some((l, _)) = q.lease("w0", Duration::from_millis(5)) {
            q.complete(l);
            drained += 1;
        }
        assert_eq!(drained, accepted);
        assert_eq!(q.push(train_task(9999)), Err(QueueClosed));
        assert_eq!(q.push_all([train_task(9998)]), Err(QueueClosed));
        assert_eq!(q.push_idem(train_task(9997), "k"), Err(QueueClosed));
    }

    #[test]
    fn eval_lane_preempts_train_backlog() {
        // Priority lanes: an eval task pushed behind a long train backlog
        // is still the next task handed out.
        let q = TaskQueue::new(Duration::from_secs(10));
        for i in 0..8 {
            q.push(train_task(i)).unwrap();
        }
        q.push(eval_task(100)).unwrap();
        let (l, t) = q.lease("w0", Duration::from_millis(10)).unwrap();
        assert!(matches!(t, Task::Eval(_)), "express lane must go first");
        assert_eq!(t.id(), 100);
        assert!(q.complete(l));
        // then the train backlog drains in FIFO order
        let (_, t2) = q.lease("w0", Duration::from_millis(10)).unwrap();
        assert_eq!(t2.id(), 0);
    }

    #[test]
    fn nack_with_retry_after_delays_redelivery() {
        let q = TaskQueue::new(Duration::from_secs(10));
        q.push(train_task(1)).unwrap();
        let (l, _) = q.lease("w0", Duration::from_millis(10)).unwrap();
        let t0 = Instant::now();
        assert!(q.nack(l, Some(Duration::from_millis(80))));
        // not re-leasable before the delay elapses ...
        assert!(
            q.lease("w1", Duration::from_millis(20)).is_none(),
            "nacked task redelivered before its retry_after"
        );
        // ... but redelivered promptly once it does
        let (l2, t) = q.lease("w1", Duration::from_millis(500)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(80));
        assert_eq!(t.id(), 1);
        assert_eq!(l2.generation, 2, "nack counts as an attempt");
        assert!(q.complete(l2));
        assert_eq!(q.stats().requeues, 1);
    }

    #[test]
    fn delayed_nack_does_not_block_ready_tasks_behind_it() {
        let q = TaskQueue::new(Duration::from_secs(10));
        q.push(train_task(1)).unwrap();
        let (l, _) = q.lease("w0", Duration::from_millis(10)).unwrap();
        assert!(q.nack(l, Some(Duration::from_millis(200))));
        q.push(train_task(2)).unwrap();
        // task 2 is ready and must not starve behind the delayed task 1
        let (l2, t) = q.lease("w1", Duration::from_millis(20)).unwrap();
        assert_eq!(t.id(), 2);
        assert!(q.complete(l2));
    }

    #[test]
    fn idempotency_key_dedups_redelivered_publish() {
        let q = TaskQueue::new(Duration::from_secs(10));
        assert_eq!(q.push_idem(eval_task(1), "eval:p0:path1"), Ok(true));
        // a redelivered publish (retry after a lost ack) with the same key
        assert_eq!(q.push_idem(eval_task(1), "eval:p0:path1"), Ok(false));
        assert_eq!(q.stats().pending, 1, "duplicate must not double-enqueue");
        assert_eq!(q.stats().idem_dropped, 1);
        // dedup survives a checkpoint/restore cycle
        let q2 = TaskQueue::restore(&q.checkpoint_state(), Duration::from_secs(10)).unwrap();
        assert_eq!(q2.push_idem(eval_task(1), "eval:p0:path1"), Ok(false));
        assert_eq!(q2.stats().pending, 1);
        assert_eq!(q2.stats().idem_dropped, 2, "idem_dropped survives restore");
        // a different key is independent work
        assert_eq!(q2.push_idem(eval_task(2), "eval:p0:path2"), Ok(true));
        assert_eq!(q2.stats().pending, 2);
    }

    #[test]
    fn stale_retirements_are_counted() {
        let q = TaskQueue::new(Duration::from_millis(20));
        q.push(train_task(1)).unwrap();
        let (zombie, _) = q.lease("w0", Duration::from_millis(10)).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let (live, _) = q.lease("w1", Duration::from_millis(100)).unwrap();
        assert!(!q.complete(zombie));
        assert!(!q.fail(zombie));
        assert!(!q.nack(zombie, Some(Duration::from_millis(5))));
        assert_eq!(q.stats().stale_completes, 3);
        assert!(q.complete(live));
        // counter survives checkpoint/restore
        let q2 = TaskQueue::restore(&q.checkpoint_state(), Duration::from_millis(20)).unwrap();
        assert_eq!(q2.stats().stale_completes, 3);
    }

    #[test]
    fn concurrent_workers_complete_everything_despite_failures() {
        let q = std::sync::Arc::new(TaskQueue::new(Duration::from_millis(30)));
        for i in 0..40 {
            q.push(train_task(i)).unwrap();
        }
        let done = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        std::thread::scope(|s| {
            for w in 0..6 {
                let q = std::sync::Arc::clone(&q);
                let done = std::sync::Arc::clone(&done);
                s.spawn(move || {
                    let mut rng = crate::util::rng::Rng::new(w as u64);
                    while let Some((lease, _t)) = q.lease(&format!("w{w}"), Duration::from_millis(200)) {
                        if rng.f64() < 0.3 {
                            // simulate preemption: abandon (lease will expire)
                            continue;
                        }
                        if q.complete(lease) {
                            done.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                        }
                    }
                });
            }
            q.wait_idle(Duration::from_millis(5));
            q.close();
        });
        assert_eq!(done.load(std::sync::atomic::Ordering::SeqCst), 40);
        assert!(q.stats().requeues > 0);
    }

    #[test]
    fn dead_letter_after_max_attempts_unblocks_wait_idle() {
        let q = std::sync::Arc::new(TaskQueue::with_max_attempts(Duration::from_secs(10), 2));
        q.push(train_task(1)).unwrap();
        std::thread::scope(|s| {
            let q2 = std::sync::Arc::clone(&q);
            // a worker that fails the task every time it is handed out
            s.spawn(move || {
                while let Some((lease, _)) = q2.lease("w0", Duration::from_millis(200)) {
                    q2.fail(lease);
                }
            });
            // before dead-lettering existed this spun forever:
            // fail -> requeue -> fail -> requeue -> ...
            q.wait_idle(Duration::from_millis(5));
            q.close();
        });
        let stats = q.stats();
        assert_eq!(stats.dead, 1);
        assert_eq!(stats.completed, 0);
        // attempt 1 requeued, attempt 2 buried (not counted as a requeue)
        assert_eq!(stats.requeues, 1);
        assert_eq!(stats.buried, 1);
        assert_eq!(stats.reclaimed, 0, "explicit fail() is not a reclaim");
        assert_eq!(q.dead_tasks()[0].id(), 1);
        // terminal: never handed out again
        assert!(q.lease("w1", Duration::from_millis(5)).is_none());
    }

    #[test]
    fn expiry_buries_after_max_attempts_and_rejects_zombie() {
        let q = TaskQueue::with_max_attempts(Duration::from_millis(20), 1);
        q.push(train_task(3)).unwrap();
        let (l, _) = q.lease("w0", Duration::from_millis(10)).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(q.reclaim_expired(), 1);
        assert!(q.is_idle());
        assert_eq!(q.stats().dead, 1);
        assert_eq!(q.stats().requeues, 0);
        // the expiry was both a reclaim and (attempts exhausted) a burial
        assert_eq!(q.stats().reclaimed, 1);
        assert_eq!(q.stats().buried, 1);
        // zombie completion of a buried task is rejected
        assert!(!q.complete(l));
        assert_eq!(q.stats().completed, 0);
        assert_eq!(q.stats().stale_completes, 1);
    }

    #[test]
    fn restore_redelivers_open_lease_exactly_once() {
        let q = TaskQueue::new(Duration::from_secs(30));
        q.push(train_task(1)).unwrap();
        q.push(train_task(2)).unwrap();
        let (lease, leased) = q.lease("w0", Duration::from_millis(10)).unwrap();
        assert_eq!(leased.id(), 1);
        // checkpoint taken while the lease is open; server then "dies"
        let state = q.checkpoint_state();
        let q2 = TaskQueue::restore(&state, Duration::from_secs(30)).unwrap();
        let mut ids = vec![];
        while let Some((l, t)) = q2.lease("w1", Duration::from_millis(5)) {
            ids.push(t.id());
            assert!(q2.complete(l));
        }
        ids.sort();
        assert_eq!(ids, vec![1, 2], "open lease must be redelivered exactly once");
        // the pre-restore lease belongs to the dead server's world:
        // completing it against the restored queue must not double-retire
        assert!(!q2.complete(lease));
        assert_eq!(q2.stats().completed, 2);
    }

    #[test]
    fn restore_preserves_dead_letter_state() {
        let q = TaskQueue::with_max_attempts(Duration::from_secs(5), 1);
        q.push(train_task(1)).unwrap();
        q.push(train_task(2)).unwrap();
        let (l, _) = q.lease("w0", Duration::from_millis(10)).unwrap();
        q.fail(l); // attempt 1 of max 1 -> buried
        let state = q.checkpoint_state();
        let q2 = TaskQueue::restore(&state, Duration::from_secs(5)).unwrap();
        // the buried task stays terminal; only task 2 is delivered
        let (l2, t2) = q2.lease("w1", Duration::from_millis(5)).unwrap();
        assert_eq!(t2.id(), 2);
        assert!(q2.complete(l2));
        assert!(q2.lease("w1", Duration::from_millis(5)).is_none());
        assert_eq!(q2.stats().dead, 1);
        assert_eq!(q2.dead_tasks()[0].id(), 1);
    }

    #[test]
    fn restore_preserves_cumulative_fault_counters() {
        let q = TaskQueue::with_max_attempts(Duration::from_millis(20), 1);
        q.push(train_task(1)).unwrap();
        let _ = q.lease("w0", Duration::from_millis(10)).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(q.reclaim_expired(), 1); // reclaim #1, and burial #1
        let q2 = TaskQueue::restore(&q.checkpoint_state(), Duration::from_millis(20)).unwrap();
        let s = q2.stats();
        assert_eq!(s.reclaimed, 1, "reclaim history survives the restart");
        assert_eq!(s.buried, 1, "burial history survives the restart");
        // a checkpoint written before the counters existed restores to 0
        let old = Json::parse(
            r#"{"pending":[],"in_flight":[],"dead":[],"completed":0,"max_attempts":0}"#,
        )
        .unwrap();
        let q3 = TaskQueue::restore(&old, Duration::from_secs(5)).unwrap();
        assert_eq!(q3.stats().reclaimed, 0);
        assert_eq!(q3.stats().buried, 0);
        assert_eq!(q3.stats().stale_completes, 0);
        assert_eq!(q3.stats().idem_dropped, 0);
    }

    #[test]
    fn restore_bails_on_unrecognized_task_kind() {
        // Regression: the decoder used to coerce ANY unknown kind into an
        // eval task with default fields — a corrupted checkpoint silently
        // turned train work into garbage evals.
        let state = Json::parse(
            r#"{"pending":[{"kind":"trian","id":1,"phase":0,"path":0,
                "ckpt":"x.dpc"}],"in_flight":[],"dead":[],
                "completed":0,"max_attempts":0}"#,
        )
        .unwrap();
        let err = TaskQueue::restore(&state, Duration::from_secs(5)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unrecognized task kind"), "wrong error: {msg}");
        assert!(msg.contains("trian"), "error must name the bad kind: {msg}");
    }

    #[test]
    fn restore_then_bury_respects_prior_attempts() {
        // Regression: checkpoint_state dropped the per-task generations
        // map, so a poison task got a fresh max_attempts budget on every
        // server restart and could churn forever.
        let q = TaskQueue::with_max_attempts(Duration::from_secs(5), 2);
        q.push(train_task(1)).unwrap();
        let (l, _) = q.lease("w0", Duration::from_millis(10)).unwrap();
        q.fail(l); // attempt 1 of 2: requeued
        let state = q.checkpoint_state();
        let q2 = TaskQueue::restore(&state, Duration::from_secs(5)).unwrap();
        let (l2, t) = q2.lease("w1", Duration::from_millis(10)).unwrap();
        assert_eq!(t.id(), 1);
        assert_eq!(l2.generation, 2, "attempt count must survive the restart");
        q2.fail(l2); // attempt 2 of 2: buried, NOT requeued
        assert_eq!(q2.stats().dead, 1, "restart must not reset the dead-letter budget");
        assert_eq!(q2.stats().requeues, 0);
        assert!(q2.lease("w1", Duration::from_millis(5)).is_none());
        // old-format checkpoints (no generations field) start counts empty
        let old = Json::parse(
            r#"{"pending":[],"in_flight":[],"dead":[],"completed":0,"max_attempts":0}"#,
        )
        .unwrap();
        assert!(TaskQueue::restore(&old, Duration::from_secs(5)).is_ok());
    }

    #[test]
    fn checkpoint_restore_preserves_tasks() {
        let q = TaskQueue::new(Duration::from_secs(5));
        for i in 0..5 {
            q.push(train_task(i)).unwrap();
        }
        let _ = q.lease("w0", Duration::from_millis(10)).unwrap(); // one in flight
        let state = q.checkpoint_state();
        let q2 = TaskQueue::restore(&state, Duration::from_secs(5)).unwrap();
        // all 5 tasks are retrievable from the restored queue, with the
        // optimizer-state chain intact
        let mut ids = vec![];
        while let Some((l, t)) = q2.lease("w", Duration::from_millis(5)) {
            if let Task::Train(tt) = &t {
                assert_eq!(tt.opt_in.as_deref(), Some(std::path::Path::new("prev.opt.dpc")));
                assert_eq!(tt.opt_out, std::path::PathBuf::from("next.opt.dpc"));
            }
            ids.push(t.id());
            q2.complete(l);
        }
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }
}
