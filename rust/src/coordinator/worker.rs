//! Worker pool (paper §3.1, §3.4).
//!
//! Each worker is an OS thread standing in for an "island of compute": it
//! leases tasks from the queue, runs the inner optimization on the PJRT
//! engine, saves the result checkpoint, records it in the DB, and loops.
//! Tasks are completely independent — no worker-to-worker communication.
//!
//! Fault injection: with `preemption_prob`, a worker abandons its task
//! mid-flight (half gracefully — the task requeues immediately — and half
//! as a hard crash where only lease expiry recovers it); backup-pool
//! workers (paper §3.4, "low-tier priority") use a higher preemption
//! probability. With `crash_prob` a worker thread exits entirely, to be
//! resurrected by the [`crate::coordinator::monitor`].
//!
//! Determinism despite retries: a task's batch stream is seeded by
//! (phase, path), so a re-execution replays the identical inner steps and
//! every file write is an atomic rename — retried tasks are idempotent
//! (the optimizer-state chain reads `opt_in`, which no retry mutates).
//!
//! Module-sharded exchange (paper §3.3): after the inner phase the worker
//! splits `theta_before - theta_after` itself and ships ONE
//! `delta:L{l}E{e}` section per traversed module in a DPC2 checkpoint —
//! executors then fetch only the sections of modules they own. AdamW
//! moments (`m`/`v`) and the early-stopping eval copy of theta stay in
//! worker-local files and are never shipped.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{DilocoConfig, RunConfig};
use crate::coordinator::db::{CheckpointDb, CkptRow};
use crate::coordinator::queue::TaskQueue;
use crate::coordinator::task::{EvalTask, Task, TrainTask};
use crate::data::corpus::Corpus;
use crate::data::dataset::{BatchSampler, Sharding};
use crate::info;
use crate::params::checkpoint::{self, Checkpoint};
use crate::runtime::engine::Engine;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Shared context every worker thread gets.
pub struct WorkerCtx {
    pub engine: Arc<Engine>,
    pub queue: Arc<TaskQueue>,
    pub db: Arc<CheckpointDb>,
    pub corpus: Arc<Corpus>,
    pub sharding: Arc<Sharding>,
    /// Module/level/path algebra — the worker needs it to split its own
    /// delta into per-module sections (paper Algorithm 1 line 13).
    pub topo: Arc<Topology>,
    pub diloco: DilocoConfig,
    pub run: RunConfig,
    /// Early-stopping ledger: path -> (best holdout nll/token, ckpt).
    pub best: Mutex<HashMap<usize, (f64, PathBuf)>>,
    /// Push an eval task after each train checkpoint (early stopping on).
    pub eval_after_train: bool,
    /// Worker heartbeats (name -> unix-ish millis from a monotonic base).
    pub heartbeats: Mutex<HashMap<String, Instant>>,
    /// Probability a worker thread exits entirely per task (monitor test).
    pub crash_prob: f64,
    /// Deterministic fault injection (chaos harness); `None` in
    /// production. Consulted at task start, around checkpoint
    /// publication, and after the DPC2 file is written.
    pub chaos: Option<Arc<crate::chaos::injector::FaultInjector>>,
    pub shutting_down: AtomicBool,
    next_eval_id: AtomicU64,
}

impl WorkerCtx {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        engine: Arc<Engine>,
        queue: Arc<TaskQueue>,
        db: Arc<CheckpointDb>,
        corpus: Arc<Corpus>,
        sharding: Arc<Sharding>,
        topo: Arc<Topology>,
        diloco: DilocoConfig,
        run: RunConfig,
        eval_after_train: bool,
    ) -> Arc<WorkerCtx> {
        Arc::new(WorkerCtx {
            engine,
            queue,
            db,
            corpus,
            sharding,
            topo,
            diloco,
            run,
            best: Mutex::new(HashMap::new()),
            eval_after_train,
            heartbeats: Mutex::new(HashMap::new()),
            crash_prob: 0.0,
            chaos: None,
            shutting_down: AtomicBool::new(false),
            next_eval_id: AtomicU64::new(1 << 32),
        })
    }

    fn heartbeat(&self, name: &str) {
        self.heartbeats
            .lock()
            .unwrap()
            .insert(name.to_string(), Instant::now());
    }

    fn remove_heartbeat(&self, name: &str) {
        self.heartbeats.lock().unwrap().remove(name);
    }

    pub fn live_workers(&self) -> usize {
        self.heartbeats.lock().unwrap().len()
    }
}

/// Deterministic batch-stream seed for a task (idempotent retries).
fn task_seed(run_seed: u64, phase: usize, path: usize) -> u64 {
    run_seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((phase as u64) << 20)
        .wrapping_add(path as u64)
}

/// The worker main loop; returns when the queue closes or on injected crash.
pub fn worker_loop(ctx: Arc<WorkerCtx>, name: String, backup: bool) {
    let mut rng = Rng::new(
        ctx.run.seed ^ name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64)),
    );
    // Backup-pool devices are preempted "frequently" (paper §3.4).
    let preempt_p = if backup {
        (ctx.run.preemption_prob * 4.0).min(0.9)
    } else {
        ctx.run.preemption_prob
    };
    ctx.heartbeat(&name);
    loop {
        if ctx.shutting_down.load(Ordering::Relaxed) {
            break;
        }
        ctx.heartbeat(&name);
        let Some((lease, task)) = ctx.queue.lease(&name, Duration::from_millis(300)) else {
            let stats = ctx.queue.stats();
            if stats.pending == 0 && stats.in_flight == 0 && ctx.shutting_down.load(Ordering::Relaxed)
            {
                break;
            }
            // lease() returns None when closed+drained too
            if ctx.queue.is_idle() && ctx.shutting_down.load(Ordering::Relaxed) {
                break;
            }
            continue;
        };
        // ---- fault injection (deterministic chaos plan) ----
        if let Some(inj) = ctx.chaos.as_deref() {
            if let Task::Train(t) = &task {
                use crate::chaos::injector::TaskAction;
                match inj.on_task_start(t.phase, t.path) {
                    TaskAction::Run { delay: None } => {}
                    TaskAction::Run { delay: Some(d) } => std::thread::sleep(d),
                    TaskAction::Requeue => {
                        ctx.queue.fail(lease);
                        continue;
                    }
                    // hard crash of the task — lease expiry recovers it
                    TaskAction::Abandon => continue,
                }
            }
        }
        // ---- fault injection (probabilistic) ----
        if preempt_p > 0.0 && rng.f64() < preempt_p {
            if rng.f64() < 0.5 {
                ctx.queue.fail(lease); // graceful preemption
            } // else: hard crash of the task — lease expiry requeues it
            crate::debug!("worker", "{name} preempted on {}", task.describe());
            continue;
        }
        let res = match &task {
            Task::Train(t) => run_train(&ctx, t),
            Task::Eval(t) => run_eval(&ctx, t),
        };
        match res {
            Ok(()) => {
                ctx.queue.complete(lease);
            }
            Err(e) => {
                crate::warn_!("worker", "{name} failed {}: {e:#}", task.describe());
                ctx.queue.fail(lease);
            }
        }
        if ctx.crash_prob > 0.0 && rng.f64() < ctx.crash_prob {
            crate::debug!("worker", "{name} crashing (injected)");
            ctx.remove_heartbeat(&name);
            return;
        }
    }
    ctx.remove_heartbeat(&name);
}

fn run_train(ctx: &WorkerCtx, t: &TrainTask) -> Result<()> {
    // Input checkpoint carries only the assembled theta; read just that
    // section (random access — the file may hold more).
    let before = checkpoint::load_section(&t.ckpt_in, "theta")
        .with_context(|| format!("loading input ckpt for path {}", t.path))?;
    let n = ctx.engine.manifest.total_params;
    // Worker-local AdamW state from the previous phase. A missing file
    // when the coordinator says one exists is an error, not a silent
    // reset to zero moments.
    let (mut m, mut v) = match &t.opt_in {
        None => (vec![0.0; n], vec![0.0; n]),
        Some(p) => {
            let mut ock = Checkpoint::load(p)
                .with_context(|| format!("loading opt state for path {}", t.path))?;
            let m = ock
                .take("m")
                .with_context(|| format!("opt state {} missing m", p.display()))?;
            let v = ock
                .take("v")
                .with_context(|| format!("opt state {} missing v", p.display()))?;
            anyhow::ensure!(
                m.len() == n && v.len() == n,
                "opt state {} sized for a different model ({}/{} vs {n} params)",
                p.display(),
                m.len(),
                v.len()
            );
            (m, v)
        }
    };
    let mut theta = before.clone();
    let mc = ctx.engine.model();
    let shard = &ctx.sharding.shards[t.path];
    let mut sampler = BatchSampler::new(
        &shard.docs,
        mc.batch,
        mc.seq_train,
        task_seed(ctx.run.seed, t.phase, t.path),
    );
    let mut loss_sum = 0.0f64;
    let tau = mc.tau;
    // §Perf A/B (EXPERIMENTS.md): the fused lax.scan path wins when steps
    // are dispatch-bound (tiny models: +8%) but LOSES ~11% at path scale,
    // where the scan's carried-buffer copies outweigh the saved dispatches.
    // Per the measure->keep-or-revert protocol the per-step loop stays the
    // default; DIPACO_FUSED_STEPS=1 opts in.
    let fused = tau > 0
        && t.steps % tau == 0
        && ctx.engine.has("train_steps")
        && std::env::var("DIPACO_FUSED_STEPS").as_deref() == Ok("1");
    if fused {
        // §Perf fast path: tau steps per PJRT dispatch (lax.scan in HLO).
        for chunk in 0..t.steps / tau {
            let start = t.start_step + chunk * tau;
            let lrs: Vec<f32> = (1..=tau).map(|i| ctx.diloco.lr_at(start + i)).collect();
            let mut tokens = Vec::with_capacity(tau * mc.batch * mc.seq_train);
            for _ in 0..tau {
                let (b, _) = sampler.next_batch(&ctx.corpus);
                tokens.extend_from_slice(&b);
            }
            let (th2, m2, v2, losses) =
                ctx.engine
                    .train_steps(&theta, &m, &v, start as f32, &lrs, &tokens)?;
            theta = th2;
            m = m2;
            v = v2;
            loss_sum += losses.iter().map(|&l| l as f64).sum::<f64>();
        }
    } else {
        for i in 0..t.steps {
            let step = t.start_step + i + 1;
            let lr = ctx.diloco.lr_at(step);
            let (tokens, _) = sampler.next_batch(&ctx.corpus);
            let out = ctx
                .engine
                .train_step(&theta, &m, &v, step as f32, lr, &tokens)?;
            theta = out.theta;
            m = out.m;
            v = out.v;
            loss_sum += out.loss as f64;
        }
    }
    let mean_loss = (loss_sum / t.steps.max(1) as f64) as f32;
    // Worker-local optimizer state: stays on this "island of compute",
    // never shipped through the exchange.
    checkpoint::save_sections(&t.opt_out, &[("m", m.as_slice()), ("v", v.as_slice())])?;
    // Worker-local full-theta copy for the early-stopping evaluator.
    let eval_ckpt = if ctx.eval_after_train {
        let p = t.ckpt_out.with_extension("eval.dpc");
        checkpoint::save_sections(&p, &[("theta", theta.as_slice())])?;
        Some(p)
    } else {
        None
    };
    // Ship one outer-gradient section per traversed module (paper
    // Algorithm 1 line 13, split worker-side): executors fetch only the
    // sections of modules they own.
    let (ck, modules) = ctx.topo.delta_checkpoint(t.path, &before, &theta);
    let ck = ck.with("loss", vec![mean_loss]);
    // Simulated cross-DC checkpoint transfer (Effingo, paper §3.3).
    if ctx.run.transfer_delay_ms > 0 {
        std::thread::sleep(Duration::from_millis(ctx.run.transfer_delay_ms));
    }
    if let Some(inj) = ctx.chaos.as_deref() {
        inj.before_publish(t.phase, t.path);
    }
    ck.save(&t.ckpt_out)?;
    if let Some(inj) = ctx.chaos.as_deref() {
        // torn-write simulation: the executor's checksum verification —
        // not this worker — must detect the damage
        inj.corrupt_after_write(t.phase, t.path, &t.ckpt_out)?;
    }
    ctx.db.insert(CkptRow {
        rowid: 0,
        phase: t.phase,
        path_id: t.path,
        kind: "path".into(),
        file: t.ckpt_out.clone(),
        step: t.start_step + t.steps,
        loss: mean_loss,
        modules,
    });
    if let Some(inj) = ctx.chaos.as_deref() {
        inj.mark_published(t.phase, t.path);
    }
    if let Some(ckpt) = eval_ckpt {
        let id = ctx.next_eval_id.fetch_add(1, Ordering::Relaxed);
        ctx.queue.push(Task::Eval(EvalTask {
            id,
            phase: t.phase,
            path: t.path,
            ckpt,
        }));
    }
    Ok(())
}

fn run_eval(ctx: &WorkerCtx, t: &EvalTask) -> Result<()> {
    let shard = &ctx.sharding.shards[t.path];
    if shard.holdout.is_empty() {
        return Ok(());
    }
    let theta = checkpoint::load_section(&t.ckpt, "theta")
        .with_context(|| format!("loading eval theta for path {}", t.path))?;
    let mc = ctx.engine.model();
    let (nll, count) = crate::eval::eval_docs(
        &ctx.engine,
        &theta,
        &shard.holdout,
        &ctx.corpus,
        mc.seq_train,
    )?;
    let per_tok = nll / count.max(1) as f64;
    let mut best = ctx.best.lock().unwrap();
    let entry = best.entry(t.path).or_insert((f64::INFINITY, t.ckpt.clone()));
    if per_tok < entry.0 {
        *entry = (per_tok, t.ckpt.clone());
    }
    ctx.db.insert(CkptRow {
        rowid: 0,
        phase: t.phase,
        path_id: t.path,
        kind: "eval".into(),
        file: t.ckpt.clone(),
        step: 0,
        loss: per_tok as f32,
        modules: Vec::new(),
    });
    Ok(())
}

/// Handle to the pool for spawning/joining and monitor-driven respawns.
pub struct WorkerPool {
    ctx: Arc<WorkerCtx>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next_id: AtomicU64,
    pub target_workers: usize,
}

impl WorkerPool {
    pub fn spawn(ctx: Arc<WorkerCtx>, primary: usize, backup: usize) -> Arc<WorkerPool> {
        let pool = Arc::new(WorkerPool {
            ctx,
            handles: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
            target_workers: primary,
        });
        for _ in 0..primary {
            pool.spawn_worker(false);
        }
        for _ in 0..backup {
            pool.spawn_worker(true);
        }
        pool
    }

    pub fn spawn_worker(&self, backup: bool) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let name = if backup {
            format!("backup-{id}")
        } else {
            format!("worker-{id}")
        };
        let ctx = Arc::clone(&self.ctx);
        let h = std::thread::Builder::new()
            .name(name.clone())
            .spawn(move || worker_loop(ctx, name, backup))
            .expect("spawn worker");
        self.handles.lock().unwrap().push(h);
    }

    pub fn ctx(&self) -> &Arc<WorkerCtx> {
        &self.ctx
    }

    /// Signal shutdown and join all workers (queue must be closed too).
    pub fn shutdown(&self) {
        self.ctx.shutting_down.store(true, Ordering::Relaxed);
        self.ctx.queue.close();
        let mut handles = self.handles.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
        info!("pool", "worker pool shut down");
    }
}
