//! Worker pool (paper §3.1, §3.4).
//!
//! Each worker is an OS thread standing in for an "island of compute": it
//! leases tasks from the queue, runs the inner optimization on the PJRT
//! engine, saves the result checkpoint, records it in the DB, and loops.
//! Tasks are completely independent — no worker-to-worker communication.
//!
//! Fault injection: with `preemption_prob`, a worker abandons its task
//! mid-flight (half gracefully — the task requeues immediately — and half
//! as a hard crash where only lease expiry recovers it); backup-pool
//! workers (paper §3.4, "low-tier priority") use a higher preemption
//! probability. With `crash_prob` a worker thread exits entirely, to be
//! resurrected by the [`crate::coordinator::monitor`].
//!
//! Determinism despite retries: a task's batch stream is seeded by
//! (phase, path), so a re-execution replays the identical inner steps and
//! every file write is an atomic rename — retried tasks are idempotent
//! (the optimizer-state chain reads `opt_in`, which no retry mutates).
//!
//! Module-sharded exchange (paper §3.3): after the inner phase the worker
//! splits `theta_before - theta_after` itself and ships ONE
//! `delta:L{l}E{e}` section per traversed module in a DPC2 checkpoint —
//! executors then fetch only the sections of modules they own. AdamW
//! moments (`m`/`v`) and the early-stopping eval copy of theta stay in
//! worker-local files and are never shipped.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{DilocoConfig, RunConfig};
use crate::coordinator::db::{CheckpointDb, CkptRow};
use crate::coordinator::queue::TaskQueue;
use crate::coordinator::task::{EvalTask, Task, TrainTask};
use crate::data::corpus::Corpus;
use crate::data::dataset::{BatchSampler, Sharding};
use crate::info;
use crate::params::checkpoint::{self, Checkpoint};
use crate::runtime::engine::Engine;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Shared context every worker thread gets.
pub struct WorkerCtx {
    pub engine: Arc<Engine>,
    pub queue: Arc<TaskQueue>,
    pub db: Arc<CheckpointDb>,
    pub corpus: Arc<Corpus>,
    pub sharding: Arc<Sharding>,
    /// Module/level/path algebra — the worker needs it to split its own
    /// delta into per-module sections (paper Algorithm 1 line 13).
    pub topo: Arc<Topology>,
    pub diloco: DilocoConfig,
    pub run: RunConfig,
    /// Early-stopping ledger: path -> (best holdout nll/token, ckpt).
    pub best: Mutex<HashMap<usize, (f64, PathBuf)>>,
    /// Push an eval task after each train checkpoint (early stopping on).
    pub eval_after_train: bool,
    /// Worker heartbeats (name -> unix-ish millis from a monotonic base).
    pub heartbeats: Mutex<HashMap<String, Instant>>,
    /// Probability a worker thread exits entirely per task (monitor test).
    pub crash_prob: f64,
    /// Deterministic fault injection (chaos harness); `None` in
    /// production. Consulted at task start, around checkpoint
    /// publication, and after the DPC2 file is written.
    pub chaos: Option<Arc<crate::chaos::injector::FaultInjector>>,
    /// Section exchange plane this worker publishes through after each
    /// checkpoint save (local filesystem by default; the phase driver
    /// swaps in the TCP exchange when the run asks for it).
    pub transport: Arc<dyn crate::transport::SectionTransport>,
    pub shutting_down: AtomicBool,
    next_eval_id: AtomicU64,
}

impl WorkerCtx {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        engine: Arc<Engine>,
        queue: Arc<TaskQueue>,
        db: Arc<CheckpointDb>,
        corpus: Arc<Corpus>,
        sharding: Arc<Sharding>,
        topo: Arc<Topology>,
        diloco: DilocoConfig,
        run: RunConfig,
        eval_after_train: bool,
    ) -> Arc<WorkerCtx> {
        Arc::new(WorkerCtx {
            engine,
            queue,
            db,
            corpus,
            sharding,
            topo,
            diloco,
            run,
            best: Mutex::new(HashMap::new()),
            eval_after_train,
            heartbeats: Mutex::new(HashMap::new()),
            crash_prob: 0.0,
            chaos: None,
            transport: Arc::new(crate::transport::local::LocalTransport),
            shutting_down: AtomicBool::new(false),
            next_eval_id: AtomicU64::new(1 << 32),
        })
    }

    fn heartbeat(&self, name: &str) {
        self.heartbeats
            .lock()
            .unwrap()
            .insert(name.to_string(), Instant::now());
    }

    fn remove_heartbeat(&self, name: &str) {
        self.heartbeats.lock().unwrap().remove(name);
    }

    pub fn live_workers(&self) -> usize {
        self.heartbeats.lock().unwrap().len()
    }
}

/// Deterministic batch-stream seed for a task (idempotent retries).
fn task_seed(run_seed: u64, phase: usize, path: usize) -> u64 {
    run_seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((phase as u64) << 20)
        .wrapping_add(path as u64)
}

/// The worker main loop; returns when the queue closes or on injected crash.
pub fn worker_loop(ctx: Arc<WorkerCtx>, name: String, backup: bool) {
    let mut rng = Rng::new(
        ctx.run.seed ^ name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64)),
    );
    // Backup-pool devices are preempted "frequently" (paper §3.4).
    let preempt_p = if backup {
        (ctx.run.preemption_prob * 4.0).min(0.9)
    } else {
        ctx.run.preemption_prob
    };
    ctx.heartbeat(&name);
    loop {
        if ctx.shutting_down.load(Ordering::Relaxed) {
            break;
        }
        ctx.heartbeat(&name);
        let Some((lease, task)) = ctx.queue.lease(&name, Duration::from_millis(300)) else {
            let stats = ctx.queue.stats();
            if stats.pending == 0 && stats.in_flight == 0 && ctx.shutting_down.load(Ordering::Relaxed)
            {
                break;
            }
            // lease() returns None when closed+drained too
            if ctx.queue.is_idle() && ctx.shutting_down.load(Ordering::Relaxed) {
                break;
            }
            continue;
        };
        // ---- fault injection (deterministic chaos plan) ----
        if let Some(inj) = ctx.chaos.as_deref() {
            if let Task::Train(t) = &task {
                use crate::chaos::injector::TaskAction;
                match inj.on_task_start(t.phase, t.path) {
                    TaskAction::Run { delay: None } => {}
                    TaskAction::Run { delay: Some(d) } => std::thread::sleep(d),
                    TaskAction::Requeue => {
                        ctx.queue.fail(lease);
                        continue;
                    }
                    // hard crash of the task — lease expiry recovers it
                    TaskAction::Abandon => continue,
                }
            }
        }
        // ---- fault injection (probabilistic) ----
        if preempt_p > 0.0 && rng.f64() < preempt_p {
            if rng.f64() < 0.5 {
                ctx.queue.fail(lease); // graceful preemption
            } // else: hard crash of the task — lease expiry requeues it
            crate::debug!("worker", "{name} preempted on {}", task.describe());
            continue;
        }
        let res = match &task {
            Task::Train(t) => run_train(&ctx, t),
            Task::Eval(t) => run_eval(&ctx, t),
        };
        match res {
            Ok(()) => {
                // A false return is a zombie double-retire: the lease
                // expired, the task was reassigned, and this worker's
                // result arrived too late to count. It used to vanish
                // silently; now it is counted (QueueStats.stale_completes)
                // and logged.
                if !ctx.queue.complete(lease) {
                    crate::warn_!(
                        "worker",
                        "{name} completed {} on a stale lease (task was reassigned); \
                         result dropped",
                        task.describe()
                    );
                }
            }
            Err(e) => {
                crate::warn_!("worker", "{name} failed {}: {e:#}", task.describe());
                if !ctx.queue.fail(lease) {
                    crate::warn_!(
                        "worker",
                        "{name} failed {} on a stale lease (task was reassigned)",
                        task.describe()
                    );
                }
            }
        }
        if ctx.crash_prob > 0.0 && rng.f64() < ctx.crash_prob {
            crate::debug!("worker", "{name} crashing (injected)");
            ctx.remove_heartbeat(&name);
            return;
        }
    }
    ctx.remove_heartbeat(&name);
}

fn run_train(ctx: &WorkerCtx, t: &TrainTask) -> Result<()> {
    // Input checkpoint carries only the assembled theta; read just that
    // section (random access — the file may hold more).
    let before = checkpoint::load_section(&t.ckpt_in, "theta")
        .with_context(|| format!("loading input ckpt for path {}", t.path))?;
    let n = ctx.engine.manifest.total_params;
    // Worker-local AdamW state from the previous phase. A missing file
    // when the coordinator says one exists is an error, not a silent
    // reset to zero moments.
    let (mut m, mut v) = match &t.opt_in {
        None => (vec![0.0; n], vec![0.0; n]),
        Some(p) => {
            let mut ock = Checkpoint::load(p)
                .with_context(|| format!("loading opt state for path {}", t.path))?;
            let m = ock
                .take("m")
                .with_context(|| format!("opt state {} missing m", p.display()))?;
            let v = ock
                .take("v")
                .with_context(|| format!("opt state {} missing v", p.display()))?;
            anyhow::ensure!(
                m.len() == n && v.len() == n,
                "opt state {} sized for a different model ({}/{} vs {n} params)",
                p.display(),
                m.len(),
                v.len()
            );
            (m, v)
        }
    };
    let mut theta = before.clone();
    let mc = ctx.engine.model();
    let shard = &ctx.sharding.shards[t.path];
    let mut sampler = BatchSampler::new(
        &shard.docs,
        mc.batch,
        mc.seq_train,
        task_seed(ctx.run.seed, t.phase, t.path),
    );
    let mut loss_sum = 0.0f64;
    let tau = mc.tau;
    // ---- streaming outer sync setup (DESIGN.md "Streaming outer sync") ----
    // Module groups publish as their inner-step boundary passes; with
    // publish_groups <= 1 there is one group, published at phase end in
    // the legacy position (byte-identical output for the f32 codec).
    let codec = ctx.run.delta_codec;
    let groups = ctx.topo.publish_groups(t.path, ctx.run.publish_groups.max(1));
    let staggered = groups.len() > 1;
    // Residual chain: lossy codecs carry quantization error forward;
    // staggered publication additionally carries the movement a module
    // makes AFTER its group's snapshot (it keeps training with the path).
    let need_residual = codec.is_lossy() || staggered;
    let mut res_in: Option<Checkpoint> = match (&t.opt_in, need_residual) {
        (Some(p), true) => {
            let rp = p.with_extension("res.dpc");
            Some(Checkpoint::load(&rp).with_context(|| {
                format!(
                    "loading delta residual {} for path {} (required when codec={codec} \
                     or staggered publication is on)",
                    rp.display(),
                    t.path
                )
            })?)
        }
        _ => None, // genesis phase (zero residual), or exact whole-phase f32
    };
    // boundary g: publish group g once this many inner steps are done
    let bounds: Vec<usize> = (1..=groups.len()).map(|g| t.steps * g / groups.len()).collect();
    let mut published = 0usize;
    let mut res_out: Vec<(String, Vec<f32>)> = Vec::new();
    let mut snaps: Vec<(usize, crate::topology::ModuleId, Vec<f32>)> = Vec::new();
    // §Perf A/B (EXPERIMENTS.md): the fused lax.scan path wins when steps
    // are dispatch-bound (tiny models: +8%) but LOSES ~11% at path scale,
    // where the scan's carried-buffer copies outweigh the saved dispatches.
    // Per the measure->keep-or-revert protocol the per-step loop stays the
    // default; DIPACO_FUSED_STEPS=1 opts in.
    let fused = tau > 0
        && t.steps % tau == 0
        && ctx.engine.has("train_steps")
        && std::env::var("DIPACO_FUSED_STEPS").as_deref() == Ok("1");
    if fused {
        // §Perf fast path: tau steps per PJRT dispatch (lax.scan in HLO).
        for chunk in 0..t.steps / tau {
            let start = t.start_step + chunk * tau;
            let lrs: Vec<f32> = (1..=tau).map(|i| ctx.diloco.lr_at(start + i)).collect();
            let mut tokens = Vec::with_capacity(tau * mc.batch * mc.seq_train);
            for _ in 0..tau {
                let (b, _) = sampler.next_batch(&ctx.corpus);
                tokens.extend_from_slice(&b);
            }
            let (th2, m2, v2, losses) =
                ctx.engine
                    .train_steps(&theta, &m, &v, start as f32, &lrs, &tokens)?;
            theta = th2;
            m = m2;
            v = v2;
            loss_sum += losses.iter().map(|&l| l as f64).sum::<f64>();
            let done = (chunk + 1) * tau;
            while published + 1 < groups.len() && bounds[published] <= done {
                let loss_now = (loss_sum / done as f64) as f32;
                publish_group(
                    ctx, t, published, false, &groups[published], &before, &theta,
                    &mut res_in, &mut res_out, &mut snaps, need_residual, loss_now,
                    t.start_step + done,
                )?;
                published += 1;
            }
        }
    } else {
        for i in 0..t.steps {
            let step = t.start_step + i + 1;
            let lr = ctx.diloco.lr_at(step);
            let (tokens, _) = sampler.next_batch(&ctx.corpus);
            let out = ctx
                .engine
                .train_step(&theta, &m, &v, step as f32, lr, &tokens)?;
            theta = out.theta;
            m = out.m;
            v = out.v;
            loss_sum += out.loss as f64;
            while published + 1 < groups.len() && bounds[published] <= i + 1 {
                let loss_now = (loss_sum / (i + 1) as f64) as f32;
                publish_group(
                    ctx, t, published, false, &groups[published], &before, &theta,
                    &mut res_in, &mut res_out, &mut snaps, need_residual, loss_now,
                    t.start_step + i + 1,
                )?;
                published += 1;
            }
        }
    }
    let mean_loss = (loss_sum / t.steps.max(1) as f64) as f32;
    // Worker-local optimizer state: stays on this "island of compute",
    // never shipped through the exchange.
    checkpoint::save_sections(&t.opt_out, &[("m", m.as_slice()), ("v", v.as_slice())])?;
    // Worker-local full-theta copy for the early-stopping evaluator.
    let eval_ckpt = if ctx.eval_after_train {
        let p = t.ckpt_out.with_extension("eval.dpc");
        checkpoint::save_sections(&p, &[("theta", theta.as_slice())])?;
        Some(p)
    } else {
        None
    };
    // Ship one outer-gradient section per traversed module (paper
    // Algorithm 1 line 13, split worker-side): executors fetch only the
    // sections of modules they own. Any group whose boundary the loop
    // already passed is published; the FINAL group publishes here, in the
    // legacy position — with one group this is exactly the old whole-path
    // checkpoint, byte for byte under the f32 codec.
    while published < groups.len() {
        let last = published + 1 == groups.len();
        publish_group(
            ctx, t, published, last, &groups[published], &before, &theta, &mut res_in,
            &mut res_out, &mut snaps, need_residual, mean_loss,
            t.start_step + t.steps,
        )?;
        published += 1;
    }
    // Error-feedback residual for the NEXT phase: quantization error per
    // module, plus — for groups published before the phase ended — the
    // movement their modules made after the snapshot (snapshot - final,
    // in the delta's before-minus-after convention). Worker-local, like
    // the optimizer state; never shipped.
    if need_residual {
        let mut fin = Vec::new();
        for (idx, m, snap) in &snaps {
            ctx.topo.extract_into(m.level, &theta, &mut fin);
            let r = &mut res_out[*idx].1;
            for (ri, (s, f)) in r.iter_mut().zip(snap.iter().zip(&fin)) {
                *ri += s - f;
            }
        }
        let refs: Vec<(&str, &[f32])> =
            res_out.iter().map(|(n, d)| (n.as_str(), d.as_slice())).collect();
        checkpoint::save_sections(&t.opt_out.with_extension("res.dpc"), &refs)?;
    }
    if let Some(ckpt) = eval_ckpt {
        let id = ctx.next_eval_id.fetch_add(1, Ordering::Relaxed);
        // One eval per (phase, path), no matter how many times a zombie
        // re-execution of this train task reaches this line: the
        // idempotency key dedups redelivered publishes. And a closed
        // queue means shutdown is draining — dropping the eval is the
        // clean exit (it used to assert and take the coordinator down).
        let idem = format!("eval:p{}:path{}", t.phase, t.path);
        match ctx.queue.push_idem(
            Task::Eval(EvalTask {
                id,
                phase: t.phase,
                path: t.path,
                ckpt,
            }),
            &idem,
        ) {
            Ok(true) => {}
            Ok(false) => crate::debug!(
                "worker",
                "eval for phase {} path {} already enqueued (deduped by key {idem})",
                t.phase,
                t.path
            ),
            Err(_closed) => crate::debug!(
                "worker",
                "queue closed; dropping eval for phase {} path {} (clean shutdown drain)",
                t.phase,
                t.path
            ),
        }
    }
    Ok(())
}

/// Publish one module group's delta sections (streaming outer sync).
///
/// Non-final groups go to a side file (`<ckpt_out>.g{gid}.dpc`) under
/// kind `path:g{gid}` with the group's modules as row metadata — the
/// executor reduces them while the worker keeps stepping. The final
/// group goes to `ckpt_out` itself in the legacy position: it carries
/// the `loss` section, the simulated transfer delay, and the chaos
/// publication hooks (exactly one before_publish/mark_published pair per
/// task, so fault plans keep their one-fault-per-path semantics). With a
/// single group its kind is plain `path`, preserving the phase-synchronous
/// wire format bit for bit under the f32 codec.
///
/// Every published delta is `module_delta(before, theta_now) + residual_in`,
/// encoded under the run codec; the encoder's error-feedback residual is
/// collected into `res_out` (non-final groups also snapshot the module's
/// current params so the post-snapshot movement can be folded in at phase
/// end — see `run_train`).
#[allow(clippy::too_many_arguments)]
fn publish_group(
    ctx: &WorkerCtx,
    t: &TrainTask,
    gid: usize,
    last: bool,
    group: &[crate::topology::ModuleId],
    before: &[f32],
    theta: &[f32],
    res_in: &mut Option<Checkpoint>,
    res_out: &mut Vec<(String, Vec<f32>)>,
    snaps: &mut Vec<(usize, crate::topology::ModuleId, Vec<f32>)>,
    need_residual: bool,
    loss_now: f32,
    step_now: usize,
) -> Result<()> {
    let codec = ctx.run.delta_codec;
    let mut ck = Checkpoint::new();
    let mut modules = Vec::with_capacity(group.len());
    let mut delta = Vec::new();
    for &m in group {
        ctx.topo.module_delta_into(m, before, theta, &mut delta);
        if let Some(rck) = res_in.as_mut() {
            let r = rck.take(&format!("res:{m}")).with_context(|| {
                format!("delta residual for path {} missing section res:{m}", t.path)
            })?;
            anyhow::ensure!(
                r.len() == delta.len(),
                "residual res:{m} sized {} vs module size {}",
                r.len(),
                delta.len()
            );
            for (d, ri) in delta.iter_mut().zip(&r) {
                *d += ri;
            }
        }
        let (wire, qres) = checkpoint::encode_delta_feedback(codec, &delta);
        if need_residual {
            if !last {
                snaps.push((res_out.len(), m, ctx.topo.extract(m.level, theta)));
            }
            res_out.push((format!("res:{m}"), qres));
        }
        modules.push(m);
        ck = ck.with(&m.delta_section(), wire);
    }
    let (file, kind) = if last {
        let kind = if gid == 0 { "path".to_string() } else { format!("path:g{gid}") };
        (t.ckpt_out.clone(), kind)
    } else {
        (
            t.ckpt_out.with_extension(format!("g{gid}.dpc")),
            format!("path:g{gid}"),
        )
    };
    if last {
        ck = ck.with("loss", vec![loss_now]);
        // Simulated cross-DC checkpoint transfer (Effingo, paper §3.3).
        if ctx.run.transfer_delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(ctx.run.transfer_delay_ms));
        }
        if let Some(inj) = ctx.chaos.as_deref() {
            inj.before_publish(t.phase, t.path);
        }
    }
    ck.save(&file)?;
    if last {
        if let Some(inj) = ctx.chaos.as_deref() {
            // torn-write simulation: the executor's checksum verification —
            // not this worker — must detect the damage
            inj.corrupt_after_write(t.phase, t.path, &file)?;
        }
    }
    // Ship the group's sections through the exchange plane BEFORE the DB
    // row exists, so a row never references sections the plane cannot
    // serve. Local transport is a no-op (the save's rename published).
    ctx.transport
        .publish(
            &crate::transport::PublishCtx {
                phase: t.phase,
                path: t.path,
                kind: kind.clone(),
            },
            &file,
            &modules,
        )
        .with_context(|| {
            format!(
                "publishing sections of {} for path {}",
                file.display(),
                t.path
            )
        })?;
    ctx.db.insert(CkptRow {
        rowid: 0,
        phase: t.phase,
        path_id: t.path,
        kind,
        file,
        step: step_now,
        loss: loss_now,
        modules,
    });
    if last {
        if let Some(inj) = ctx.chaos.as_deref() {
            inj.mark_published(t.phase, t.path);
        }
    }
    Ok(())
}

fn run_eval(ctx: &WorkerCtx, t: &EvalTask) -> Result<()> {
    let shard = &ctx.sharding.shards[t.path];
    if shard.holdout.is_empty() {
        return Ok(());
    }
    let theta = checkpoint::load_section(&t.ckpt, "theta")
        .with_context(|| format!("loading eval theta for path {}", t.path))?;
    let mc = ctx.engine.model();
    let (nll, count) = crate::eval::eval_docs(
        &ctx.engine,
        &theta,
        &shard.holdout,
        &ctx.corpus,
        mc.seq_train,
    )?;
    let per_tok = nll / count.max(1) as f64;
    let mut best = ctx.best.lock().unwrap();
    let entry = best.entry(t.path).or_insert((f64::INFINITY, t.ckpt.clone()));
    if per_tok < entry.0 {
        *entry = (per_tok, t.ckpt.clone());
    }
    ctx.db.insert(CkptRow {
        rowid: 0,
        phase: t.phase,
        path_id: t.path,
        kind: "eval".into(),
        file: t.ckpt.clone(),
        step: 0,
        loss: per_tok as f32,
        modules: Vec::new(),
    });
    Ok(())
}

/// Handle to the pool for spawning/joining and monitor-driven respawns.
pub struct WorkerPool {
    ctx: Arc<WorkerCtx>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next_id: AtomicU64,
    pub target_workers: usize,
}

impl WorkerPool {
    pub fn spawn(ctx: Arc<WorkerCtx>, primary: usize, backup: usize) -> Arc<WorkerPool> {
        let pool = Arc::new(WorkerPool {
            ctx,
            handles: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
            target_workers: primary,
        });
        for _ in 0..primary {
            pool.spawn_worker(false);
        }
        for _ in 0..backup {
            pool.spawn_worker(true);
        }
        pool
    }

    pub fn spawn_worker(&self, backup: bool) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let name = if backup {
            format!("backup-{id}")
        } else {
            format!("worker-{id}")
        };
        let ctx = Arc::clone(&self.ctx);
        let h = std::thread::Builder::new()
            .name(name.clone())
            .spawn(move || worker_loop(ctx, name, backup))
            .expect("spawn worker");
        self.handles.lock().unwrap().push(h);
    }

    pub fn ctx(&self) -> &Arc<WorkerCtx> {
        &self.ctx
    }

    /// Signal shutdown and join all workers (queue must be closed too).
    pub fn shutdown(&self) {
        self.ctx.shutting_down.store(true, Ordering::Relaxed);
        self.ctx.queue.close();
        let mut handles = self.handles.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
        info!("pool", "worker pool shut down");
    }
}
