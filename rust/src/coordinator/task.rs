//! Task types flowing through the coordinator (paper §3, Figure 6).

use std::path::PathBuf;

/// One inner-optimization assignment: train `path` on its shard for
/// `steps` inner steps starting from checkpoint `ckpt_in` (paper §3.1:
/// "each of which involves training a path for a specific number of steps
/// from a given checkpoint").
#[derive(Debug, Clone, PartialEq)]
pub struct TrainTask {
    pub id: u64,
    pub phase: usize,
    pub path: usize,
    /// Inner steps to run (tau).
    pub steps: usize,
    /// Global inner-step counter at task start (drives the LR schedule and
    /// AdamW bias correction).
    pub start_step: usize,
    /// Input checkpoint (assembled path parameters, `theta` section only —
    /// optimizer state travels through the worker-local `opt_*` files).
    pub ckpt_in: PathBuf,
    /// Where to write the shipped result checkpoint: one
    /// `delta:L{l}E{e}` section per traversed module plus `loss`.
    pub ckpt_out: PathBuf,
    /// Worker-local AdamW state (`m`/`v`) from the previous phase; `None`
    /// on a path's first phase (the worker starts from zero moments —
    /// explicit, so a *lost* state file errors loudly instead of being
    /// silently treated as genesis). Never shipped to the executors.
    pub opt_in: Option<PathBuf>,
    /// Where the worker writes this phase's AdamW state. Distinct from
    /// `opt_in` so retried tasks stay idempotent.
    pub opt_out: PathBuf,
}

/// Evaluation assignment: score a saved path checkpoint on its shard
/// holdout (early stopping, paper §2.7) — enqueued when the train
/// checkpoint lands (Figure 6, teal arrow).
#[derive(Debug, Clone, PartialEq)]
pub struct EvalTask {
    pub id: u64,
    pub phase: usize,
    pub path: usize,
    pub ckpt: PathBuf,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Task {
    Train(TrainTask),
    Eval(EvalTask),
}

impl Task {
    pub fn id(&self) -> u64 {
        match self {
            Task::Train(t) => t.id,
            Task::Eval(t) => t.id,
        }
    }

    pub fn describe(&self) -> String {
        match self {
            Task::Train(t) => format!("train[phase={} path={} steps={}]", t.phase, t.path, t.steps),
            Task::Eval(t) => format!("eval[phase={} path={}]", t.phase, t.path),
        }
    }
}
