//! Checkpoint-metadata database — the Spanner stand-in (paper §3, blue box
//! in Figure 6): "the path to the checkpoint, along with the metadata of
//! the checkpoint (e.g., path ID, outer step ID, etc.), is recorded in a
//! database table. This enables other components to query the checkpoint
//! file path for a given path."
//!
//! Rows carry **module-level metadata** (`modules`: the `ModuleId`s whose
//! `delta:L{l}E{e}` sections the checkpoint file contains), so an
//! outer-optimization executor can decide which sections to fetch from a
//! row without opening the file — the module-sharded parameter plane's
//! equivalent of a column index.
//!
//! Consumers (outer-optimization executors, evaluators) either poll with a
//! monotonically increasing row id (`rows_since`) or subscribe to a
//! channel for push notifications — the "load training checkpoints as soon
//! as they appear in the table" behaviour that online averaging needs.
//! Insert/lookup go through a `(phase, path_id, kind)` hash index (insert
//! runs on every task completion; a linear history scan there is O(rows)
//! per task and was the coordinator's only quadratic path). State persists
//! to JSON for crash recovery.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::Sender;
use std::sync::Mutex;

use crate::topology::ModuleId;
use crate::util::json::Json;
use anyhow::{Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct CkptRow {
    pub rowid: u64,
    pub phase: usize,
    pub path_id: usize,
    pub kind: String, // "path" (worker output) | "eval" | "module" (outer output)
    pub file: PathBuf,
    pub step: usize,
    pub loss: f32,
    /// Modules whose `delta:` sections the file carries (empty for rows
    /// whose checkpoints are not module-sectioned, e.g. eval rows).
    pub modules: Vec<ModuleId>,
}

#[derive(Default)]
struct Inner {
    rows: Vec<CkptRow>,
    /// (phase, path_id, kind) -> index into `rows`.
    index: HashMap<(usize, usize, String), usize>,
    subscribers: Vec<Sender<CkptRow>>,
}

#[derive(Default)]
pub struct CheckpointDb {
    inner: Mutex<Inner>,
}

impl CheckpointDb {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a row; fan out to subscribers. Duplicate (phase, path, kind)
    /// rows are dropped (idempotent writes from retried tasks).
    pub fn insert(&self, mut row: CkptRow) -> u64 {
        let mut g = self.inner.lock().unwrap();
        let key = (row.phase, row.path_id, row.kind.clone());
        if let Some(&i) = g.index.get(&key) {
            return g.rows[i].rowid;
        }
        let idx = g.rows.len();
        row.rowid = idx as u64 + 1;
        g.index.insert(key, idx);
        g.rows.push(row.clone());
        g.subscribers.retain(|s| s.send(row.clone()).is_ok());
        row.rowid
    }

    /// Rows with rowid > `since`, oldest first.
    pub fn rows_since(&self, since: u64) -> Vec<CkptRow> {
        let g = self.inner.lock().unwrap();
        g.rows.iter().filter(|r| r.rowid > since).cloned().collect()
    }

    pub fn query(&self, phase: usize, kind: &str) -> Vec<CkptRow> {
        let g = self.inner.lock().unwrap();
        g.rows
            .iter()
            .filter(|r| r.phase == phase && r.kind == kind)
            .cloned()
            .collect()
    }

    /// Rows of `phase` whose kind starts with `prefix`, oldest first.
    /// Streaming workers publish one row per module group under
    /// `path:g{i}` alongside (or instead of) a whole-path `path` row;
    /// `query_prefix(phase, "path")` picks up both without matching
    /// unrelated kinds like `eval`.
    pub fn query_prefix(&self, phase: usize, prefix: &str) -> Vec<CkptRow> {
        let g = self.inner.lock().unwrap();
        g.rows
            .iter()
            .filter(|r| r.phase == phase && r.kind.starts_with(prefix))
            .cloned()
            .collect()
    }

    pub fn lookup(&self, phase: usize, path_id: usize, kind: &str) -> Option<CkptRow> {
        let g = self.inner.lock().unwrap();
        g.index
            .get(&(phase, path_id, kind.to_string()))
            .map(|&i| g.rows[i].clone())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Push notifications for every future insert.
    pub fn subscribe(&self, tx: Sender<CkptRow>) {
        self.inner.lock().unwrap().subscribers.push(tx);
    }

    // ------------------------------------------------------- persistence

    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        Json::obj(vec![(
            "rows",
            Json::arr(g.rows.iter().map(|r| {
                Json::obj(vec![
                    ("rowid", Json::num(r.rowid as f64)),
                    ("phase", Json::num(r.phase as f64)),
                    ("path_id", Json::num(r.path_id as f64)),
                    ("kind", Json::str(r.kind.clone())),
                    ("file", Json::str(r.file.to_string_lossy())),
                    ("step", Json::num(r.step as f64)),
                    ("loss", Json::num(r.loss as f64)),
                    (
                        "modules",
                        Json::arr(r.modules.iter().map(|m| Json::str(m.to_string()))),
                    ),
                ])
            })),
        )])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<CheckpointDb> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).context("parsing db json")?;
        let db = CheckpointDb::new();
        {
            let mut g = db.inner.lock().unwrap();
            for r in j.req("rows")?.as_arr().context("rows")? {
                let row = CkptRow {
                    rowid: r.req("rowid")?.as_usize().unwrap_or(0) as u64,
                    phase: r.req("phase")?.as_usize().unwrap_or(0),
                    path_id: r.req("path_id")?.as_usize().unwrap_or(0),
                    kind: r.req("kind")?.as_str().unwrap_or("").to_string(),
                    file: r.req("file")?.as_str().unwrap_or("").into(),
                    step: r.req("step")?.as_usize().unwrap_or(0),
                    loss: r.req("loss")?.as_f64().unwrap_or(0.0) as f32,
                    // pre-DPC2 saved state has no modules field
                    modules: r
                        .get("modules")
                        .and_then(|a| a.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|m| m.as_str().and_then(ModuleId::parse))
                                .collect()
                        })
                        .unwrap_or_default(),
                };
                let idx = g.rows.len();
                g.index
                    .insert((row.phase, row.path_id, row.kind.clone()), idx);
                g.rows.push(row);
            }
        }
        Ok(db)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(phase: usize, path_id: usize, kind: &str) -> CkptRow {
        CkptRow {
            rowid: 0,
            phase,
            path_id,
            kind: kind.into(),
            file: format!("/gfs/p{phase}/path{path_id}.dpc").into(),
            step: 100,
            loss: 2.5,
            modules: vec![
                ModuleId { level: 0, expert: 0 },
                ModuleId {
                    level: 1,
                    expert: path_id,
                },
            ],
        }
    }

    #[test]
    fn insert_query_lookup() {
        let db = CheckpointDb::new();
        db.insert(row(0, 0, "path"));
        db.insert(row(0, 1, "path"));
        db.insert(row(1, 0, "path"));
        assert_eq!(db.query(0, "path").len(), 2);
        assert!(db.lookup(1, 0, "path").is_some());
        assert!(db.lookup(1, 1, "path").is_none());
    }

    #[test]
    fn query_prefix_matches_streamed_group_rows_not_eval() {
        let db = CheckpointDb::new();
        db.insert(row(0, 0, "path"));
        db.insert(row(0, 1, "path:g0"));
        db.insert(row(0, 1, "path:g1"));
        db.insert(row(0, 2, "eval"));
        db.insert(row(1, 3, "path"));
        let got = db.query_prefix(0, "path");
        assert_eq!(got.len(), 3);
        assert!(got.iter().all(|r| r.kind != "eval" && r.phase == 0));
    }

    #[test]
    fn duplicate_inserts_are_idempotent() {
        let db = CheckpointDb::new();
        let a = db.insert(row(0, 0, "path"));
        let b = db.insert(row(0, 0, "path")); // retried task
        assert_eq!(a, b);
        assert_eq!(db.len(), 1);
    }

    #[test]
    fn indexed_lookup_matches_scan_at_scale() {
        let db = CheckpointDb::new();
        for phase in 0..20 {
            for p in 0..50 {
                db.insert(row(phase, p, "path"));
                db.insert(row(phase, p, "eval"));
            }
        }
        assert_eq!(db.len(), 2000);
        let hit = db.lookup(13, 37, "path").unwrap();
        assert_eq!((hit.phase, hit.path_id), (13, 37));
        assert_eq!(hit.kind, "path");
        assert!(db.lookup(20, 0, "path").is_none());
        assert!(db.lookup(13, 37, "module").is_none());
    }

    #[test]
    fn rows_since_is_monotonic() {
        let db = CheckpointDb::new();
        for i in 0..5 {
            db.insert(row(0, i, "path"));
        }
        let newer = db.rows_since(3);
        assert_eq!(newer.len(), 2);
        assert!(newer.iter().all(|r| r.rowid > 3));
    }

    #[test]
    fn subscribers_get_pushed_rows() {
        let db = CheckpointDb::new();
        let (tx, rx) = std::sync::mpsc::channel();
        db.subscribe(tx);
        db.insert(row(2, 7, "path"));
        let got = rx.recv_timeout(std::time::Duration::from_millis(100)).unwrap();
        assert_eq!(got.path_id, 7);
        assert_eq!(got.phase, 2);
        assert_eq!(got.modules, row(2, 7, "path").modules);
    }

    #[test]
    fn persistence_roundtrip() {
        let db = CheckpointDb::new();
        db.insert(row(0, 0, "path"));
        db.insert(row(0, 1, "module"));
        let p = std::env::temp_dir().join(format!("dipaco-db-{}.json", std::process::id()));
        db.save(&p).unwrap();
        let db2 = CheckpointDb::load(&p).unwrap();
        assert_eq!(db2.len(), 2);
        assert_eq!(db2.query(0, "module").len(), 1);
        // module metadata survives persistence, and the rebuilt index works
        assert_eq!(db2.lookup(0, 0, "path").unwrap().modules, row(0, 0, "path").modules);
        let c = db2.insert(row(0, 0, "path"));
        assert_eq!(c, 1); // deduped against the reloaded index
    }
}
