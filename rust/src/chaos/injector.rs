//! Runtime fault delivery: turns a declarative [`FaultPlan`] into hooks
//! the worker/coordinator plumbing consults at well-defined points.
//!
//! Three hook sites, mirroring where real failures strike:
//!
//! * [`FaultInjector::on_task_start`] — right after a lease is granted,
//!   before any compute. Kill (abandon the lease), preempt (fail it),
//!   stall past expiry, or straggle.
//! * [`FaultInjector::before_publish`] / [`FaultInjector::mark_published`]
//!   — around the checkpoint save + DB insert. Delay or reorder
//!   publication (reorders block on a condvar until the dependency's
//!   `mark_published` arrives, with a 5s deadline so a buggy plan cannot
//!   deadlock the suite — a timeout is recorded as its own fired event).
//! * [`FaultInjector::corrupt_after_write`] — after the DPC2 file hits
//!   disk, before its row is published.
//!
//! Every fault is consumed on its *first* delivery: the retry of a
//! killed/preempted/expired task runs clean, which is exactly the
//! real-world shape (the replacement worker is healthy) and what keeps
//! requeue counts deterministic. Fired events are recorded as canonical
//! strings and returned sorted, so two runs of the same seed produce
//! byte-identical `ChaosReport`s regardless of thread interleaving.

use std::collections::HashSet;
use std::path::Path;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::chaos::corruptor;
use crate::chaos::plan::{Fault, FaultPlan};

/// What the worker should do with the task it just leased.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskAction {
    /// Run it, optionally sleeping first (straggler / lease-expiry hold).
    Run { delay: Option<Duration> },
    /// Graceful preemption: fail the lease so the task requeues now.
    Requeue,
    /// Hard crash: walk away without failing — lease expiry recovers it.
    Abandon,
}

struct InjState {
    pending: Vec<Fault>,
    fired: Vec<String>,
    published: HashSet<(usize, usize)>,
}

pub struct FaultInjector {
    state: Mutex<InjState>,
    cv: Condvar,
}

impl FaultInjector {
    pub fn new(plan: &FaultPlan) -> FaultInjector {
        FaultInjector {
            state: Mutex::new(InjState {
                pending: plan.faults.clone(),
                fired: Vec::new(),
                published: HashSet::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Consult (and consume) any task-start fault for `(phase, path)`.
    pub fn on_task_start(&self, phase: usize, path: usize) -> TaskAction {
        let mut g = self.state.lock().unwrap();
        let Some(idx) = g
            .pending
            .iter()
            .position(|f| f.task_start_target() == Some((phase, path)))
        else {
            return TaskAction::Run { delay: None };
        };
        let fault = g.pending.remove(idx);
        g.fired.push(fault.describe());
        match fault {
            Fault::KillWorker { .. } => TaskAction::Abandon,
            Fault::Preempt { .. } => TaskAction::Requeue,
            Fault::ExpireLease { hold_ms, .. } => TaskAction::Run {
                delay: Some(Duration::from_millis(hold_ms)),
            },
            Fault::Straggle { delay_ms, .. } => TaskAction::Run {
                delay: Some(Duration::from_millis(delay_ms)),
            },
            _ => unreachable!("task_start_target filtered to worker-side faults"),
        }
    }

    /// Block/sleep per any publication fault for `(phase, path)`. Called
    /// by the worker after computing the delta, before the checkpoint
    /// save + DB insert.
    pub fn before_publish(&self, phase: usize, path: usize) {
        let mut g = self.state.lock().unwrap();
        if let Some(idx) = g.pending.iter().position(|f| {
            matches!(f, Fault::DelayPublish { phase: fp, path: fq, .. } if *fp == phase && *fq == path)
        }) {
            let fault = g.pending.remove(idx);
            g.fired.push(fault.describe());
            let Fault::DelayPublish { delay_ms, .. } = fault else {
                unreachable!()
            };
            drop(g);
            std::thread::sleep(Duration::from_millis(delay_ms));
            g = self.state.lock().unwrap();
        }
        if let Some(idx) = g.pending.iter().position(|f| {
            matches!(f, Fault::ReorderPublish { phase: fp, then, .. } if *fp == phase && *then == path)
        }) {
            let fault = g.pending.remove(idx);
            g.fired.push(fault.describe());
            let Fault::ReorderPublish { first, .. } = fault else {
                unreachable!()
            };
            let deadline = Instant::now() + Duration::from_secs(5);
            while !g.published.contains(&(phase, first)) {
                let now = Instant::now();
                if now >= deadline {
                    g.fired
                        .push(format!("phase {phase}: reorder wait for path {first} timed out"));
                    break;
                }
                let (g2, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
                g = g2;
            }
        }
    }

    /// Damage the just-written checkpoint if the plan says so.
    pub fn corrupt_after_write(&self, phase: usize, path: usize, file: &Path) -> Result<()> {
        let mode = {
            let mut g = self.state.lock().unwrap();
            match g.pending.iter().position(|f| {
                matches!(f, Fault::Corrupt { phase: fp, path: fq, .. } if *fp == phase && *fq == path)
            }) {
                Some(idx) => {
                    let fault = g.pending.remove(idx);
                    g.fired.push(fault.describe());
                    let Fault::Corrupt { mode, .. } = fault else {
                        unreachable!()
                    };
                    Some(mode)
                }
                None => None,
            }
        };
        if let Some(mode) = mode {
            corruptor::corrupt_file(file, mode)?;
        }
        Ok(())
    }

    /// Record that `(phase, path)` has published its row (wakes any
    /// reorder waiter). Idempotent — duplicate publications from zombie
    /// workers are fine.
    pub fn mark_published(&self, phase: usize, path: usize) {
        let mut g = self.state.lock().unwrap();
        g.published.insert((phase, path));
        self.cv.notify_all();
    }

    /// Faults that actually fired, in canonical (sorted) order.
    pub fn fired_events(&self) -> Vec<String> {
        let mut v = self.state.lock().unwrap().fired.clone();
        v.sort();
        v
    }

    /// Planned faults that never got the chance to fire, sorted.
    pub fn unfired(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .state
            .lock()
            .unwrap()
            .pending
            .iter()
            .map(Fault::describe)
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn faults_fire_exactly_once() {
        let plan = FaultPlan::new(vec![
            Fault::KillWorker { phase: 0, path: 1 },
            Fault::Straggle {
                phase: 1,
                path: 0,
                delay_ms: 3,
            },
        ]);
        let inj = FaultInjector::new(&plan);
        // untargeted task runs clean
        assert_eq!(inj.on_task_start(0, 0), TaskAction::Run { delay: None });
        // first delivery eats the fault, the retry runs clean
        assert_eq!(inj.on_task_start(0, 1), TaskAction::Abandon);
        assert_eq!(inj.on_task_start(0, 1), TaskAction::Run { delay: None });
        assert_eq!(
            inj.on_task_start(1, 0),
            TaskAction::Run {
                delay: Some(Duration::from_millis(3))
            }
        );
        assert_eq!(inj.fired_events().len(), 2);
        assert!(inj.unfired().is_empty());
    }

    #[test]
    fn reorder_blocks_until_dependency_publishes() {
        let plan = FaultPlan::new(vec![Fault::ReorderPublish {
            phase: 0,
            first: 1,
            then: 0,
        }]);
        let inj = Arc::new(FaultInjector::new(&plan));
        let inj2 = Arc::clone(&inj);
        let waiter = std::thread::spawn(move || {
            let t0 = Instant::now();
            inj2.before_publish(0, 0); // must block until (0, 1) publishes
            inj2.mark_published(0, 0);
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(40));
        inj.before_publish(0, 1); // no fault on the dependency itself
        inj.mark_published(0, 1);
        let waited = waiter.join().unwrap();
        assert!(waited >= Duration::from_millis(30), "waiter returned early");
        let fired = inj.fired_events();
        assert_eq!(fired.len(), 1);
        assert!(!fired[0].contains("timed out"));
    }
}
