//! Runtime fault delivery: turns a declarative [`FaultPlan`] into hooks
//! the worker/coordinator plumbing consults at well-defined points.
//!
//! Three hook sites, mirroring where real failures strike:
//!
//! * [`FaultInjector::on_task_start`] — right after a lease is granted,
//!   before any compute. Kill (abandon the lease), preempt (fail it),
//!   stall past expiry, or straggle.
//! * [`FaultInjector::before_publish`] / [`FaultInjector::mark_published`]
//!   — around the checkpoint save + DB insert. Delay or reorder
//!   publication (reorders block on a condvar until the dependency's
//!   `mark_published` arrives, with a 5s deadline so a buggy plan cannot
//!   deadlock the suite — a timeout is recorded as its own fired event).
//! * [`FaultInjector::corrupt_after_write`] — after the DPC2 file hits
//!   disk, before its row is published.
//!
//! Every fault is consumed on its *first* delivery: the retry of a
//! killed/preempted/expired task runs clean, which is exactly the
//! real-world shape (the replacement worker is healthy) and what keeps
//! requeue counts deterministic. Fired events are recorded as canonical
//! strings and returned sorted, so two runs of the same seed produce
//! byte-identical `ChaosReport`s regardless of thread interleaving.

use std::collections::HashSet;
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::chaos::corruptor;
use crate::chaos::plan::{Fault, FaultPlan, ServeFault, ServeFaultPlan};
use crate::serve::server::PathExecutor;

/// What the transport client should do with the section frame it is
/// about to send (see [`crate::transport::tcp`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetAction {
    /// Send clean.
    Deliver,
    /// The frame is lost in flight: the client must treat the attempt as
    /// failed (without the server ever seeing it) and retry.
    Drop,
    /// The frame is held this long in flight before delivery.
    Delay(Duration),
    /// The frame is delivered twice (a retransmit race); the server's
    /// idempotency dedup must keep a single accumulation.
    Duplicate,
    /// The frame's payload tail is torn in flight (checksum kept from the
    /// clean bytes); the server must nack and the client re-send.
    Truncate,
}

/// What the worker should do with the task it just leased.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskAction {
    /// Run it, optionally sleeping first (straggler / lease-expiry hold).
    Run { delay: Option<Duration> },
    /// Graceful preemption: fail the lease so the task requeues now.
    Requeue,
    /// Hard crash: walk away without failing — lease expiry recovers it.
    Abandon,
}

struct InjState {
    pending: Vec<Fault>,
    fired: Vec<String>,
    published: HashSet<(usize, usize)>,
}

pub struct FaultInjector {
    state: Mutex<InjState>,
    cv: Condvar,
}

impl FaultInjector {
    pub fn new(plan: &FaultPlan) -> FaultInjector {
        FaultInjector {
            state: Mutex::new(InjState {
                pending: plan.faults.clone(),
                fired: Vec::new(),
                published: HashSet::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Consult (and consume) any task-start fault for `(phase, path)`.
    pub fn on_task_start(&self, phase: usize, path: usize) -> TaskAction {
        let mut g = self.state.lock().unwrap();
        let Some(idx) = g
            .pending
            .iter()
            .position(|f| f.task_start_target() == Some((phase, path)))
        else {
            return TaskAction::Run { delay: None };
        };
        let fault = g.pending.remove(idx);
        g.fired.push(fault.describe());
        match fault {
            Fault::KillWorker { .. } => TaskAction::Abandon,
            Fault::Preempt { .. } => TaskAction::Requeue,
            Fault::ExpireLease { hold_ms, .. } => TaskAction::Run {
                delay: Some(Duration::from_millis(hold_ms)),
            },
            Fault::Straggle { delay_ms, .. } => TaskAction::Run {
                delay: Some(Duration::from_millis(delay_ms)),
            },
            _ => unreachable!("task_start_target filtered to worker-side faults"),
        }
    }

    /// Block/sleep per any publication fault for `(phase, path)`. Called
    /// by the worker after computing the delta, before the checkpoint
    /// save + DB insert.
    pub fn before_publish(&self, phase: usize, path: usize) {
        let mut g = self.state.lock().unwrap();
        if let Some(idx) = g.pending.iter().position(|f| {
            matches!(f, Fault::DelayPublish { phase: fp, path: fq, .. } if *fp == phase && *fq == path)
        }) {
            let fault = g.pending.remove(idx);
            g.fired.push(fault.describe());
            let Fault::DelayPublish { delay_ms, .. } = fault else {
                unreachable!()
            };
            drop(g);
            std::thread::sleep(Duration::from_millis(delay_ms));
            g = self.state.lock().unwrap();
        }
        if let Some(idx) = g.pending.iter().position(|f| {
            matches!(f, Fault::ReorderPublish { phase: fp, then, .. } if *fp == phase && *then == path)
        }) {
            let fault = g.pending.remove(idx);
            g.fired.push(fault.describe());
            let Fault::ReorderPublish { first, .. } = fault else {
                unreachable!()
            };
            let deadline = Instant::now() + Duration::from_secs(5);
            while !g.published.contains(&(phase, first)) {
                let now = Instant::now();
                if now >= deadline {
                    g.fired
                        .push(format!("phase {phase}: reorder wait for path {first} timed out"));
                    break;
                }
                let (g2, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
                g = g2;
            }
        }
    }

    /// Consult (and consume) any transport fault for `(phase, path)`.
    /// Called by the TCP client once per section frame; the first frame
    /// of a faulted publish takes the hit, everything after runs clean —
    /// the consumed-once shape every other hook follows.
    pub fn on_net_send(&self, phase: usize, path: usize) -> NetAction {
        let mut g = self.state.lock().unwrap();
        let Some(idx) = g
            .pending
            .iter()
            .position(|f| f.net_target() == Some((phase, path)))
        else {
            return NetAction::Deliver;
        };
        let fault = g.pending.remove(idx);
        g.fired.push(fault.describe());
        match fault {
            Fault::NetDrop { .. } => NetAction::Drop,
            Fault::NetDelay { delay_ms, .. } => NetAction::Delay(Duration::from_millis(delay_ms)),
            Fault::NetDuplicate { .. } => NetAction::Duplicate,
            Fault::NetTruncate { .. } => NetAction::Truncate,
            _ => unreachable!("net_target filtered to transport faults"),
        }
    }

    /// Damage the just-written checkpoint if the plan says so.
    pub fn corrupt_after_write(&self, phase: usize, path: usize, file: &Path) -> Result<()> {
        let mode = {
            let mut g = self.state.lock().unwrap();
            match g.pending.iter().position(|f| {
                matches!(f, Fault::Corrupt { phase: fp, path: fq, .. } if *fp == phase && *fq == path)
            }) {
                Some(idx) => {
                    let fault = g.pending.remove(idx);
                    g.fired.push(fault.describe());
                    let Fault::Corrupt { mode, .. } = fault else {
                        unreachable!()
                    };
                    Some(mode)
                }
                None => None,
            }
        };
        if let Some(mode) = mode {
            corruptor::corrupt_file(file, mode)?;
        }
        Ok(())
    }

    /// Record that `(phase, path)` has published its row (wakes any
    /// reorder waiter). Idempotent — duplicate publications from zombie
    /// workers are fine.
    pub fn mark_published(&self, phase: usize, path: usize) {
        let mut g = self.state.lock().unwrap();
        g.published.insert((phase, path));
        self.cv.notify_all();
    }

    /// Faults that actually fired, in canonical (sorted) order.
    pub fn fired_events(&self) -> Vec<String> {
        let mut v = self.state.lock().unwrap().fired.clone();
        v.sort();
        v
    }

    /// Planned faults that never got the chance to fire, sorted.
    pub fn unfired(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .state
            .lock()
            .unwrap()
            .pending
            .iter()
            .map(Fault::describe)
            .collect();
        v.sort();
        v
    }
}

/// What [`ChaosExec`] should do with the forward call it is about to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForwardFault {
    /// Run clean.
    None,
    /// Panic mid-forward (payload prefixed `chaos-inject` so the quiet
    /// panic hook can silence it).
    Panic,
    /// Sleep, then fail the batch with an error.
    Wedge(Duration),
    /// Sleep, then run clean.
    Slow(Duration),
}

struct ServeInjState {
    /// `(fault, remaining budget)`; a fault moves to `fired` when its
    /// budget reaches zero.
    pending: Vec<(ServeFault, usize)>,
    fired: Vec<String>,
}

/// Serving-plane fault delivery: one shared injector consulted by every
/// path's [`ChaosExec`] at each forward call. Faults on the same path are
/// consumed in plan order, one budget unit per forward call, so a serial
/// scenario driver maps faults 1:1 onto its submissions.
pub struct ServeInjector {
    state: Mutex<ServeInjState>,
}

impl ServeInjector {
    pub fn new(plan: &ServeFaultPlan) -> ServeInjector {
        ServeInjector {
            state: Mutex::new(ServeInjState {
                pending: plan
                    .faults
                    .iter()
                    .map(|f| (f.clone(), f.batches()))
                    .collect(),
                fired: Vec::new(),
            }),
        }
    }

    /// Consume one budget unit of the first live fault on `path` (if
    /// any) and say how this forward call should misbehave.
    pub fn on_forward(&self, path: usize) -> ForwardFault {
        let mut g = self.state.lock().unwrap();
        let Some(idx) = g
            .pending
            .iter()
            .position(|(f, left)| f.path() == path && *left > 0)
        else {
            return ForwardFault::None;
        };
        g.pending[idx].1 -= 1;
        let (fault, left) = g.pending[idx].clone();
        if left == 0 {
            g.fired.push(fault.describe());
        }
        match fault {
            ServeFault::PanicExec { .. } => ForwardFault::Panic,
            ServeFault::WedgeBatch { wedge_ms, .. } => {
                ForwardFault::Wedge(Duration::from_millis(wedge_ms))
            }
            ServeFault::SlowExec { delay_ms, .. } => {
                ForwardFault::Slow(Duration::from_millis(delay_ms))
            }
        }
    }

    /// Faults whose whole budget was delivered, in canonical (sorted)
    /// order.
    pub fn fired_events(&self) -> Vec<String> {
        let mut v = self.state.lock().unwrap().fired.clone();
        v.sort();
        v
    }

    /// Faults with budget left undelivered (sorted) — a non-empty list
    /// means the scenario never drove enough traffic at the faulted path.
    pub fn unfired(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .state
            .lock()
            .unwrap()
            .pending
            .iter()
            .filter(|(_, left)| *left > 0)
            .map(|(f, _)| f.describe())
            .collect();
        v.sort();
        v
    }
}

/// Fault-injecting executor wrapper: delegates to the real executor,
/// except when the [`ServeInjector`] says this forward call misbehaves.
/// The panic payload is prefixed `chaos-inject` (see
/// `testkit::install_quiet_panic_hook`).
pub struct ChaosExec<E: PathExecutor> {
    path: usize,
    inner: E,
    injector: Arc<ServeInjector>,
}

impl<E: PathExecutor> ChaosExec<E> {
    pub fn new(path: usize, inner: E, injector: Arc<ServeInjector>) -> Self {
        ChaosExec {
            path,
            inner,
            injector,
        }
    }
}

impl<E: PathExecutor> PathExecutor for ChaosExec<E> {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn seq(&self) -> usize {
        self.inner.seq()
    }

    fn forward(&mut self, toks: &[i32], rows: usize) -> Result<Vec<(f64, usize)>> {
        match self.injector.on_forward(self.path) {
            ForwardFault::None => self.inner.forward(toks, rows),
            ForwardFault::Panic => {
                panic!("chaos-inject: executor panic on path {}", self.path)
            }
            ForwardFault::Wedge(d) => {
                std::thread::sleep(d);
                anyhow::bail!("chaos-inject: wedged batch killed on path {}", self.path)
            }
            ForwardFault::Slow(d) => {
                std::thread::sleep(d);
                self.inner.forward(toks, rows)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faults_fire_exactly_once() {
        let plan = FaultPlan::new(vec![
            Fault::KillWorker { phase: 0, path: 1 },
            Fault::Straggle {
                phase: 1,
                path: 0,
                delay_ms: 3,
            },
        ]);
        let inj = FaultInjector::new(&plan);
        // untargeted task runs clean
        assert_eq!(inj.on_task_start(0, 0), TaskAction::Run { delay: None });
        // first delivery eats the fault, the retry runs clean
        assert_eq!(inj.on_task_start(0, 1), TaskAction::Abandon);
        assert_eq!(inj.on_task_start(0, 1), TaskAction::Run { delay: None });
        assert_eq!(
            inj.on_task_start(1, 0),
            TaskAction::Run {
                delay: Some(Duration::from_millis(3))
            }
        );
        assert_eq!(inj.fired_events().len(), 2);
        assert!(inj.unfired().is_empty());
    }

    #[test]
    fn net_faults_fire_once_and_skip_other_hooks() {
        let plan = FaultPlan::new(vec![
            Fault::NetDrop { phase: 0, path: 1 },
            Fault::NetDelay {
                phase: 1,
                path: 0,
                delay_ms: 15,
            },
            Fault::NetDuplicate { phase: 1, path: 2 },
            Fault::NetTruncate { phase: 2, path: 0 },
        ]);
        let inj = FaultInjector::new(&plan);
        // net faults never strike the task-start hook
        assert_eq!(inj.on_task_start(0, 1), TaskAction::Run { delay: None });
        // untargeted send delivers clean
        assert_eq!(inj.on_net_send(0, 0), NetAction::Deliver);
        // first send takes the hit, the retry/next frame runs clean
        assert_eq!(inj.on_net_send(0, 1), NetAction::Drop);
        assert_eq!(inj.on_net_send(0, 1), NetAction::Deliver);
        assert_eq!(
            inj.on_net_send(1, 0),
            NetAction::Delay(Duration::from_millis(15))
        );
        assert_eq!(inj.on_net_send(1, 2), NetAction::Duplicate);
        assert_eq!(inj.on_net_send(2, 0), NetAction::Truncate);
        assert_eq!(inj.fired_events().len(), 4);
        assert!(inj.unfired().is_empty());
    }

    #[test]
    fn reorder_blocks_until_dependency_publishes() {
        let plan = FaultPlan::new(vec![Fault::ReorderPublish {
            phase: 0,
            first: 1,
            then: 0,
        }]);
        let inj = Arc::new(FaultInjector::new(&plan));
        let inj2 = Arc::clone(&inj);
        let waiter = std::thread::spawn(move || {
            let t0 = Instant::now();
            inj2.before_publish(0, 0); // must block until (0, 1) publishes
            inj2.mark_published(0, 0);
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(40));
        inj.before_publish(0, 1); // no fault on the dependency itself
        inj.mark_published(0, 1);
        let waited = waiter.join().unwrap();
        assert!(waited >= Duration::from_millis(30), "waiter returned early");
        let fired = inj.fired_events();
        assert_eq!(fired.len(), 1);
        assert!(!fired[0].contains("timed out"));
    }

    #[test]
    fn serve_faults_drain_budget_per_forward_call() {
        let plan = ServeFaultPlan::new(vec![
            ServeFault::PanicExec { path: 0, batches: 2 },
            ServeFault::SlowExec {
                path: 2,
                batches: 1,
                delay_ms: 25,
            },
        ]);
        let inj = ServeInjector::new(&plan);
        // untouched path always runs clean
        assert_eq!(inj.on_forward(1), ForwardFault::None);
        // path 0: two panics, then healed
        assert_eq!(inj.on_forward(0), ForwardFault::Panic);
        assert_eq!(inj.unfired().len(), 2, "budget not yet drained");
        assert_eq!(inj.on_forward(0), ForwardFault::Panic);
        assert_eq!(inj.on_forward(0), ForwardFault::None);
        assert_eq!(
            inj.fired_events(),
            vec!["path 0: panic executor for 2 batches".to_string()]
        );
        // path 2: one slow batch, then healed
        assert_eq!(
            inj.on_forward(2),
            ForwardFault::Slow(Duration::from_millis(25))
        );
        assert_eq!(inj.on_forward(2), ForwardFault::None);
        assert!(inj.unfired().is_empty());
        assert_eq!(inj.fired_events().len(), 2);
    }

    #[test]
    fn chaos_exec_panics_wedges_and_heals() {
        crate::testkit::install_quiet_panic_hook();
        struct OkExec;
        impl PathExecutor for OkExec {
            fn batch(&self) -> usize {
                1
            }
            fn seq(&self) -> usize {
                4
            }
            fn forward(&mut self, _t: &[i32], rows: usize) -> Result<Vec<(f64, usize)>> {
                Ok((0..rows).map(|_| (1.0, 3)).collect())
            }
        }
        let plan = ServeFaultPlan::new(vec![ServeFault::WedgeBatch {
            path: 0,
            batches: 1,
            wedge_ms: 5,
        }]);
        let inj = Arc::new(ServeInjector::new(&plan));
        let mut exec = ChaosExec::new(0, OkExec, Arc::clone(&inj));
        let err = exec.forward(&[0; 4], 1).unwrap_err();
        assert!(err.to_string().contains("wedged batch"), "{err:#}");
        // budget drained: next call is clean
        assert_eq!(exec.forward(&[0; 4], 1).unwrap().len(), 1);

        let panic_plan = ServeFaultPlan::new(vec![ServeFault::PanicExec { path: 1, batches: 1 }]);
        let inj = Arc::new(ServeInjector::new(&panic_plan));
        let mut exec = ChaosExec::new(1, OkExec, Arc::clone(&inj));
        let unwound =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| exec.forward(&[0; 4], 1)));
        assert!(unwound.is_err(), "PanicExec must unwind");
        assert!(exec.forward(&[0; 4], 1).is_ok());
        assert!(inj.unfired().is_empty());
    }
}
