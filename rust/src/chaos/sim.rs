//! Engine-free chaos simulation: the REAL coordinator plumbing —
//! [`TaskQueue`] leases, [`CheckpointDb`] pub/sub, DPC2 checkpoint files,
//! and the sharded [`run_phase_outer`] executors — driven by simulated
//! workers whose "inner optimization" is a cheap pure function of
//! `(seed, phase, path, theta)`.
//!
//! Why simulate the inner phase instead of running the PJRT engine? Two
//! reasons. First, the chaos suite must run everywhere tier-1 runs — no
//! AOT artifacts required. Second, and more fundamentally, the oracle
//! demands *bit-identical* convergence: the sim worker is idempotent by
//! construction (a zombie re-execution of a task recomputes the very same
//! bytes), which is the same contract the real worker honors via seeded
//! batch streams — here it is exact rather than merely reproducible, so
//! any divergence the oracle reports is attributable to the coordinator
//! plumbing under test, never to compute noise.
//!
//! What stays real is everything the faults actually strike: lease
//! handout/expiry/redelivery, generation-guarded retirement, DB dedup and
//! subscriber replay, DPC2 section writes + checksummed reads, module
//! sharding, and the buffered path-ordered outer reduce.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::chaos::injector::{FaultInjector, TaskAction};
use crate::chaos::plan::FaultPlan;
use crate::config::{DeltaCodec, DilocoConfig, TopologySpec};
use crate::coordinator::db::{CheckpointDb, CkptRow};
use crate::coordinator::outer::{
    collect_late_contribs, run_phase_outer, shard_modules, LateContrib, OuterConfig,
};
use crate::coordinator::queue::TaskQueue;
use crate::coordinator::task::{Task, TrainTask};
use crate::optim::Nesterov;
use crate::params::checkpoint::{self, Checkpoint};
use crate::params::manifest::Manifest;
use crate::topology::{ModuleId, ModuleStore, Topology};
use crate::transport::SectionTransport;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Everything that defines one simulated run. Faulted and reference runs
/// share a spec (identical seed) except where a scenario deliberately
/// varies the executor schedule (drop/re-join).
#[derive(Debug, Clone)]
pub struct SimSpec {
    pub seed: u64,
    pub phases: usize,
    pub workers: usize,
    pub lease_ms: u64,
    /// Outer-executor count per phase; the last entry repeats. A varying
    /// vec (e.g. `[2, 1, 2]`) models an executor dropping out and
    /// re-joining between phases — modules are re-sharded and each
    /// module's outer momentum must follow it.
    pub executors_per_phase: Vec<usize>,
    pub topo: TopologySpec,
    pub layers: usize,
    pub d: usize,
    /// Wire codec for worker delta sections (streaming outer sync).
    pub codec: DeltaCodec,
    /// Module groups per path for staggered publication; 0/1 = one
    /// whole-path row, the pre-streaming layout.
    pub publish_groups: usize,
    /// Straggler grace window for the outer executors (0 = wait forever).
    pub grace_ms: u64,
    /// `(phase, path)` pairs declared late up front: executors skip their
    /// rows in-phase and they merge into the NEXT phase's accumulation.
    pub declared_late: Vec<(usize, usize)>,
    /// Route section publication over the TCP exchange plane (loopback)
    /// instead of the shared filesystem. The oracle's bit-identical
    /// verdicts must hold either way.
    pub tcp: bool,
}

impl SimSpec {
    pub fn new(seed: u64) -> SimSpec {
        SimSpec {
            seed,
            phases: 3,
            workers: 3,
            lease_ms: 30_000,
            executors_per_phase: vec![2],
            topo: TopologySpec::grid(vec![2, 2]),
            layers: 4,
            d: 8,
            codec: DeltaCodec::F32,
            publish_groups: 0,
            grace_ms: 0,
            declared_late: Vec::new(),
            tcp: false,
        }
    }
}

/// Miniature manifest in the python layout (same shape the property
/// tests use); deterministic in `(n_layers, d)`.
pub fn sim_manifest_json(n_layers: usize, d: usize) -> String {
    let mut leaves = Vec::new();
    let mut off = 0usize;
    let mut push = |name: String, shape: Vec<usize>, off: &mut usize| {
        let size: usize = shape.iter().product();
        leaves.push(format!(
            r#"{{"name":"{name}","offset":{off},"size":{size},"shape":[{}]}}"#,
            shape
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
                .join(",")
        ));
        *off += size;
    };
    push("embed.tok".into(), vec![32, d], &mut off);
    push("embed.pos".into(), vec![16, d], &mut off);
    for i in 0..n_layers {
        push(format!("block{i}.attn.wq"), vec![d, d], &mut off);
        push(format!("block{i}.ln1.scale"), vec![d], &mut off);
        push(format!("block{i}.mlp.w1"), vec![d, 2 * d], &mut off);
    }
    push("head.w".into(), vec![d, 32], &mut off);
    format!(
        r#"{{"preset":"chaos","config":{{"vocab":32,"d_model":{d},"n_layers":{n_layers},
          "n_heads":2,"d_ff":{f},"seq_train":16,"seq_eval":16,"batch":1,"prefix":4,"d_head":{dh}}},
          "total_params":{off},"leaves":[{ls}],"entrypoints":[]}}"#,
        f = 2 * d,
        dh = d / 2,
        ls = leaves.join(",")
    )
}

pub fn sim_topology(spec: &SimSpec) -> Topology {
    let j = sim_manifest_json(spec.layers, spec.d);
    let man = Manifest::from_json(&Json::parse(&j).unwrap()).unwrap();
    Topology::build(&man, &spec.topo)
}

fn base_theta(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed).fork(0xBA5E);
    (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect()
}

/// The simulated inner phase: a pure function of `(seed, phase, path,
/// theta)`. Retried and zombie re-executions of a task therefore write
/// bit-identical deltas — exact idempotency, so the oracle's bitwise
/// comparison isolates coordinator bugs.
pub fn sim_after(seed: u64, phase: usize, path: usize, before: &[f32]) -> Vec<f32> {
    let stream = 0x515E ^ ((phase as u64) << 24) ^ path as u64;
    let mut rng = Rng::new(seed).fork(stream);
    before
        .iter()
        .map(|&b| 0.995 * b - 0.01 * rng.normal_f32(0.0, 1.0))
        .collect()
}

/// Result of one simulated run.
#[derive(Debug)]
pub struct SimOutcome {
    pub store: ModuleStore,
    /// Phases whose outer update completed (< spec.phases on abort).
    pub phases_run: usize,
    pub completed: u64,
    pub requeues: u64,
    pub dead: usize,
    /// The loud failure, if the run aborted (`{:#}`-formatted chain).
    pub error: Option<String>,
    /// Injected faults that fired, canonical sorted order.
    pub events: Vec<String>,
    /// Planned faults that never got the chance to fire, sorted.
    pub unfired: Vec<String>,
}

#[allow(clippy::too_many_arguments)]
fn sim_run_train(
    db: &CheckpointDb,
    topo: &Topology,
    injector: &FaultInjector,
    transport: Option<&crate::transport::tcp::TcpExchange>,
    seed: u64,
    codec: DeltaCodec,
    publish_groups: usize,
    t: &TrainTask,
) -> Result<()> {
    let before = checkpoint::load_section(&t.ckpt_in, "theta")
        .with_context(|| format!("sim worker loading input for path {}", t.path))?;
    let after = sim_after(seed, t.phase, t.path, &before);
    let groups = topo.publish_groups(t.path, publish_groups.max(1));
    let need_residual = codec.is_lossy() || groups.len() > 1;
    // Residual chain: sim tasks carry no optimizer state (`opt_in` is
    // None), so the previous phase's residual file is derived from the
    // run layout. It is a pure function of (seed, phases so far), and
    // phase t-1's files are immutable once its outer update ran, so
    // zombie re-executions of this task still write identical bytes.
    let mut res_in: Option<Checkpoint> = if need_residual && t.phase > 0 {
        let p = t
            .ckpt_out
            .parent()
            .and_then(Path::parent)
            .map(|root| {
                root.join(format!("phase{}", t.phase - 1))
                    .join(format!("path{}.opt.res.dpc", t.path))
            })
            .context("sim task ckpt_out has no phase dir parent")?;
        Some(
            Checkpoint::load(&p)
                .with_context(|| format!("sim worker loading residual {}", p.display()))?,
        )
    } else {
        None
    };
    let mut res_out: Vec<(String, Vec<f32>)> = Vec::new();
    let mut delta: Vec<f32> = Vec::new();
    let last_gid = groups.len() - 1;
    for (gid, group) in groups.iter().enumerate() {
        let last = gid == last_gid;
        // The sim inner phase is one pure jump, so every group snapshots
        // the same final theta; staggering here exercises the row
        // plumbing, not partial movement.
        let mut ck = Checkpoint::new();
        let mut modules = Vec::with_capacity(group.len());
        for &m in group {
            topo.module_delta_into(m, &before, &after, &mut delta);
            if let Some(rck) = res_in.as_mut() {
                let r = rck
                    .take(&format!("res:{m}"))
                    .with_context(|| format!("sim residual missing section res:{m}"))?;
                anyhow::ensure!(
                    r.len() == delta.len(),
                    "sim residual res:{m} has {} floats, module expects {}",
                    r.len(),
                    delta.len()
                );
                for (d, ri) in delta.iter_mut().zip(&r) {
                    *d += ri;
                }
            }
            let (wire, qres) = checkpoint::encode_delta_feedback(codec, &delta);
            if need_residual {
                res_out.push((format!("res:{m}"), qres));
            }
            modules.push(m);
            ck = ck.with(&m.delta_section(), wire);
        }
        let (file, kind) = if last {
            let kind = if gid == 0 {
                "path".to_string()
            } else {
                format!("path:g{gid}")
            };
            (t.ckpt_out.clone(), kind)
        } else {
            (
                t.ckpt_out.with_extension(format!("g{gid}.dpc")),
                format!("path:g{gid}"),
            )
        };
        if last {
            ck = ck.with("loss", vec![1.0]);
            injector.before_publish(t.phase, t.path);
        }
        ck.save(&file)?;
        if last {
            injector.corrupt_after_write(t.phase, t.path, &file)?;
        }
        // Same ship-before-row ordering as the real worker: the exchange
        // plane serves the sections before the DB row announces them.
        if let Some(tx) = transport {
            tx.publish(
                &crate::transport::PublishCtx {
                    phase: t.phase,
                    path: t.path,
                    kind: kind.clone(),
                },
                &file,
                &modules,
            )
            .with_context(|| format!("sim publishing sections of {}", file.display()))?;
        }
        db.insert(CkptRow {
            rowid: 0,
            phase: t.phase,
            path_id: t.path,
            kind,
            file,
            step: t.steps,
            loss: 1.0,
            modules,
        });
        if last {
            injector.mark_published(t.phase, t.path);
        }
    }
    if need_residual {
        let refs: Vec<(&str, &[f32])> = res_out
            .iter()
            .map(|(n, d)| (n.as_str(), d.as_slice()))
            .collect();
        checkpoint::save_sections(&t.opt_out.with_extension("res.dpc"), &refs)?;
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn sim_worker_loop(
    queue: &TaskQueue,
    db: &CheckpointDb,
    topo: &Topology,
    injector: &FaultInjector,
    transport: Option<&crate::transport::tcp::TcpExchange>,
    shutdown: &AtomicBool,
    seed: u64,
    codec: DeltaCodec,
    publish_groups: usize,
    name: &str,
) {
    loop {
        let Some((lease, task)) = queue.lease(name, Duration::from_millis(100)) else {
            if shutdown.load(Ordering::Relaxed) {
                return;
            }
            continue;
        };
        let Task::Train(t) = task else {
            queue.complete(lease);
            continue;
        };
        match injector.on_task_start(t.phase, t.path) {
            // hard crash: walk away; lease expiry + reclaim recovers it
            TaskAction::Abandon => continue,
            TaskAction::Requeue => {
                queue.fail(lease);
                continue;
            }
            TaskAction::Run { delay } => {
                if let Some(d) = delay {
                    std::thread::sleep(d);
                }
            }
        }
        match sim_run_train(db, topo, injector, transport, seed, codec, publish_groups, &t) {
            Ok(()) => {
                queue.complete(lease);
            }
            Err(_) => {
                queue.fail(lease);
            }
        }
    }
}

/// Run `spec.phases` DiPaCo outer phases over the real coordinator stack
/// with `plan`'s faults injected. Returns the final [`ModuleStore`] (or
/// the loud error) plus queue/fault accounting.
pub fn run_sim(spec: &SimSpec, plan: &FaultPlan, rundir: &Path) -> Result<SimOutcome> {
    std::fs::create_dir_all(rundir)
        .with_context(|| format!("creating rundir {}", rundir.display()))?;
    let topo = Arc::new(sim_topology(spec));
    let theta0 = base_theta(spec.seed, topo.total_params);
    let store = Arc::new(Mutex::new(ModuleStore::from_base(&topo, &theta0)));
    let queue = Arc::new(TaskQueue::new(Duration::from_millis(spec.lease_ms)));
    let db = Arc::new(CheckpointDb::new());
    let injector = Arc::new(FaultInjector::new(plan));
    let shutdown = Arc::new(AtomicBool::new(false));

    // One TCP exchange for the whole run, sharded over the WIDEST
    // executor count the schedule ever uses. Per-phase re-sharding stays
    // correct because readers consult the union of every endpoint's
    // store, so a fixed module→server route can never hide a section
    // from a re-sharded executor.
    let transport: Option<Arc<crate::transport::tcp::TcpExchange>> = if spec.tcp {
        let net_execs = spec
            .executors_per_phase
            .iter()
            .copied()
            .max()
            .unwrap_or(1)
            .max(1);
        let net_shards = shard_modules(&topo, net_execs);
        Some(
            crate::transport::tcp::TcpExchange::start(
                &net_shards,
                crate::config::TransportConfig {
                    mode: crate::config::TransportMode::Tcp,
                    ..Default::default()
                },
                Some(Arc::clone(&injector)),
            )
            .context("starting sim TCP section exchange plane")?,
        )
    } else {
        None
    };

    // Sim workers live for the whole run (they idle-poll between phases).
    let mut workers = Vec::new();
    for w in 0..spec.workers.max(1) {
        let queue = Arc::clone(&queue);
        let db = Arc::clone(&db);
        let topo = Arc::clone(&topo);
        let injector = Arc::clone(&injector);
        let transport = transport.clone();
        let shutdown = Arc::clone(&shutdown);
        let seed = spec.seed;
        let codec = spec.codec;
        let publish_groups = spec.publish_groups;
        let name = format!("sim-{w}");
        workers.push(
            std::thread::Builder::new()
                .name(name.clone())
                .spawn(move || {
                    sim_worker_loop(
                        &queue,
                        &db,
                        &topo,
                        &injector,
                        transport.as_deref(),
                        &shutdown,
                        seed,
                        codec,
                        publish_groups,
                        &name,
                    )
                })
                .expect("spawn sim worker"),
        );
    }

    let diloco = DilocoConfig {
        loss_reweigh: false,
        ..Default::default()
    };
    // Master velocity map: outer momentum belongs to the MODULE, not to
    // any particular executor — re-sharding between phases (executor
    // drop/re-join) must not reset it.
    let mut velocity: HashMap<ModuleId, Vec<f32>> = HashMap::new();
    // Late-path contributions collected after one phase, merged into the
    // next phase's accumulation (streaming outer sync's grace semantics).
    let mut carry: Vec<LateContrib> = Vec::new();
    let (done_tx, _done_rx) = channel();

    let mut phases_run = 0usize;
    let mut error: Option<String> = None;
    let mut theta_buf: Vec<f32> = Vec::new();
    for t in 0..spec.phases {
        let executors = *spec
            .executors_per_phase
            .get(t)
            .or(spec.executors_per_phase.last())
            .unwrap_or(&1);
        let shards = shard_modules(&topo, executors);
        // deal each shard's optimizer its modules' velocity
        let mut opts: Vec<Nesterov> = shards
            .iter()
            .map(|owned| {
                let mut vel = HashMap::new();
                for m in owned {
                    if let Some(v) = velocity.remove(m) {
                        vel.insert(*m, v);
                    }
                }
                Nesterov::from_velocity(diloco.outer_lr, diloco.outer_momentum, vel)
            })
            .collect();

        // per-path input checkpoints (assembled theta) + train tasks
        let phase_dir = rundir.join(format!("phase{t}"));
        std::fs::create_dir_all(&phase_dir)?;
        let mut tasks = Vec::new();
        {
            let store_g = store.lock().unwrap();
            for p in 0..topo.paths {
                topo.assemble_into(&store_g, p, &mut theta_buf);
                let ckpt_in = phase_dir.join(format!("path{p}.in.dpc"));
                checkpoint::save_sections(&ckpt_in, &[("theta", theta_buf.as_slice())])?;
                tasks.push(Task::Train(TrainTask {
                    id: (t * topo.paths + p) as u64 + 1,
                    phase: t,
                    path: p,
                    steps: 1,
                    start_step: 0,
                    ckpt_in,
                    ckpt_out: phase_dir.join(format!("path{p}.out.dpc")),
                    opt_in: None,
                    opt_out: phase_dir.join(format!("path{p}.opt.dpc")),
                }));
            }
        }
        queue
            .push_all(tasks)
            .expect("sim queue stays open until the run shuts down");
        let cfg = OuterConfig {
            diloco: diloco.clone(),
            shard_sizes: vec![1; topo.paths],
            codec: spec.codec,
            grace: (spec.grace_ms > 0).then(|| Duration::from_millis(spec.grace_ms)),
            declared_late: spec.declared_late.clone(),
            carry_in: std::mem::take(&mut carry),
            transport: transport
                .clone()
                .map(|t| t as Arc<dyn crate::transport::SectionTransport>),
            ..Default::default()
        };
        let res = run_phase_outer(&topo, &store, &mut opts, &shards, &cfg, t, &db, &done_tx);
        // merge velocity back regardless of outcome (abort must not lose it)
        for opt in opts {
            velocity.extend(opt.into_velocity());
        }
        match res {
            Ok(report) => {
                queue.wait_idle(Duration::from_millis(5));
                phases_run += 1;
                if t + 1 < spec.phases && !report.late.is_empty() {
                    match collect_late_contribs(&topo, &db, &cfg, t, &report.late) {
                        Ok(c) => carry = c,
                        Err(e) => {
                            error = Some(format!("{e:#}"));
                            break;
                        }
                    }
                }
            }
            Err(e) => {
                error = Some(format!("{e:#}"));
                break;
            }
        }
    }

    shutdown.store(true, Ordering::Relaxed);
    queue.close();
    for h in workers {
        let _ = h.join();
    }
    let stats = queue.stats();
    let store = store.lock().unwrap().clone();
    Ok(SimOutcome {
        store,
        phases_run,
        completed: stats.completed,
        requeues: stats.requeues,
        dead: stats.dead,
        error,
        events: injector.fired_events(),
        unfired: injector.unfired(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_manifest_builds_a_topology() {
        let spec = SimSpec::new(7);
        let topo = sim_topology(&spec);
        assert_eq!(topo.paths, 4);
        assert!(topo.total_params > 0);
        // every module has at least one path through it
        for m in topo.all_modules() {
            assert!(topo.paths_through(m) >= 1);
        }
    }

    #[test]
    fn sim_after_is_idempotent_and_seed_sensitive() {
        let before: Vec<f32> = (0..64).map(|i| i as f32 * 0.01).collect();
        let a = sim_after(7, 1, 2, &before);
        let b = sim_after(7, 1, 2, &before);
        assert_eq!(a, b, "re-execution must reproduce identical bytes");
        assert_ne!(a, sim_after(8, 1, 2, &before));
        assert_ne!(a, sim_after(7, 1, 3, &before));
        assert_ne!(a, sim_after(7, 2, 2, &before));
    }
}
