//! Convergence-equivalence oracle: run the same recipe twice — once
//! fault-free, once under a [`FaultPlan`] — and demand either a
//! *bit-identical* final [`ModuleStore`] or a *loud, structured* abort.
//!
//! Bitwise is the right bar because every source of legitimate numeric
//! variation has been engineered out: the sim worker is a pure function
//! of `(seed, phase, path, theta)`, the DB dedups re-published rows, and
//! the outer executors reduce contributions in path-id-sorted order
//! regardless of arrival order. Any remaining difference is a
//! coordinator bug — silent double-accumulation, lost momentum on
//! re-shard, a zombie sneaking past the generation guard — exactly the
//! class of failure tolerance tests exist to catch.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::chaos::injector::{ChaosExec, ServeInjector};
use crate::chaos::plan::{FaultPlan, ServeFaultPlan};
use crate::chaos::sim::{run_sim, sim_topology, SimOutcome, SimSpec};
use crate::config::{BreakerConfig, ServeConfig, SupervisorConfig};
use crate::serve::request::ServeError;
use crate::serve::server::{PathExecutor, Server};
use crate::topology::{ModuleStore, Topology};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Order-independent digest of a store (fletcher-style over the bit
/// patterns, modules visited in canonical `all_modules()` order).
pub fn store_digest(topo: &Topology, store: &ModuleStore) -> u64 {
    let mut a: u64 = 0;
    let mut b: u64 = 0;
    for m in topo.all_modules() {
        for &x in store.get(m) {
            a = (a + x.to_bits() as u64) % 0xFFFF_FFFF;
            b = (b + a) % 0xFFFF_FFFF;
        }
    }
    (b << 32) | a
}

/// Largest elementwise |a - b| across all modules. The bounded-divergence
/// oracle for lossy codecs: quantization moves bytes, error feedback
/// bounds how far, and this measures the realized bound. Length mismatch
/// returns infinity (structurally different stores never pass).
pub fn max_abs_divergence(topo: &Topology, a: &ModuleStore, b: &ModuleStore) -> f64 {
    let mut worst: f64 = 0.0;
    for m in topo.all_modules() {
        let (xs, ys) = (a.get(m), b.get(m));
        if xs.len() != ys.len() {
            return f64::INFINITY;
        }
        for (x, y) in xs.iter().zip(ys) {
            let d = (*x as f64 - *y as f64).abs();
            if !d.is_finite() {
                return f64::INFINITY;
            }
            worst = worst.max(d);
        }
    }
    worst
}

/// First bitwise difference between two stores, human-readable.
pub fn first_divergence(topo: &Topology, a: &ModuleStore, b: &ModuleStore) -> Option<String> {
    for m in topo.all_modules() {
        let (xs, ys) = (a.get(m), b.get(m));
        if xs.len() != ys.len() {
            return Some(format!("module {m}: length {} vs {}", xs.len(), ys.len()));
        }
        for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Some(format!("module {m}[{i}]: {x} vs {y} (bitwise)"));
            }
        }
    }
    None
}

/// What the faulted run did relative to the fault-free reference.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    /// Faulted run finished and its store is bit-identical to the
    /// reference — the coordinator absorbed every fault.
    ConvergedIdentical,
    /// Faulted run finished within the scenario's divergence tolerance
    /// (lossy-codec scenarios, where bitwise identity is not the
    /// contract but bounded drift is). `max_abs` is the realized worst
    /// elementwise gap.
    ConvergedBounded { max_abs: f64 },
    /// The plan contained an unrecoverable fault (checkpoint corruption)
    /// and the run aborted with a structured error, as it must.
    AbortedLoudly { error: String },
    /// Finished but with different bytes — a silent-corruption bug.
    Diverged { detail: String },
    /// The plan expected an abort but the run sailed through — the
    /// detection layer (checksums) failed to fire.
    UnexpectedSuccess,
}

/// Structured record of one chaos scenario; serializes deterministically
/// (fixed field order, sorted event lists, hex digests).
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub scenario: String,
    pub seed: u64,
    pub planned: Vec<String>,
    pub fired: Vec<String>,
    pub unfired: Vec<String>,
    pub phases_run: usize,
    pub completed: u64,
    pub requeues: u64,
    pub dead_tasks: usize,
    pub reference_digest: u64,
    pub faulted_digest: Option<u64>,
    pub verdict: Verdict,
}

impl ChaosReport {
    /// A scenario passes when the coordinator either fully absorbed the
    /// faults or refused loudly; divergence and silent success both fail.
    pub fn is_pass(&self) -> bool {
        matches!(
            self.verdict,
            Verdict::ConvergedIdentical
                | Verdict::ConvergedBounded { .. }
                | Verdict::AbortedLoudly { .. }
        )
    }

    pub fn to_json(&self) -> Json {
        // digests as hex STRINGS: Json numbers are f64 and u64 digests
        // above 2^53 would silently lose bits.
        let verdict = match &self.verdict {
            Verdict::ConvergedIdentical => {
                Json::obj(vec![("kind", Json::str("converged-identical"))])
            }
            Verdict::ConvergedBounded { max_abs } => Json::obj(vec![
                ("kind", Json::str("converged-bounded")),
                ("max_abs", Json::num(*max_abs)),
            ]),
            Verdict::AbortedLoudly { error } => Json::obj(vec![
                ("kind", Json::str("aborted-loudly")),
                ("error", Json::str(error.clone())),
            ]),
            Verdict::Diverged { detail } => Json::obj(vec![
                ("kind", Json::str("diverged")),
                ("detail", Json::str(detail.clone())),
            ]),
            Verdict::UnexpectedSuccess => {
                Json::obj(vec![("kind", Json::str("unexpected-success"))])
            }
        };
        Json::obj(vec![
            ("scenario", Json::str(self.scenario.clone())),
            ("seed", Json::num(self.seed as f64)),
            (
                "planned",
                Json::arr(self.planned.iter().map(|s| Json::str(s.clone()))),
            ),
            (
                "fired",
                Json::arr(self.fired.iter().map(|s| Json::str(s.clone()))),
            ),
            (
                "unfired",
                Json::arr(self.unfired.iter().map(|s| Json::str(s.clone()))),
            ),
            ("phases_run", Json::num(self.phases_run as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("requeues", Json::num(self.requeues as f64)),
            ("dead_tasks", Json::num(self.dead_tasks as f64)),
            (
                "reference_digest",
                Json::str(format!("{:016x}", self.reference_digest)),
            ),
            (
                "faulted_digest",
                match self.faulted_digest {
                    Some(d) => Json::str(format!("{d:016x}")),
                    None => Json::Null,
                },
            ),
            ("verdict", verdict),
        ])
    }
}

/// Strip the run directory out of error text so reports are stable
/// across machines and runs.
fn sanitize(err: &str, dir: &Path) -> String {
    err.replace(&dir.display().to_string(), "<rundir>")
}

/// Run `plan` against `spec` and judge it against a fault-free run of
/// the identical spec.
pub fn run_scenario(name: &str, spec: &SimSpec, plan: &FaultPlan) -> Result<ChaosReport> {
    run_scenario_vs(name, spec, spec, plan)
}

/// Like [`run_scenario`] but the faulted and reference runs may differ
/// in coordinator shape (e.g. executor drop/re-join schedules) — they
/// must still share a seed so the simulated compute is identical.
pub fn run_scenario_vs(
    name: &str,
    faulted: &SimSpec,
    reference: &SimSpec,
    plan: &FaultPlan,
) -> Result<ChaosReport> {
    run_scenario_vs_tol(name, faulted, reference, plan, None)
}

/// Like [`run_scenario_vs`] with an explicit divergence tolerance:
/// `None` demands bitwise identity; `Some(tol)` accepts a finished run
/// whose worst elementwise gap vs the reference is `<= tol`
/// ([`Verdict::ConvergedBounded`]) — the oracle for lossy delta codecs,
/// where the faulted spec deliberately quantizes and only bounded drift
/// is the contract.
pub fn run_scenario_vs_tol(
    name: &str,
    faulted: &SimSpec,
    reference: &SimSpec,
    plan: &FaultPlan,
    tolerance: Option<f64>,
) -> Result<ChaosReport> {
    ensure!(
        faulted.seed == reference.seed,
        "faulted and reference specs must share a seed"
    );
    let base = std::env::temp_dir().join(format!(
        "dipaco-chaos-{}-{}-{}",
        std::process::id(),
        name,
        faulted.seed
    ));
    let _ = std::fs::remove_dir_all(&base);

    let topo = sim_topology(reference);
    let ref_out = run_sim(reference, &FaultPlan::none(), &base.join("reference"))
        .with_context(|| format!("scenario {name}: reference run"))?;
    ensure!(
        ref_out.error.is_none(),
        "scenario {name}: fault-free reference run failed: {}",
        ref_out.error.unwrap_or_default()
    );
    let fault_out = run_sim(faulted, plan, &base.join("faulted"))
        .with_context(|| format!("scenario {name}: faulted run"))?;

    let report = judge(name, faulted, plan, &topo, &ref_out, &fault_out, &base, tolerance);
    let _ = std::fs::remove_dir_all(&base);
    Ok(report)
}

#[allow(clippy::too_many_arguments)]
fn judge(
    name: &str,
    spec: &SimSpec,
    plan: &FaultPlan,
    topo: &Topology,
    ref_out: &SimOutcome,
    fault_out: &SimOutcome,
    base: &Path,
    tolerance: Option<f64>,
) -> ChaosReport {
    let expects_abort = plan.expects_abort();
    let (verdict, faulted_digest) = match (&fault_out.error, expects_abort) {
        (Some(e), true) => (
            Verdict::AbortedLoudly {
                error: sanitize(e, base),
            },
            None,
        ),
        (Some(e), false) => (
            Verdict::Diverged {
                detail: format!("unexpected abort: {}", sanitize(e, base)),
            },
            None,
        ),
        (None, true) => (Verdict::UnexpectedSuccess, Some(store_digest(topo, &fault_out.store))),
        (None, false) => {
            let d = store_digest(topo, &fault_out.store);
            match tolerance {
                None => match first_divergence(topo, &ref_out.store, &fault_out.store) {
                    None => (Verdict::ConvergedIdentical, Some(d)),
                    Some(detail) => (Verdict::Diverged { detail }, Some(d)),
                },
                Some(tol) => {
                    let max_abs = max_abs_divergence(topo, &ref_out.store, &fault_out.store);
                    if max_abs <= tol {
                        (Verdict::ConvergedBounded { max_abs }, Some(d))
                    } else {
                        (
                            Verdict::Diverged {
                                detail: format!(
                                    "max |Δ| {max_abs:.3e} exceeds tolerance {tol:.3e}"
                                ),
                            },
                            Some(d),
                        )
                    }
                }
            }
        }
    };
    ChaosReport {
        scenario: name.to_string(),
        seed: spec.seed,
        planned: plan.describe(),
        fired: fault_out.events.clone(),
        unfired: fault_out.unfired.clone(),
        phases_run: fault_out.phases_run,
        completed: fault_out.completed,
        requeues: fault_out.requeues,
        dead_tasks: fault_out.dead,
        reference_digest: store_digest(topo, &ref_out.store),
        faulted_digest,
        verdict,
    }
}

// ---------------------------------------------------------------------------
// Serving-plane chaos: drive a real Server with scripted executor faults
// and demand that NO request ever hangs — every ticket resolves with a
// score, a redirect, or a loud ServeError — and that faulted paths
// recover (breaker closed, health healthy) once the fault budget drains.
// ---------------------------------------------------------------------------

/// Shape of one serve-chaos scenario. Everything timing-sensitive is
/// pinned so two runs of the same `(spec, plan)` produce byte-identical
/// reports: micro-batches of 1 flushed instantly, one serial client (the
/// next submission happens only after the previous ticket resolved, so
/// breaker transitions are ordered), stable runner-up tie-breaking in the
/// router, and a breaker cooldown long enough that no half-open probe can
/// sneak into the fault/traffic phases.
#[derive(Debug, Clone)]
pub struct ServeScenarioSpec {
    pub seed: u64,
    /// Paths served (>= 2 so degraded routing has a fallback).
    pub paths: usize,
    /// Mixed-path submissions in the traffic phase (seeded stream).
    pub traffic: usize,
    /// Breaker `min_samples` AND every fault's budget: the last faulted
    /// batch is exactly the batch that trips the breaker, so all planned
    /// faults fire before admission stops routing to the path.
    pub fault_batches: usize,
    /// Breaker cooldown. The fault + traffic phases must complete within
    /// this of the first trip (they are sleep-free except for injected
    /// wedge/slow delays, well under a second).
    pub cooldown_ms: u64,
}

impl ServeScenarioSpec {
    pub fn new(seed: u64) -> ServeScenarioSpec {
        ServeScenarioSpec {
            seed,
            paths: 3,
            traffic: 48,
            fault_batches: 3,
            cooldown_ms: 1200,
        }
    }
}

/// Structured record of one serve-chaos scenario; serializes
/// deterministically (fixed field order, sorted event lists, no wall
/// times). Counters are CLIENT-side classifications of every submission,
/// so "no hung request" is judged from the waiter's perspective.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeChaosReport {
    pub scenario: String,
    pub seed: u64,
    pub paths: usize,
    pub planned: Vec<String>,
    pub fired: Vec<String>,
    pub unfired: Vec<String>,
    /// Total submissions across all phases.
    pub submitted: u64,
    /// Resolved Ok on the path the client intended.
    pub ok: u64,
    /// Resolved Ok on a fallback path (degraded-mode redirect).
    pub redirected: u64,
    /// Resolved with a loud ServeError (ExecFailed etc.).
    pub errored: u64,
    /// Refused at admission as Shed (fallback saturated).
    pub shed: u64,
    /// Refused at admission with no fallback (CircuitOpen & co).
    pub refused: u64,
    /// Tickets that did not resolve within the 10s deadline — the one
    /// outcome the serving plane must NEVER produce.
    pub hung: u64,
    pub per_path_trips: Vec<u64>,
    /// Breaker state per path after shutdown ("closed"/"open"/"half-open").
    pub final_breaker: Vec<String>,
    /// Worker health per path after shutdown ("healthy"/"restarting"/"down").
    pub final_health: Vec<String>,
    /// Invariant violations found by the judge; empty = pass.
    pub violations: Vec<String>,
}

impl ServeChaosReport {
    pub fn is_pass(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let strs = |v: &[String]| Json::arr(v.iter().map(|s| Json::str(s.clone())));
        Json::obj(vec![
            ("scenario", Json::str(self.scenario.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("paths", Json::num(self.paths as f64)),
            ("planned", strs(&self.planned)),
            ("fired", strs(&self.fired)),
            ("unfired", strs(&self.unfired)),
            ("submitted", Json::num(self.submitted as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("redirected", Json::num(self.redirected as f64)),
            ("errored", Json::num(self.errored as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("refused", Json::num(self.refused as f64)),
            ("hung", Json::num(self.hung as f64)),
            (
                "per_path_trips",
                Json::arr(self.per_path_trips.iter().map(|&t| Json::num(t as f64))),
            ),
            ("final_breaker", strs(&self.final_breaker)),
            ("final_health", strs(&self.final_health)),
            ("violations", strs(&self.violations)),
        ])
    }
}

/// Synthetic instant executor for serve-chaos scenarios (the faults come
/// from the [`ChaosExec`] wrapper, never from the backend itself).
struct SynthServeExec {
    seq: usize,
}

impl PathExecutor for SynthServeExec {
    fn batch(&self) -> usize {
        1
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn forward(&mut self, _toks: &[i32], rows: usize) -> Result<Vec<(f64, usize)>> {
        Ok((0..rows).map(|_| (1.0, self.seq - 1)).collect())
    }
}

/// Client-side outcome tally for one scenario run.
#[derive(Default)]
struct Tally {
    submitted: u64,
    ok: u64,
    redirected: u64,
    errored: u64,
    shed: u64,
    refused: u64,
    hung: u64,
}

impl Tally {
    /// Submit one document intended for `path` and classify how it
    /// resolves. Blocks until resolution (serial client — this ordering
    /// is what makes breaker transitions deterministic).
    fn drive(&mut self, server: &Server, paths: usize, path: usize, seq: usize) {
        self.submitted += 1;
        let z: Vec<f32> = (0..paths).map(|j| if j == path { 1.0 } else { 0.0 }).collect();
        match server.submit(&z, vec![0i32; seq]) {
            Ok(t) => match t.wait_timeout(Duration::from_secs(10)) {
                None => self.hung += 1,
                Some(Ok(resp)) => {
                    if resp.path == path {
                        self.ok += 1;
                    } else {
                        self.redirected += 1;
                    }
                }
                Some(Err(_)) => self.errored += 1,
            },
            Err(ServeError::Shed { .. }) => self.shed += 1,
            Err(_) => self.refused += 1,
        }
    }
}

/// Run one serving fault plan against a real [`Server`] and judge the
/// self-healing invariants. Three serial phases:
///
/// 1. **fault** — each fault's full budget is driven at its own path, so
///    the breaker trips on exactly the last faulted batch;
/// 2. **traffic** — a seeded mixed-path stream; submissions whose primary
///    is tripped must redirect, everything else serves normally;
/// 3. **recovery** — sleep out the cooldown, then probe each faulted path
///    until its breaker closes again (half-open probe batches).
pub fn run_serve_scenario(
    name: &str,
    spec: &ServeScenarioSpec,
    plan: &ServeFaultPlan,
) -> ServeChaosReport {
    assert!(spec.paths >= 2, "serve scenarios need a fallback path");
    for f in &plan.faults {
        assert!(f.path() < spec.paths, "fault on unknown path: {f:?}");
        assert_eq!(
            f.batches(),
            spec.fault_batches,
            "fault budget must equal breaker min_samples (see ServeScenarioSpec)"
        );
    }
    crate::testkit::install_quiet_panic_hook();
    const SEQ: usize = 8;
    let injector = Arc::new(ServeInjector::new(plan));
    let execs: Vec<ChaosExec<SynthServeExec>> = (0..spec.paths)
        .map(|p| ChaosExec::new(p, SynthServeExec { seq: SEQ }, Arc::clone(&injector)))
        .collect();
    let cfg = ServeConfig {
        queue_cap: 256,
        max_batch: 1,
        max_wait_ms: 0,
        idle_ms: 5,
        breaker: BreakerConfig {
            enabled: true,
            window: 8,
            min_samples: spec.fault_batches,
            error_rate: 0.5,
            latency_ms: 15.0, // injected delays are >= 20ms
            cooldown_ms: spec.cooldown_ms,
            probes: 2,
        },
        supervisor: SupervisorConfig {
            backoff_ms: 1,
            backoff_max_ms: 8,
            max_consecutive_panics: 0,
        },
        ..Default::default()
    };
    let server = Server::start(
        &cfg,
        crate::testkit::routers::one_hot_router(spec.paths),
        execs,
    );
    let mut tally = Tally::default();

    // Phase 1: drain every fault budget at its own path.
    for f in &plan.faults {
        for _ in 0..f.batches() {
            tally.drive(&server, spec.paths, f.path(), SEQ);
        }
    }
    // Phase 2: seeded mixed traffic over all paths.
    let mut rng = Rng::new(spec.seed).fork(0x5E2E_C4A0);
    for _ in 0..spec.traffic {
        let p = rng.gen_range(spec.paths);
        tally.drive(&server, spec.paths, p, SEQ);
    }
    // Phase 3: recovery — wait out the cooldown, then drive each faulted
    // path through its half-open probes back to closed.
    let faulted = plan.faulted_paths();
    if !faulted.is_empty() {
        std::thread::sleep(Duration::from_millis(spec.cooldown_ms + 400));
        for &p in &faulted {
            for _ in 0..(cfg.breaker.probes + 2) {
                tally.drive(&server, spec.paths, p, SEQ);
            }
        }
    }

    let fired = injector.fired_events();
    let unfired = injector.unfired();
    let rep = server.shutdown();
    let final_breaker = rep.per_path_breaker.clone();
    let final_health: Vec<String> = rep
        .per_path_health
        .iter()
        .map(|h| h.as_str().to_string())
        .collect();

    let mut violations = Vec::new();
    if tally.hung > 0 {
        violations.push(format!("{} tickets hung past the 10s deadline", tally.hung));
    }
    if tally.refused > 0 {
        violations.push(format!(
            "{} submissions refused with no fallback despite a healthy path",
            tally.refused
        ));
    }
    if tally.shed > 0 {
        violations.push(format!(
            "{} redirects shed despite an unsaturated fallback queue",
            tally.shed
        ));
    }
    if !unfired.is_empty() {
        violations.push(format!("planned faults never fired: {unfired:?}"));
    }
    for &p in &faulted {
        if rep.per_path_trips[p] == 0 {
            violations.push(format!("path {p}: breaker never tripped under faults"));
        }
        if final_breaker[p] != "closed" {
            violations.push(format!(
                "path {p}: breaker did not recover to closed (is {})",
                final_breaker[p]
            ));
        }
        if final_health[p] != "healthy" {
            violations.push(format!(
                "path {p}: worker did not recover to healthy (is {})",
                final_health[p]
            ));
        }
    }
    if plan.faults.is_empty() && (tally.redirected > 0 || tally.errored > 0) {
        violations.push(format!(
            "fault-free run saw {} redirects / {} errors",
            tally.redirected, tally.errored
        ));
    }

    ServeChaosReport {
        scenario: name.to_string(),
        seed: spec.seed,
        paths: spec.paths,
        planned: plan.describe(),
        fired,
        unfired,
        submitted: tally.submitted,
        ok: tally.ok,
        redirected: tally.redirected,
        errored: tally.errored,
        shed: tally.shed,
        refused: tally.refused,
        hung: tally.hung,
        per_path_trips: rep.per_path_trips,
        final_breaker,
        final_health,
        violations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::plan::ServeFault;
    use crate::chaos::sim::sim_topology;

    #[test]
    fn digest_detects_single_bit_flip() {
        let spec = SimSpec::new(3);
        let topo = sim_topology(&spec);
        let n = topo.total_params;
        let theta: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
        let a = ModuleStore::from_base(&topo, &theta);
        let mut b = a.clone();
        let d0 = store_digest(&topo, &a);
        assert_eq!(d0, store_digest(&topo, &b), "digest must be deterministic");
        assert!(first_divergence(&topo, &a, &b).is_none());

        let m = topo.all_modules()[1];
        let v = b.get_mut(m);
        v[0] = f32::from_bits(v[0].to_bits() ^ 1);
        assert_ne!(d0, store_digest(&topo, &b));
        let msg = first_divergence(&topo, &a, &b).expect("must spot the flip");
        assert!(msg.contains("bitwise"), "unhelpful divergence message: {msg}");
    }

    #[test]
    fn max_abs_divergence_measures_worst_gap_and_bounded_verdict_passes() {
        let spec = SimSpec::new(3);
        let topo = sim_topology(&spec);
        let theta: Vec<f32> = (0..topo.total_params).map(|i| i as f32 * 0.01).collect();
        let a = ModuleStore::from_base(&topo, &theta);
        let mut b = a.clone();
        assert_eq!(max_abs_divergence(&topo, &a, &b), 0.0);
        let m = topo.all_modules()[0];
        b.get_mut(m)[1] += 0.5;
        let d = max_abs_divergence(&topo, &a, &b);
        assert!((d - 0.5).abs() < 1e-4, "worst gap should be ~0.5, got {d}");

        let rep = ChaosReport {
            scenario: "unit-bounded".into(),
            seed: 3,
            planned: vec![],
            fired: vec![],
            unfired: vec![],
            phases_run: 3,
            completed: 12,
            requeues: 0,
            dead_tasks: 0,
            reference_digest: 1,
            faulted_digest: Some(2),
            verdict: Verdict::ConvergedBounded { max_abs: d },
        };
        assert!(rep.is_pass(), "bounded convergence within tolerance is a pass");
        assert!(rep.to_json().to_string().contains("converged-bounded"));
    }

    #[test]
    fn report_json_is_deterministic_and_hex_digested() {
        let rep = ChaosReport {
            scenario: "unit".into(),
            seed: 9,
            planned: vec!["phase 0: kill worker on path 1".into()],
            fired: vec!["phase 0: kill worker on path 1".into()],
            unfired: vec![],
            phases_run: 3,
            completed: 12,
            requeues: 1,
            dead_tasks: 0,
            reference_digest: u64::MAX - 5,
            faulted_digest: Some(u64::MAX - 5),
            verdict: Verdict::ConvergedIdentical,
        };
        let s1 = rep.to_json().to_string();
        let s2 = rep.clone().to_json().to_string();
        assert_eq!(s1, s2);
        // u64::MAX - 5 is not representable in f64; hex string must be exact
        assert!(s1.contains(&format!("{:016x}", u64::MAX - 5)), "{s1}");
        assert!(s1.contains("converged-identical"));
    }

    #[test]
    fn serve_scenario_fault_free_baseline_is_clean() {
        let spec = ServeScenarioSpec {
            seed: 11,
            paths: 2,
            traffic: 10,
            fault_batches: 3,
            cooldown_ms: 200,
        };
        let rep = run_serve_scenario("unit-baseline", &spec, &ServeFaultPlan::none());
        assert!(rep.is_pass(), "violations: {:?}", rep.violations);
        assert_eq!(rep.submitted, 10);
        assert_eq!(rep.ok, 10, "fault-free traffic all serves on its own path");
        assert_eq!(rep.hung, 0);
        assert_eq!(rep.per_path_trips, vec![0, 0]);
        assert_eq!(rep.final_breaker, vec!["closed", "closed"]);
        assert_eq!(rep.to_json().to_string(), rep.to_json().to_string());
    }

    #[test]
    fn serve_scenario_panic_plan_trips_redirects_and_recovers() {
        let spec = ServeScenarioSpec {
            seed: 5,
            paths: 2,
            traffic: 16,
            fault_batches: 3,
            cooldown_ms: 300,
        };
        let plan = ServeFaultPlan::new(vec![ServeFault::PanicExec { path: 0, batches: 3 }]);
        let rep = run_serve_scenario("unit-panic", &spec, &plan);
        assert!(rep.is_pass(), "violations: {:?}", rep.violations);
        assert_eq!(rep.hung, 0);
        assert_eq!(rep.errored, 3, "each panicked batch resolves loudly");
        assert!(rep.redirected > 0, "open breaker must redirect traffic");
        assert_eq!(rep.per_path_trips, vec![1, 0]);
        assert_eq!(rep.final_breaker, vec!["closed", "closed"]);
        assert_eq!(rep.final_health, vec!["healthy", "healthy"]);
        assert!(rep.unfired.is_empty());
        assert_eq!(
            rep.submitted,
            3 + 16 + 4,
            "fault batches + traffic + recovery probes"
        );
    }
}
