//! Convergence-equivalence oracle: run the same recipe twice — once
//! fault-free, once under a [`FaultPlan`] — and demand either a
//! *bit-identical* final [`ModuleStore`] or a *loud, structured* abort.
//!
//! Bitwise is the right bar because every source of legitimate numeric
//! variation has been engineered out: the sim worker is a pure function
//! of `(seed, phase, path, theta)`, the DB dedups re-published rows, and
//! the outer executors reduce contributions in path-id-sorted order
//! regardless of arrival order. Any remaining difference is a
//! coordinator bug — silent double-accumulation, lost momentum on
//! re-shard, a zombie sneaking past the generation guard — exactly the
//! class of failure tolerance tests exist to catch.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::chaos::plan::FaultPlan;
use crate::chaos::sim::{run_sim, sim_topology, SimOutcome, SimSpec};
use crate::topology::{ModuleStore, Topology};
use crate::util::json::Json;

/// Order-independent digest of a store (fletcher-style over the bit
/// patterns, modules visited in canonical `all_modules()` order).
pub fn store_digest(topo: &Topology, store: &ModuleStore) -> u64 {
    let mut a: u64 = 0;
    let mut b: u64 = 0;
    for m in topo.all_modules() {
        for &x in store.get(m) {
            a = (a + x.to_bits() as u64) % 0xFFFF_FFFF;
            b = (b + a) % 0xFFFF_FFFF;
        }
    }
    (b << 32) | a
}

/// First bitwise difference between two stores, human-readable.
pub fn first_divergence(topo: &Topology, a: &ModuleStore, b: &ModuleStore) -> Option<String> {
    for m in topo.all_modules() {
        let (xs, ys) = (a.get(m), b.get(m));
        if xs.len() != ys.len() {
            return Some(format!("module {m}: length {} vs {}", xs.len(), ys.len()));
        }
        for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Some(format!("module {m}[{i}]: {x} vs {y} (bitwise)"));
            }
        }
    }
    None
}

/// What the faulted run did relative to the fault-free reference.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    /// Faulted run finished and its store is bit-identical to the
    /// reference — the coordinator absorbed every fault.
    ConvergedIdentical,
    /// The plan contained an unrecoverable fault (checkpoint corruption)
    /// and the run aborted with a structured error, as it must.
    AbortedLoudly { error: String },
    /// Finished but with different bytes — a silent-corruption bug.
    Diverged { detail: String },
    /// The plan expected an abort but the run sailed through — the
    /// detection layer (checksums) failed to fire.
    UnexpectedSuccess,
}

/// Structured record of one chaos scenario; serializes deterministically
/// (fixed field order, sorted event lists, hex digests).
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub scenario: String,
    pub seed: u64,
    pub planned: Vec<String>,
    pub fired: Vec<String>,
    pub unfired: Vec<String>,
    pub phases_run: usize,
    pub completed: u64,
    pub requeues: u64,
    pub dead_tasks: usize,
    pub reference_digest: u64,
    pub faulted_digest: Option<u64>,
    pub verdict: Verdict,
}

impl ChaosReport {
    /// A scenario passes when the coordinator either fully absorbed the
    /// faults or refused loudly; divergence and silent success both fail.
    pub fn is_pass(&self) -> bool {
        matches!(
            self.verdict,
            Verdict::ConvergedIdentical | Verdict::AbortedLoudly { .. }
        )
    }

    pub fn to_json(&self) -> Json {
        // digests as hex STRINGS: Json numbers are f64 and u64 digests
        // above 2^53 would silently lose bits.
        let verdict = match &self.verdict {
            Verdict::ConvergedIdentical => {
                Json::obj(vec![("kind", Json::str("converged-identical"))])
            }
            Verdict::AbortedLoudly { error } => Json::obj(vec![
                ("kind", Json::str("aborted-loudly")),
                ("error", Json::str(error.clone())),
            ]),
            Verdict::Diverged { detail } => Json::obj(vec![
                ("kind", Json::str("diverged")),
                ("detail", Json::str(detail.clone())),
            ]),
            Verdict::UnexpectedSuccess => {
                Json::obj(vec![("kind", Json::str("unexpected-success"))])
            }
        };
        Json::obj(vec![
            ("scenario", Json::str(self.scenario.clone())),
            ("seed", Json::num(self.seed as f64)),
            (
                "planned",
                Json::arr(self.planned.iter().map(|s| Json::str(s.clone()))),
            ),
            (
                "fired",
                Json::arr(self.fired.iter().map(|s| Json::str(s.clone()))),
            ),
            (
                "unfired",
                Json::arr(self.unfired.iter().map(|s| Json::str(s.clone()))),
            ),
            ("phases_run", Json::num(self.phases_run as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("requeues", Json::num(self.requeues as f64)),
            ("dead_tasks", Json::num(self.dead_tasks as f64)),
            (
                "reference_digest",
                Json::str(format!("{:016x}", self.reference_digest)),
            ),
            (
                "faulted_digest",
                match self.faulted_digest {
                    Some(d) => Json::str(format!("{d:016x}")),
                    None => Json::Null,
                },
            ),
            ("verdict", verdict),
        ])
    }
}

/// Strip the run directory out of error text so reports are stable
/// across machines and runs.
fn sanitize(err: &str, dir: &Path) -> String {
    err.replace(&dir.display().to_string(), "<rundir>")
}

/// Run `plan` against `spec` and judge it against a fault-free run of
/// the identical spec.
pub fn run_scenario(name: &str, spec: &SimSpec, plan: &FaultPlan) -> Result<ChaosReport> {
    run_scenario_vs(name, spec, spec, plan)
}

/// Like [`run_scenario`] but the faulted and reference runs may differ
/// in coordinator shape (e.g. executor drop/re-join schedules) — they
/// must still share a seed so the simulated compute is identical.
pub fn run_scenario_vs(
    name: &str,
    faulted: &SimSpec,
    reference: &SimSpec,
    plan: &FaultPlan,
) -> Result<ChaosReport> {
    ensure!(
        faulted.seed == reference.seed,
        "faulted and reference specs must share a seed"
    );
    let base = std::env::temp_dir().join(format!(
        "dipaco-chaos-{}-{}-{}",
        std::process::id(),
        name,
        faulted.seed
    ));
    let _ = std::fs::remove_dir_all(&base);

    let topo = sim_topology(reference);
    let ref_out = run_sim(reference, &FaultPlan::none(), &base.join("reference"))
        .with_context(|| format!("scenario {name}: reference run"))?;
    ensure!(
        ref_out.error.is_none(),
        "scenario {name}: fault-free reference run failed: {}",
        ref_out.error.unwrap_or_default()
    );
    let fault_out = run_sim(faulted, plan, &base.join("faulted"))
        .with_context(|| format!("scenario {name}: faulted run"))?;

    let report = judge(name, faulted, plan, &topo, &ref_out, &fault_out, &base);
    let _ = std::fs::remove_dir_all(&base);
    Ok(report)
}

fn judge(
    name: &str,
    spec: &SimSpec,
    plan: &FaultPlan,
    topo: &Topology,
    ref_out: &SimOutcome,
    fault_out: &SimOutcome,
    base: &Path,
) -> ChaosReport {
    let expects_abort = plan.expects_abort();
    let (verdict, faulted_digest) = match (&fault_out.error, expects_abort) {
        (Some(e), true) => (
            Verdict::AbortedLoudly {
                error: sanitize(e, base),
            },
            None,
        ),
        (Some(e), false) => (
            Verdict::Diverged {
                detail: format!("unexpected abort: {}", sanitize(e, base)),
            },
            None,
        ),
        (None, true) => (Verdict::UnexpectedSuccess, Some(store_digest(topo, &fault_out.store))),
        (None, false) => {
            let d = store_digest(topo, &fault_out.store);
            match first_divergence(topo, &ref_out.store, &fault_out.store) {
                None => (Verdict::ConvergedIdentical, Some(d)),
                Some(detail) => (Verdict::Diverged { detail }, Some(d)),
            }
        }
    };
    ChaosReport {
        scenario: name.to_string(),
        seed: spec.seed,
        planned: plan.describe(),
        fired: fault_out.events.clone(),
        unfired: fault_out.unfired.clone(),
        phases_run: fault_out.phases_run,
        completed: fault_out.completed,
        requeues: fault_out.requeues,
        dead_tasks: fault_out.dead,
        reference_digest: store_digest(topo, &ref_out.store),
        faulted_digest,
        verdict,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::sim::sim_topology;

    #[test]
    fn digest_detects_single_bit_flip() {
        let spec = SimSpec::new(3);
        let topo = sim_topology(&spec);
        let n = topo.total_params;
        let theta: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
        let a = ModuleStore::from_base(&topo, &theta);
        let mut b = a.clone();
        let d0 = store_digest(&topo, &a);
        assert_eq!(d0, store_digest(&topo, &b), "digest must be deterministic");
        assert!(first_divergence(&topo, &a, &b).is_none());

        let m = topo.all_modules()[1];
        let v = b.get_mut(m);
        v[0] = f32::from_bits(v[0].to_bits() ^ 1);
        assert_ne!(d0, store_digest(&topo, &b));
        let msg = first_divergence(&topo, &a, &b).expect("must spot the flip");
        assert!(msg.contains("bitwise"), "unhelpful divergence message: {msg}");
    }

    #[test]
    fn report_json_is_deterministic_and_hex_digested() {
        let rep = ChaosReport {
            scenario: "unit".into(),
            seed: 9,
            planned: vec!["phase 0: kill worker on path 1".into()],
            fired: vec!["phase 0: kill worker on path 1".into()],
            unfired: vec![],
            phases_run: 3,
            completed: 12,
            requeues: 1,
            dead_tasks: 0,
            reference_digest: u64::MAX - 5,
            faulted_digest: Some(u64::MAX - 5),
            verdict: Verdict::ConvergedIdentical,
        };
        let s1 = rep.to_json().to_string();
        let s2 = rep.clone().to_json().to_string();
        assert_eq!(s1, s2);
        // u64::MAX - 5 is not representable in f64; hex string must be exact
        assert!(s1.contains(&format!("{:016x}", u64::MAX - 5)), "{s1}");
        assert!(s1.contains("converged-identical"));
    }
}
