//! Fault taxonomy and seeded fault plans (DESIGN.md "Failure model").
//!
//! A [`FaultPlan`] is a declarative list of faults to inject into one
//! coordinator run, each addressed by `(phase, path)` — the coordinates
//! of the task it strikes. Plans are either hand-written (the named
//! scenarios in `rust/tests/integration_chaos.rs`) or drawn from a
//! seeded [`crate::util::rng::Rng`] stream ([`FaultPlan::random`]), so
//! the weekly sweep explores the scenario space while every run stays
//! exactly reproducible from its seed.
//!
//! The random generator deliberately keeps plans *oracle-clean*: at most
//! one fault per `(phase, path)`, at most one publication reorder per
//! phase, and never a fault on a reorder's dependency — each of those
//! restrictions removes a timing race that would make requeue counts (and
//! therefore the `ChaosReport`) depend on scheduler luck instead of the
//! seed. Lease-expiry holds and file corruption are only used by the
//! named scenarios, where the test controls the surrounding timing.

use crate::chaos::corruptor::CorruptMode;
use crate::util::rng::Rng;

/// One injected fault. Timing faults target the worker/queue plane;
/// `Corrupt` targets the checkpoint plane (the DPC2 file itself).
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Hard crash: the worker abandons the leased task without failing
    /// it — only lease expiry + `reclaim` recovers it.
    KillWorker { phase: usize, path: usize },
    /// Graceful preemption: the worker fails its lease, the task
    /// requeues immediately.
    Preempt { phase: usize, path: usize },
    /// The worker stalls `hold_ms` (past its lease) before running the
    /// task, forcing expiry + redelivery while the zombie lives on.
    ExpireLease {
        phase: usize,
        path: usize,
        hold_ms: u64,
    },
    /// Heterogeneous speed: the worker sleeps `delay_ms` before the
    /// task (within its lease).
    Straggle {
        phase: usize,
        path: usize,
        delay_ms: u64,
    },
    /// Checkpoint written, publication to the DB delayed `delay_ms`.
    DelayPublish {
        phase: usize,
        path: usize,
        delay_ms: u64,
    },
    /// Path `then` publishes only after path `first` has published —
    /// an adversarial arrival order for the online averaging.
    ReorderPublish {
        phase: usize,
        first: usize,
        then: usize,
    },
    /// Damage the published DPC2 file before the DB row appears, so the
    /// executor's checksum verification is exercised end to end.
    Corrupt {
        phase: usize,
        path: usize,
        mode: CorruptMode,
    },
}

impl Fault {
    /// Canonical one-line description (stable across runs — report keys).
    pub fn describe(&self) -> String {
        match self {
            Fault::KillWorker { phase, path } => {
                format!("phase {phase}: kill worker on path {path}")
            }
            Fault::Preempt { phase, path } => {
                format!("phase {phase}: graceful preemption on path {path}")
            }
            Fault::ExpireLease {
                phase,
                path,
                hold_ms,
            } => format!("phase {phase}: hold lease {hold_ms}ms past expiry on path {path}"),
            Fault::Straggle {
                phase,
                path,
                delay_ms,
            } => format!("phase {phase}: straggle {delay_ms}ms on path {path}"),
            Fault::DelayPublish {
                phase,
                path,
                delay_ms,
            } => format!("phase {phase}: delay publication {delay_ms}ms on path {path}"),
            Fault::ReorderPublish { phase, first, then } => {
                format!("phase {phase}: publish path {then} only after path {first}")
            }
            Fault::Corrupt { phase, path, mode } => {
                format!("phase {phase}: corrupt checkpoint of path {path} ({mode})")
            }
        }
    }

    /// `(phase, path)` this fault strikes at *task start* (worker-side
    /// faults); `None` for publication/file-plane faults.
    pub fn task_start_target(&self) -> Option<(usize, usize)> {
        match *self {
            Fault::KillWorker { phase, path }
            | Fault::Preempt { phase, path }
            | Fault::ExpireLease { phase, path, .. }
            | Fault::Straggle { phase, path, .. } => Some((phase, path)),
            _ => None,
        }
    }
}

/// A set of faults to inject into one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// The fault-free plan (reference runs).
    pub fn none() -> FaultPlan {
        FaultPlan { faults: Vec::new() }
    }

    pub fn new(faults: Vec<Fault>) -> FaultPlan {
        FaultPlan { faults }
    }

    /// Plans containing file corruption must abort loudly rather than
    /// converge — the oracle's expected outcome flips on this.
    pub fn expects_abort(&self) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, Fault::Corrupt { .. }))
    }

    /// Descriptions in plan order.
    pub fn describe(&self) -> Vec<String> {
        self.faults.iter().map(Fault::describe).collect()
    }

    /// Seeded random mix of timing faults over `phases` x `paths`, up to
    /// `events` of them (fewer when a phase runs out of untouched paths).
    /// Only convergence-preserving faults are drawn — see module docs.
    pub fn random(seed: u64, phases: usize, paths: usize, events: usize) -> FaultPlan {
        assert!(phases >= 1 && paths >= 1);
        let mut rng = Rng::new(seed).fork(0xC4A05);
        let mut faults = Vec::new();
        let mut used: Vec<Vec<usize>> = vec![Vec::new(); phases];
        let mut reordered = vec![false; phases];
        for _ in 0..events {
            let phase = rng.gen_range(phases);
            let free: Vec<usize> = (0..paths).filter(|p| !used[phase].contains(p)).collect();
            if free.is_empty() {
                continue;
            }
            let mut kind = rng.gen_range(5);
            if kind == 4 && (free.len() < 2 || reordered[phase]) {
                kind = 0; // no room for a reorder here — kill instead
            }
            match kind {
                0 => {
                    let path = *rng.choose(&free);
                    used[phase].push(path);
                    faults.push(Fault::KillWorker { phase, path });
                }
                1 => {
                    let path = *rng.choose(&free);
                    used[phase].push(path);
                    faults.push(Fault::Preempt { phase, path });
                }
                2 => {
                    let path = *rng.choose(&free);
                    used[phase].push(path);
                    faults.push(Fault::Straggle {
                        phase,
                        path,
                        delay_ms: 50 + rng.gen_range(101) as u64,
                    });
                }
                3 => {
                    let path = *rng.choose(&free);
                    used[phase].push(path);
                    faults.push(Fault::DelayPublish {
                        phase,
                        path,
                        delay_ms: 20 + rng.gen_range(61) as u64,
                    });
                }
                _ => {
                    let i = rng.gen_range(free.len());
                    let first = free[i];
                    let rest: Vec<usize> = free.into_iter().filter(|&p| p != first).collect();
                    let then = *rng.choose(&rest);
                    used[phase].push(first);
                    used[phase].push(then);
                    reordered[phase] = true;
                    faults.push(Fault::ReorderPublish { phase, first, then });
                }
            }
        }
        FaultPlan { faults }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_plans_are_deterministic_per_seed() {
        let a = FaultPlan::random(99, 3, 4, 6);
        let b = FaultPlan::random(99, 3, 4, 6);
        assert_eq!(a, b);
        assert!(!a.faults.is_empty());
        let c = FaultPlan::random(100, 3, 4, 6);
        assert_ne!(a, c);
    }

    #[test]
    fn random_plans_stay_in_bounds_and_collision_free() {
        for seed in 0..50 {
            let plan = FaultPlan::random(seed, 3, 4, 8);
            let mut hit: Vec<(usize, usize)> = Vec::new();
            let mut reorders = vec![0usize; 3];
            for f in &plan.faults {
                let targets: Vec<(usize, usize)> = match *f {
                    Fault::ReorderPublish { phase, first, then } => {
                        assert_ne!(first, then);
                        reorders[phase] += 1;
                        vec![(phase, first), (phase, then)]
                    }
                    Fault::KillWorker { phase, path }
                    | Fault::Preempt { phase, path }
                    | Fault::Straggle { phase, path, .. }
                    | Fault::DelayPublish { phase, path, .. } => vec![(phase, path)],
                    _ => panic!("random plan drew a non-timing fault: {f:?}"),
                };
                for t in targets {
                    assert!(t.0 < 3 && t.1 < 4, "out of bounds: {t:?}");
                    assert!(!hit.contains(&t), "two faults on {t:?} (seed {seed})");
                    hit.push(t);
                }
            }
            assert!(reorders.iter().all(|&r| r <= 1));
        }
    }

    #[test]
    fn expects_abort_only_with_corruption() {
        assert!(!FaultPlan::random(1, 2, 2, 4).expects_abort());
        let plan = FaultPlan::new(vec![Fault::Corrupt {
            phase: 0,
            path: 0,
            mode: CorruptMode::FlipPayloadByte,
        }]);
        assert!(plan.expects_abort());
    }
}
