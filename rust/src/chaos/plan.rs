//! Fault taxonomy and seeded fault plans (DESIGN.md "Failure model").
//!
//! A [`FaultPlan`] is a declarative list of faults to inject into one
//! coordinator run, each addressed by `(phase, path)` — the coordinates
//! of the task it strikes. Plans are either hand-written (the named
//! scenarios in `rust/tests/integration_chaos.rs`) or drawn from a
//! seeded [`crate::util::rng::Rng`] stream ([`FaultPlan::random`]), so
//! the weekly sweep explores the scenario space while every run stays
//! exactly reproducible from its seed.
//!
//! The random generator deliberately keeps plans *oracle-clean*: at most
//! one fault per `(phase, path)`, at most one publication reorder per
//! phase, and never a fault on a reorder's dependency — each of those
//! restrictions removes a timing race that would make requeue counts (and
//! therefore the `ChaosReport`) depend on scheduler luck instead of the
//! seed. Lease-expiry holds and file corruption are only used by the
//! named scenarios, where the test controls the surrounding timing.

use crate::chaos::corruptor::CorruptMode;
use crate::util::rng::Rng;

/// One injected fault. Timing faults target the worker/queue plane;
/// `Corrupt` targets the checkpoint plane (the DPC2 file itself).
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Hard crash: the worker abandons the leased task without failing
    /// it — only lease expiry + `reclaim` recovers it.
    KillWorker { phase: usize, path: usize },
    /// Graceful preemption: the worker fails its lease, the task
    /// requeues immediately.
    Preempt { phase: usize, path: usize },
    /// The worker stalls `hold_ms` (past its lease) before running the
    /// task, forcing expiry + redelivery while the zombie lives on.
    ExpireLease {
        phase: usize,
        path: usize,
        hold_ms: u64,
    },
    /// Heterogeneous speed: the worker sleeps `delay_ms` before the
    /// task (within its lease).
    Straggle {
        phase: usize,
        path: usize,
        delay_ms: u64,
    },
    /// Checkpoint written, publication to the DB delayed `delay_ms`.
    DelayPublish {
        phase: usize,
        path: usize,
        delay_ms: u64,
    },
    /// Path `then` publishes only after path `first` has published —
    /// an adversarial arrival order for the online averaging.
    ReorderPublish {
        phase: usize,
        first: usize,
        then: usize,
    },
    /// Damage the published DPC2 file before the DB row appears, so the
    /// executor's checksum verification is exercised end to end.
    Corrupt {
        phase: usize,
        path: usize,
        mode: CorruptMode,
    },
    /// Transport plane: the first section frame of this path's publish is
    /// lost in flight; the client's capped-backoff retry must re-send it.
    NetDrop { phase: usize, path: usize },
    /// Transport plane: the first section frame of this path's publish is
    /// held `delay_ms` in flight before delivery.
    NetDelay {
        phase: usize,
        path: usize,
        delay_ms: u64,
    },
    /// Transport plane: the first section frame of this path's publish is
    /// delivered twice; the server's idempotency-key dedup must keep a
    /// single accumulation.
    NetDuplicate { phase: usize, path: usize },
    /// Transport plane: the first section frame of this path's publish
    /// arrives with a torn payload tail; the server's fletcher64 check
    /// must nack it and the client must re-send a clean copy.
    NetTruncate { phase: usize, path: usize },
}

impl Fault {
    /// Canonical one-line description (stable across runs — report keys).
    pub fn describe(&self) -> String {
        match self {
            Fault::KillWorker { phase, path } => {
                format!("phase {phase}: kill worker on path {path}")
            }
            Fault::Preempt { phase, path } => {
                format!("phase {phase}: graceful preemption on path {path}")
            }
            Fault::ExpireLease {
                phase,
                path,
                hold_ms,
            } => format!("phase {phase}: hold lease {hold_ms}ms past expiry on path {path}"),
            Fault::Straggle {
                phase,
                path,
                delay_ms,
            } => format!("phase {phase}: straggle {delay_ms}ms on path {path}"),
            Fault::DelayPublish {
                phase,
                path,
                delay_ms,
            } => format!("phase {phase}: delay publication {delay_ms}ms on path {path}"),
            Fault::ReorderPublish { phase, first, then } => {
                format!("phase {phase}: publish path {then} only after path {first}")
            }
            Fault::Corrupt { phase, path, mode } => {
                format!("phase {phase}: corrupt checkpoint of path {path} ({mode})")
            }
            Fault::NetDrop { phase, path } => {
                format!("phase {phase}: drop section frame of path {path} in flight")
            }
            Fault::NetDelay {
                phase,
                path,
                delay_ms,
            } => format!("phase {phase}: delay section frame of path {path} {delay_ms}ms in flight"),
            Fault::NetDuplicate { phase, path } => {
                format!("phase {phase}: duplicate section frame of path {path} in flight")
            }
            Fault::NetTruncate { phase, path } => {
                format!("phase {phase}: truncate section frame of path {path} in flight")
            }
        }
    }

    /// `(phase, path)` this fault strikes at *task start* (worker-side
    /// faults); `None` for publication/file-plane faults.
    pub fn task_start_target(&self) -> Option<(usize, usize)> {
        match *self {
            Fault::KillWorker { phase, path }
            | Fault::Preempt { phase, path }
            | Fault::ExpireLease { phase, path, .. }
            | Fault::Straggle { phase, path, .. } => Some((phase, path)),
            _ => None,
        }
    }

    /// `(phase, path)` whose *section send* this fault strikes (transport
    /// plane); `None` for every worker/file-plane fault.
    pub fn net_target(&self) -> Option<(usize, usize)> {
        match *self {
            Fault::NetDrop { phase, path }
            | Fault::NetDelay { phase, path, .. }
            | Fault::NetDuplicate { phase, path }
            | Fault::NetTruncate { phase, path } => Some((phase, path)),
            _ => None,
        }
    }
}

/// A set of faults to inject into one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// The fault-free plan (reference runs).
    pub fn none() -> FaultPlan {
        FaultPlan { faults: Vec::new() }
    }

    pub fn new(faults: Vec<Fault>) -> FaultPlan {
        FaultPlan { faults }
    }

    /// Plans containing file corruption must abort loudly rather than
    /// converge — the oracle's expected outcome flips on this.
    pub fn expects_abort(&self) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, Fault::Corrupt { .. }))
    }

    /// Descriptions in plan order.
    pub fn describe(&self) -> Vec<String> {
        self.faults.iter().map(Fault::describe).collect()
    }

    /// Seeded random mix of timing faults over `phases` x `paths`, up to
    /// `events` of them (fewer when a phase runs out of untouched paths).
    /// Only convergence-preserving faults are drawn — see module docs.
    pub fn random(seed: u64, phases: usize, paths: usize, events: usize) -> FaultPlan {
        assert!(phases >= 1 && paths >= 1);
        let mut rng = Rng::new(seed).fork(0xC4A05);
        let mut faults = Vec::new();
        let mut used: Vec<Vec<usize>> = vec![Vec::new(); phases];
        let mut reordered = vec![false; phases];
        for _ in 0..events {
            let phase = rng.gen_range(phases);
            let free: Vec<usize> = (0..paths).filter(|p| !used[phase].contains(p)).collect();
            if free.is_empty() {
                continue;
            }
            let mut kind = rng.gen_range(5);
            if kind == 4 && (free.len() < 2 || reordered[phase]) {
                kind = 0; // no room for a reorder here — kill instead
            }
            match kind {
                0 => {
                    let path = *rng.choose(&free);
                    used[phase].push(path);
                    faults.push(Fault::KillWorker { phase, path });
                }
                1 => {
                    let path = *rng.choose(&free);
                    used[phase].push(path);
                    faults.push(Fault::Preempt { phase, path });
                }
                2 => {
                    let path = *rng.choose(&free);
                    used[phase].push(path);
                    faults.push(Fault::Straggle {
                        phase,
                        path,
                        delay_ms: 50 + rng.gen_range(101) as u64,
                    });
                }
                3 => {
                    let path = *rng.choose(&free);
                    used[phase].push(path);
                    faults.push(Fault::DelayPublish {
                        phase,
                        path,
                        delay_ms: 20 + rng.gen_range(61) as u64,
                    });
                }
                _ => {
                    let i = rng.gen_range(free.len());
                    let first = free[i];
                    let rest: Vec<usize> = free.into_iter().filter(|&p| p != first).collect();
                    let then = *rng.choose(&rest);
                    used[phase].push(first);
                    used[phase].push(then);
                    reordered[phase] = true;
                    faults.push(Fault::ReorderPublish { phase, first, then });
                }
            }
        }
        FaultPlan { faults }
    }

    /// Seeded random mix of *transport-plane* faults (the weekly sweep's
    /// network leg): drop/delay/duplicate/truncate a section frame in
    /// flight, at most one per `(phase, path)`. Deliberately separate
    /// from [`FaultPlan::random`]: the timing sweep's invariants (and its
    /// tests) promise worker/queue faults only, and every net fault here
    /// is convergence-preserving by construction — the client retries,
    /// the server dedups, so the oracle still demands ConvergedIdentical.
    pub fn random_net(seed: u64, phases: usize, paths: usize, events: usize) -> FaultPlan {
        assert!(phases >= 1 && paths >= 1);
        let mut rng = Rng::new(seed).fork(0x7E75);
        let mut faults = Vec::new();
        let mut used: Vec<Vec<usize>> = vec![Vec::new(); phases];
        for _ in 0..events {
            let phase = rng.gen_range(phases);
            let free: Vec<usize> = (0..paths).filter(|p| !used[phase].contains(p)).collect();
            if free.is_empty() {
                continue;
            }
            let path = *rng.choose(&free);
            used[phase].push(path);
            faults.push(match rng.gen_range(4) {
                0 => Fault::NetDrop { phase, path },
                1 => Fault::NetDelay {
                    phase,
                    path,
                    delay_ms: 10 + rng.gen_range(31) as u64,
                },
                2 => Fault::NetDuplicate { phase, path },
                _ => Fault::NetTruncate { phase, path },
            });
        }
        FaultPlan { faults }
    }
}

/// One injected serving-plane fault: a scripted misbehaviour of one
/// path's executor, consumed one forward call at a time by
/// [`crate::chaos::injector::ChaosExec`].
///
/// `batches` is the fault's budget: how many consecutive forward calls on
/// that path misbehave before the executor heals. Scenario construction
/// keeps `batches` equal to the breaker's `min_samples`, so the last
/// faulted batch is exactly the one that trips the breaker — every
/// planned fault fires before admission stops routing to the path, which
/// is what keeps [`crate::chaos::oracle::ServeChaosReport`] deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeFault {
    /// The executor panics mid-forward (exercises the supervisor:
    /// catch_unwind, loud batch resolution, backoff restart).
    PanicExec { path: usize, batches: usize },
    /// The executor wedges for `wedge_ms` and then fails the batch — a
    /// stuck forward call that a watchdog eventually kills (exercises the
    /// breaker's error-rate trip with realistic slow failures).
    WedgeBatch {
        path: usize,
        batches: usize,
        wedge_ms: u64,
    },
    /// The executor still answers, but `delay_ms` late (exercises the
    /// breaker's latency trip: a slow path is sick even when correct).
    SlowExec {
        path: usize,
        batches: usize,
        delay_ms: u64,
    },
}

impl ServeFault {
    /// Path whose executor this fault strikes.
    pub fn path(&self) -> usize {
        match *self {
            ServeFault::PanicExec { path, .. }
            | ServeFault::WedgeBatch { path, .. }
            | ServeFault::SlowExec { path, .. } => path,
        }
    }

    /// Forward calls this fault consumes before the executor heals.
    pub fn batches(&self) -> usize {
        match *self {
            ServeFault::PanicExec { batches, .. }
            | ServeFault::WedgeBatch { batches, .. }
            | ServeFault::SlowExec { batches, .. } => batches,
        }
    }

    /// Canonical one-line description (stable across runs — report keys).
    pub fn describe(&self) -> String {
        match self {
            ServeFault::PanicExec { path, batches } => {
                format!("path {path}: panic executor for {batches} batches")
            }
            ServeFault::WedgeBatch {
                path,
                batches,
                wedge_ms,
            } => format!("path {path}: wedge {batches} batches for {wedge_ms}ms"),
            ServeFault::SlowExec {
                path,
                batches,
                delay_ms,
            } => format!("path {path}: slow executor for {batches} batches by {delay_ms}ms"),
        }
    }
}

/// A set of serving faults for one serve-chaos scenario. At most one
/// fault per path: a second fault on the same path could never drain its
/// budget (the first one trips the breaker and admission stops routing
/// there), which would make the scenario's `unfired` list non-empty by
/// construction.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeFaultPlan {
    pub faults: Vec<ServeFault>,
}

impl ServeFaultPlan {
    /// The fault-free plan (reference runs).
    pub fn none() -> ServeFaultPlan {
        ServeFaultPlan { faults: Vec::new() }
    }

    pub fn new(faults: Vec<ServeFault>) -> ServeFaultPlan {
        let mut seen = Vec::new();
        for f in &faults {
            assert!(
                !seen.contains(&f.path()),
                "two serve faults on path {} — the second could never fire",
                f.path()
            );
            seen.push(f.path());
        }
        ServeFaultPlan { faults }
    }

    /// Descriptions in plan order.
    pub fn describe(&self) -> Vec<String> {
        self.faults.iter().map(ServeFault::describe).collect()
    }

    /// Faulted path ids, ascending and deduplicated.
    pub fn faulted_paths(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.faults.iter().map(ServeFault::path).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Seeded random mix of serving faults over `paths`, up to `events`
    /// of them. Always leaves at least one path fault-free so degraded
    /// routing has a redirect target; every fault gets the same `batches`
    /// budget (the scenario's breaker `min_samples`). Injected delays stay
    /// >= 20ms, above the scenario breaker's latency trip threshold.
    pub fn random(seed: u64, paths: usize, events: usize, batches: usize) -> ServeFaultPlan {
        assert!(paths >= 2, "need a healthy path to redirect to");
        let mut rng = Rng::new(seed).fork(0x5E2E);
        let mut used = vec![false; paths];
        let mut faults = Vec::new();
        for _ in 0..events {
            let free: Vec<usize> = (0..paths).filter(|&p| !used[p]).collect();
            if free.len() <= 1 {
                break; // keep one healthy fallback
            }
            let path = *rng.choose(&free);
            used[path] = true;
            faults.push(match rng.gen_range(3) {
                0 => ServeFault::PanicExec { path, batches },
                1 => ServeFault::WedgeBatch {
                    path,
                    batches,
                    wedge_ms: 20 + rng.gen_range(21) as u64,
                },
                _ => ServeFault::SlowExec {
                    path,
                    batches,
                    delay_ms: 20 + rng.gen_range(21) as u64,
                },
            });
        }
        ServeFaultPlan { faults }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_plans_are_deterministic_per_seed() {
        let a = FaultPlan::random(99, 3, 4, 6);
        let b = FaultPlan::random(99, 3, 4, 6);
        assert_eq!(a, b);
        assert!(!a.faults.is_empty());
        let c = FaultPlan::random(100, 3, 4, 6);
        assert_ne!(a, c);
    }

    #[test]
    fn random_plans_stay_in_bounds_and_collision_free() {
        for seed in 0..50 {
            let plan = FaultPlan::random(seed, 3, 4, 8);
            let mut hit: Vec<(usize, usize)> = Vec::new();
            let mut reorders = vec![0usize; 3];
            for f in &plan.faults {
                let targets: Vec<(usize, usize)> = match *f {
                    Fault::ReorderPublish { phase, first, then } => {
                        assert_ne!(first, then);
                        reorders[phase] += 1;
                        vec![(phase, first), (phase, then)]
                    }
                    Fault::KillWorker { phase, path }
                    | Fault::Preempt { phase, path }
                    | Fault::Straggle { phase, path, .. }
                    | Fault::DelayPublish { phase, path, .. } => vec![(phase, path)],
                    _ => panic!("random plan drew a non-timing fault: {f:?}"),
                };
                for t in targets {
                    assert!(t.0 < 3 && t.1 < 4, "out of bounds: {t:?}");
                    assert!(!hit.contains(&t), "two faults on {t:?} (seed {seed})");
                    hit.push(t);
                }
            }
            assert!(reorders.iter().all(|&r| r <= 1));
        }
    }

    #[test]
    fn random_net_plans_draw_only_in_bounds_transport_faults() {
        let a = FaultPlan::random_net(42, 2, 3, 5);
        assert_eq!(a, FaultPlan::random_net(42, 2, 3, 5), "seed-deterministic");
        assert!(!a.faults.is_empty());
        for seed in 0..50 {
            let plan = FaultPlan::random_net(seed, 2, 3, 5);
            assert!(!plan.expects_abort(), "net faults all recover");
            let mut hit: Vec<(usize, usize)> = Vec::new();
            for f in &plan.faults {
                let t = f
                    .net_target()
                    .unwrap_or_else(|| panic!("net plan drew a non-transport fault: {f:?}"));
                assert_eq!(f.task_start_target(), None, "net faults skip task-start");
                assert!(t.0 < 2 && t.1 < 3, "out of bounds: {t:?}");
                assert!(!hit.contains(&t), "two faults on {t:?} (seed {seed})");
                hit.push(t);
                if let Fault::NetDelay { delay_ms, .. } = *f {
                    assert!((10..=40).contains(&delay_ms));
                }
            }
        }
    }

    #[test]
    fn expects_abort_only_with_corruption() {
        assert!(!FaultPlan::random(1, 2, 2, 4).expects_abort());
        let plan = FaultPlan::new(vec![Fault::Corrupt {
            phase: 0,
            path: 0,
            mode: CorruptMode::FlipPayloadByte,
        }]);
        assert!(plan.expects_abort());
    }

    #[test]
    fn random_serve_plans_deterministic_and_leave_a_fallback() {
        let a = ServeFaultPlan::random(7, 3, 4, 3);
        let b = ServeFaultPlan::random(7, 3, 4, 3);
        assert_eq!(a, b);
        assert!(!a.faults.is_empty());
        for seed in 0..50 {
            let plan = ServeFaultPlan::random(seed, 3, 4, 3);
            let faulted = plan.faulted_paths();
            assert!(faulted.len() < 3, "seed {seed} faulted every path");
            assert_eq!(
                faulted.len(),
                plan.faults.len(),
                "seed {seed} hit one path twice"
            );
            for f in &plan.faults {
                assert!(f.path() < 3);
                assert_eq!(f.batches(), 3);
                match *f {
                    ServeFault::WedgeBatch { wedge_ms, .. } => assert!(wedge_ms >= 20),
                    ServeFault::SlowExec { delay_ms, .. } => assert!(delay_ms >= 20),
                    ServeFault::PanicExec { .. } => {}
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "two serve faults on path 1")]
    fn serve_plan_rejects_double_faulted_path() {
        ServeFaultPlan::new(vec![
            ServeFault::PanicExec { path: 1, batches: 3 },
            ServeFault::SlowExec {
                path: 1,
                batches: 3,
                delay_ms: 25,
            },
        ]);
    }
}
