//! Torn-write simulator for DPC2 checkpoint files.
//!
//! Mutates an on-disk checkpoint the way a crashed writer or bad disk
//! would, so the fletcher64 verification in [`crate::params::checkpoint`]
//! is exercised end to end through the coordinator path (executor opens
//! the file via `SectionReader` and must fail loudly, never average
//! garbage into the `ModuleStore`). Three damage modes, each tripping a
//! *different* detector:
//!
//! * [`CorruptMode::TruncatePayload`] — cut the file mid-payload; the
//!   section read past the cut fails with "truncated payload" before any
//!   checksum is even computed.
//! * [`CorruptMode::FlipPayloadByte`] — flip one payload byte; the
//!   per-section fletcher64 reports "checksum mismatch (torn write?)".
//! * [`CorruptMode::DamageDirectory`] — flip a byte of the directory
//!   trailer checksum; `SectionReader::open` itself refuses the file
//!   ("section directory checksum mismatch").

use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorruptMode {
    TruncatePayload,
    FlipPayloadByte,
    DamageDirectory,
}

impl fmt::Display for CorruptMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            CorruptMode::TruncatePayload => "truncate-payload",
            CorruptMode::FlipPayloadByte => "flip-payload-byte",
            CorruptMode::DamageDirectory => "damage-directory",
        })
    }
}

/// Damage `path` in place. The file must be a DPC2 checkpoint; the header
/// is parsed just enough to aim the damage at the right region (payload
/// vs directory trailer).
pub fn corrupt_file(path: &Path, mode: CorruptMode) -> Result<()> {
    let mut bytes =
        std::fs::read(path).with_context(|| format!("corruptor reading {}", path.display()))?;
    anyhow::ensure!(
        bytes.len() >= 12 && &bytes[..4] == b"DPC2",
        "{}: corruptor needs a DPC2 file",
        path.display()
    );
    let header_len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
    anyhow::ensure!(
        (20..bytes.len()).contains(&header_len),
        "{}: implausible header length {header_len}",
        path.display()
    );
    let payload = bytes.len() - header_len;
    match mode {
        CorruptMode::TruncatePayload => {
            anyhow::ensure!(payload >= 2, "{}: no payload to truncate", path.display());
            bytes.truncate(header_len + payload / 2);
        }
        CorruptMode::FlipPayloadByte => {
            anyhow::ensure!(payload >= 1, "{}: no payload to flip", path.display());
            let i = header_len + (payload - 1).min(100);
            bytes[i] ^= 0xFF;
        }
        CorruptMode::DamageDirectory => {
            // last byte of the directory trailer checksum
            bytes[header_len - 1] ^= 0xFF;
        }
    }
    // plain non-atomic write: we are *simulating* a torn write
    std::fs::write(path, &bytes).with_context(|| format!("corruptor writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::checkpoint::save_sections;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("dipaco-corruptor-{}-{name}", std::process::id()))
    }

    #[test]
    fn refuses_non_dpc2_files() {
        let p = tmp("not-dpc");
        std::fs::write(&p, b"hello world, definitely not a checkpoint").unwrap();
        let err = corrupt_file(&p, CorruptMode::FlipPayloadByte).unwrap_err();
        assert!(format!("{err:#}").contains("needs a DPC2 file"));
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn truncation_shrinks_flip_preserves_length() {
        let data: Vec<f32> = (0..128).map(|i| i as f32).collect();
        let p = tmp("trunc");
        save_sections(&p, &[("theta", data.as_slice())]).unwrap();
        let full = std::fs::metadata(&p).unwrap().len();
        corrupt_file(&p, CorruptMode::TruncatePayload).unwrap();
        assert!(std::fs::metadata(&p).unwrap().len() < full);

        let p2 = tmp("flip");
        save_sections(&p2, &[("theta", data.as_slice())]).unwrap();
        let before = std::fs::read(&p2).unwrap();
        corrupt_file(&p2, CorruptMode::FlipPayloadByte).unwrap();
        let after = std::fs::read(&p2).unwrap();
        assert_eq!(before.len(), after.len());
        assert_eq!(
            before.iter().zip(&after).filter(|(a, b)| a != b).count(),
            1,
            "exactly one byte flipped"
        );
        for f in [p, p2] {
            std::fs::remove_file(&f).unwrap();
        }
    }
}
