//! The framed-TCP exchange plane: per-executor section servers plus the
//! worker-side push client.
//!
//! Topology (loopback rendezvous for now — the registry already speaks
//! `SocketAddr`, so spreading executors across hosts is a config change,
//! not a code change):
//!
//! * One [`SectionServer`] per executor shard, bound to an ephemeral
//!   loopback port, owning an in-memory [`SectionStore`]. A put is
//!   accepted only when its fletcher64 trailer verifies; a torn payload
//!   is nacked and the connection survives (lengths frame the stream).
//!   The `(key, section)` pair dedups redelivered publishes — a
//!   retransmit race or a zombie worker's re-push acks without
//!   double-storing.
//! * [`TcpExchange`] implements [`SectionTransport`]: `publish` reads
//!   the just-saved DPC2 checkpoint once (pooled buffer, same
//!   `read_into` path executors use) and pushes each `delta:` section to
//!   its owning executor per the [`Rendezvous`] registry, with connect
//!   and read timeouts plus capped-backoff retry; `open` serves executor
//!   reads from the union of the stores with the exact accounting shape
//!   of a mapped DPC2 read (`bytes_read` counts payload bytes, opening
//!   counts nothing).
//!
//! Chaos: the client consults [`FaultInjector::on_net_send`] once per
//! frame; a planned fault strikes the first frame of the targeted
//! publish (drop / delay / duplicate / truncate-in-flight) and the retry
//! machinery must recover without changing any converged byte.

use std::collections::{HashMap, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::chaos::injector::{FaultInjector, NetAction};
use crate::config::TransportConfig;
use crate::params::checkpoint::{write_f32s_le, SectionReader};
use crate::topology::ModuleId;
use crate::transport::frame::{self, Frame, FrameKind};
use crate::transport::rendezvous::Rendezvous;
use crate::transport::{PublishCtx, SectionSource, SectionTransport};
use crate::util::pool::Pool;

/// Server-side acceptance counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Sections accepted and stored.
    pub puts: u64,
    /// Redelivered puts deduplicated by idempotency key (acked, not
    /// re-stored).
    pub dup_puts: u64,
    /// Puts nacked for a payload checksum mismatch.
    pub nacks: u64,
}

#[derive(Default)]
struct StoreInner {
    /// `(file key, section name) -> payload bytes` (f32 LE, as framed).
    sections: HashMap<(String, String), Arc<Vec<u8>>>,
    /// Idempotency keys already accepted.
    seen: HashSet<(String, String)>,
    stats: StoreStats,
}

/// One executor's received sections. Shared: the accept loop's
/// connection handlers write, the executor's [`SectionSource`] reads.
#[derive(Default)]
pub struct SectionStore {
    inner: Mutex<StoreInner>,
}

impl SectionStore {
    /// Accept a verified put. Returns false when the idempotency key was
    /// already accepted (the caller still acks — redelivery is success).
    fn put(&self, key: &str, section: &str, payload: Vec<u8>) -> bool {
        let id = (key.to_string(), section.to_string());
        let mut g = self.inner.lock().unwrap();
        if !g.seen.insert(id.clone()) {
            g.stats.dup_puts += 1;
            return false;
        }
        g.stats.puts += 1;
        g.sections.insert(id, Arc::new(payload));
        true
    }

    fn nacked(&self) {
        self.inner.lock().unwrap().stats.nacks += 1;
    }

    fn get(&self, key: &str, section: &str) -> Option<Arc<Vec<u8>>> {
        self.inner
            .lock()
            .unwrap()
            .sections
            .get(&(key.to_string(), section.to_string()))
            .cloned()
    }

    pub fn stats(&self) -> StoreStats {
        self.inner.lock().unwrap().stats
    }
}

/// Framed-TCP listener for one executor shard.
pub struct SectionServer {
    addr: SocketAddr,
    store: Arc<SectionStore>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl SectionServer {
    pub fn bind(executor: usize) -> Result<SectionServer> {
        let listener = TcpListener::bind(("127.0.0.1", 0))
            .with_context(|| format!("binding section server for executor {executor}"))?;
        let addr = listener
            .local_addr()
            .context("section server local addr")?;
        let store = Arc::new(SectionStore::default());
        let stop = Arc::new(AtomicBool::new(false));
        let store2 = Arc::clone(&store);
        let stop2 = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name(format!("section-srv-{executor}"))
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let store = Arc::clone(&store2);
                    // Handlers exit when the peer closes; publishes are
                    // short-lived connections, so these never outlive a
                    // phase by more than a socket teardown.
                    let _ = std::thread::Builder::new()
                        .name("section-conn".into())
                        .spawn(move || serve_conn(stream, store));
                }
            })
            .context("spawning section server accept loop")?;
        Ok(SectionServer {
            addr,
            store,
            stop,
            accept: Some(accept),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn store(&self) -> Arc<SectionStore> {
        Arc::clone(&self.store)
    }

    pub fn stop(&mut self) {
        if self.stop.swap(true, Ordering::Relaxed) {
            return;
        }
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SectionServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_conn(mut stream: TcpStream, store: Arc<SectionStore>) {
    let _ = stream.set_nodelay(true);
    loop {
        // Peer hangup (EOF) or structural garbage both end the
        // connection; a checksum mismatch does not.
        let Ok(rf) = frame::read_frame(&mut stream) else {
            return;
        };
        if rf.frame.kind != FrameKind::Put {
            continue;
        }
        let reply = if !rf.checksum_ok {
            store.nacked();
            Frame::nack(format!(
                "section {}: frame checksum mismatch (torn in flight?)",
                rf.frame.section
            ))
        } else {
            store.put(&rf.frame.key, &rf.frame.section, rf.frame.payload);
            Frame::ack(&rf.frame.key)
        };
        if frame::write_frame(&mut stream, &reply).is_err() {
            return;
        }
    }
}

/// The TCP section exchange: servers for every executor shard plus the
/// push client workers publish through.
pub struct TcpExchange {
    cfg: TransportConfig,
    rendezvous: Rendezvous,
    servers: Vec<SectionServer>,
    pool: Arc<Pool<f32>>,
    chaos: Option<Arc<FaultInjector>>,
    sends: AtomicU64,
    resends: AtomicU64,
}

impl TcpExchange {
    /// Bind one server per executor shard and build the rendezvous
    /// registry over the resulting endpoints.
    pub fn start(
        shards: &[Vec<ModuleId>],
        cfg: TransportConfig,
        chaos: Option<Arc<FaultInjector>>,
    ) -> Result<Arc<TcpExchange>> {
        let mut servers = Vec::with_capacity(shards.len());
        for e in 0..shards.len() {
            servers.push(SectionServer::bind(e)?);
        }
        let endpoints = servers.iter().map(SectionServer::addr).collect();
        Ok(Arc::new(TcpExchange {
            cfg,
            rendezvous: Rendezvous::new(shards, endpoints),
            servers,
            pool: Pool::new(64),
            chaos,
            sends: AtomicU64::new(0),
            resends: AtomicU64::new(0),
        }))
    }

    pub fn rendezvous(&self) -> &Rendezvous {
        &self.rendezvous
    }

    /// Frames acked on their final attempt.
    pub fn sends(&self) -> u64 {
        self.sends.load(Ordering::Relaxed)
    }

    /// Failed attempts that went back through the backoff loop.
    pub fn resends(&self) -> u64 {
        self.resends.load(Ordering::Relaxed)
    }

    /// Acceptance counters summed over every executor's store.
    pub fn store_stats(&self) -> StoreStats {
        let mut total = StoreStats::default();
        for s in &self.servers {
            let st = s.store.stats();
            total.puts += st.puts;
            total.dup_puts += st.dup_puts;
            total.nacks += st.nacks;
        }
        total
    }

    fn backoff(&self, attempt: u32) -> Duration {
        let ms = self
            .cfg
            .backoff_ms
            .saturating_mul(1u64 << attempt.min(16));
        Duration::from_millis(ms.min(self.cfg.backoff_cap_ms))
    }

    /// Push one section frame, retrying with capped backoff. The chaos
    /// hook is consulted on the first attempt only — a consumed fault
    /// never strikes the retry, mirroring every other injector hook.
    fn send_section(
        &self,
        addr: SocketAddr,
        ctx: &PublishCtx,
        key: &str,
        section: &str,
        payload: &[u8],
    ) -> Result<()> {
        let mut attempt: u32 = 0;
        loop {
            let action = match (&self.chaos, attempt) {
                (Some(inj), 0) => inj.on_net_send(ctx.phase, ctx.path),
                _ => NetAction::Deliver,
            };
            match self.try_send(addr, key, section, payload, action) {
                Ok(()) => {
                    self.sends.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                Err(e) if attempt < self.cfg.retries => {
                    self.resends.fetch_add(1, Ordering::Relaxed);
                    crate::debug!(
                        "transport",
                        "section {section} attempt {} failed ({e:#}); backing off",
                        attempt + 1
                    );
                    std::thread::sleep(self.backoff(attempt));
                    attempt += 1;
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!(
                            "section {section}: {} send attempts exhausted",
                            self.cfg.retries + 1
                        )
                    })
                }
            }
        }
    }

    /// One connect + put + ack round trip, with the chaos action applied
    /// in flight.
    fn try_send(
        &self,
        addr: SocketAddr,
        key: &str,
        section: &str,
        payload: &[u8],
        action: NetAction,
    ) -> Result<()> {
        match action {
            NetAction::Drop => bail!("chaos-inject: section frame dropped in flight"),
            NetAction::Delay(d) => std::thread::sleep(d),
            _ => {}
        }
        let mut stream = TcpStream::connect_timeout(
            &addr,
            Duration::from_millis(self.cfg.connect_timeout_ms),
        )
        .with_context(|| format!("connecting executor endpoint {addr}"))?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(Duration::from_millis(self.cfg.read_timeout_ms)))
            .context("setting read timeout")?;
        let _ = stream.set_write_timeout(Some(Duration::from_millis(self.cfg.read_timeout_ms)));

        let f = Frame::put(key, section, payload.to_vec());
        let mut expect_replies = 1;
        match action {
            NetAction::Truncate if !f.payload.is_empty() => {
                // Torn tail under the clean checksum: exactly what a tear
                // between checksumming and the wire produces. The server
                // must nack; this attempt then fails and the retry sends
                // the clean frame.
                let clean_sum = frame::payload_checksum(&f.payload);
                let mut torn = f.clone();
                let n = torn.payload.len();
                for b in &mut torn.payload[n - n.min(8)..] {
                    *b ^= 0xFF;
                }
                frame::write_frame_unchecked(&mut stream, &torn, clean_sum)?;
            }
            NetAction::Duplicate => {
                // Retransmit race: the same frame lands twice; the
                // server's idempotency dedup keeps one accumulation.
                frame::write_frame(&mut stream, &f)?;
                frame::write_frame(&mut stream, &f)?;
                expect_replies = 2;
            }
            _ => frame::write_frame(&mut stream, &f)?,
        }
        let mut last_kind = FrameKind::Nack;
        let mut last_key = String::new();
        for _ in 0..expect_replies {
            let rf = frame::read_frame(&mut stream)
                .with_context(|| format!("awaiting ack for section {section}"))?;
            last_kind = rf.frame.kind;
            last_key = rf.frame.key;
        }
        match last_kind {
            FrameKind::Ack => Ok(()),
            FrameKind::Nack => bail!("executor nacked section {section}: {last_key}"),
            FrameKind::Put => bail!("unexpected Put reply for section {section}"),
        }
    }
}

impl SectionTransport for TcpExchange {
    fn publish(&self, ctx: &PublishCtx, file: &Path, modules: &[ModuleId]) -> Result<()> {
        if modules.is_empty() {
            return Ok(());
        }
        let mut reader = SectionReader::open_mapped(file)
            .with_context(|| format!("transport opening {}", file.display()))?;
        let key = file.to_string_lossy().into_owned();
        let mut wire = Pool::take(&self.pool, 0);
        for (owner, mods) in self.rendezvous.group_by_owner(modules)? {
            let addr = self.rendezvous.endpoint(owner);
            for m in mods {
                let section = m.delta_section();
                reader
                    .read_into(&section, &mut wire)
                    .with_context(|| format!("transport reading {} of {}", m, file.display()))?;
                let mut payload = Vec::with_capacity(wire.len() * 4);
                write_f32s_le(&mut payload, &wire);
                self.send_section(addr, ctx, &key, &section, &payload)
                    .with_context(|| {
                        format!(
                            "pushing {section} of {} to executor {owner}",
                            file.display()
                        )
                    })?;
            }
        }
        Ok(())
    }

    fn open(&self, file: &Path) -> Result<Box<dyn SectionSource>> {
        Ok(Box::new(NetSource {
            key: file.to_string_lossy().into_owned(),
            stores: self.servers.iter().map(SectionServer::store).collect(),
            bytes_read: 0,
        }))
    }

    fn describe(&self) -> &'static str {
        "tcp"
    }
}

/// Executor-side reads over the union of the exchange's stores. The
/// union (not just the executor's own shard) keeps late-merge reads —
/// which may touch modules another shard owns — working unchanged.
struct NetSource {
    key: String,
    stores: Vec<Arc<SectionStore>>,
    bytes_read: u64,
}

impl SectionSource for NetSource {
    fn read_into(&mut self, name: &str, out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        let payload = self
            .stores
            .iter()
            .find_map(|s| s.get(&self.key, name))
            .with_context(|| {
                format!(
                    "section {name}: not delivered to any executor endpoint for {}",
                    self.key
                )
            })?;
        if payload.len() % 4 != 0 {
            bail!("section {name}: truncated payload");
        }
        out.reserve(payload.len() / 4);
        for c in payload.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        // Same watermark shape as a mapped DPC2 read: payload bytes only.
        self.bytes_read += payload.len() as u64;
        Ok(())
    }

    fn bytes_read(&self) -> u64 {
        self.bytes_read
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::plan::{Fault, FaultPlan};
    use crate::config::TransportMode;
    use crate::params::checkpoint::Checkpoint;

    fn mid(level: usize, expert: usize) -> ModuleId {
        ModuleId { level, expert }
    }

    fn tcp_cfg() -> TransportConfig {
        TransportConfig {
            mode: TransportMode::Tcp,
            backoff_ms: 1,
            backoff_cap_ms: 5,
            ..TransportConfig::default()
        }
    }

    /// DPC2 checkpoint with two delta sections (plus a non-delta section
    /// the publish must skip), in its own temp dir.
    fn sample_checkpoint(tag: &str) -> (std::path::PathBuf, Vec<f32>, Vec<f32>) {
        let dir = std::env::temp_dir().join(format!("dipaco-ttcp-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("p0.dpc2");
        let a = vec![1.0f32, -2.5, 3.25];
        let b = vec![0.5f32, 4.0];
        let mut ck = Checkpoint::new();
        ck.sections.push(("delta:L0E0".into(), a.clone()));
        ck.sections.push(("delta:L0E1".into(), b.clone()));
        ck.sections.push(("loss".into(), vec![0.1]));
        ck.save(&file).unwrap();
        (file, a, b)
    }

    fn publish_ctx() -> PublishCtx {
        PublishCtx {
            phase: 0,
            path: 0,
            kind: "delta".into(),
        }
    }

    fn read_back(ex: &TcpExchange, file: &Path, a: &[f32], b: &[f32]) {
        let mut src = ex.open(file).unwrap();
        let mut out = Vec::new();
        src.read_into("delta:L0E0", &mut out).unwrap();
        assert_eq!(out, a);
        src.read_into("delta:L0E1", &mut out).unwrap();
        assert_eq!(out, b);
        assert_eq!(src.bytes_read(), 4 * (a.len() + b.len()) as u64);
    }

    #[test]
    fn sections_route_to_their_owning_executor_and_read_back() {
        let (file, a, b) = sample_checkpoint("route");
        let shards = vec![vec![mid(0, 0)], vec![mid(0, 1)]];
        let ex = TcpExchange::start(&shards, tcp_cfg(), None).unwrap();
        ex.publish(&publish_ctx(), &file, &[mid(0, 0), mid(0, 1)])
            .unwrap();
        // each server accepted exactly its own module's section
        assert_eq!(ex.servers[0].store.stats().puts, 1);
        assert_eq!(ex.servers[1].store.stats().puts, 1);
        assert_eq!(ex.sends(), 2);
        assert_eq!(ex.resends(), 0);
        read_back(&ex, &file, &a, &b);
        // a section nobody published is loud
        let mut src = ex.open(&file).unwrap();
        let err = src.read_into("delta:L7E7", &mut Vec::new()).unwrap_err();
        assert!(err.to_string().contains("not delivered"), "{err:#}");
        std::fs::remove_dir_all(file.parent().unwrap()).ok();
    }

    #[test]
    fn dropped_frame_is_retried_to_convergence() {
        let (file, a, b) = sample_checkpoint("drop");
        let shards = vec![vec![mid(0, 0), mid(0, 1)]];
        let inj = Arc::new(FaultInjector::new(&FaultPlan::new(vec![Fault::NetDrop {
            phase: 0,
            path: 0,
        }])));
        let ex = TcpExchange::start(&shards, tcp_cfg(), Some(Arc::clone(&inj))).unwrap();
        ex.publish(&publish_ctx(), &file, &[mid(0, 0), mid(0, 1)])
            .unwrap();
        assert_eq!(inj.fired_events().len(), 1);
        assert!(inj.unfired().is_empty());
        assert!(ex.resends() >= 1, "drop must cost a retry");
        assert_eq!(ex.store_stats().puts, 2);
        read_back(&ex, &file, &a, &b);
        std::fs::remove_dir_all(file.parent().unwrap()).ok();
    }

    #[test]
    fn duplicated_frame_is_deduped_by_idempotency_key() {
        let (file, a, b) = sample_checkpoint("dup");
        let shards = vec![vec![mid(0, 0), mid(0, 1)]];
        let inj = Arc::new(FaultInjector::new(&FaultPlan::new(vec![
            Fault::NetDuplicate { phase: 0, path: 0 },
        ])));
        let ex = TcpExchange::start(&shards, tcp_cfg(), Some(Arc::clone(&inj))).unwrap();
        ex.publish(&publish_ctx(), &file, &[mid(0, 0), mid(0, 1)])
            .unwrap();
        let st = ex.store_stats();
        assert_eq!(st.puts, 2, "one accumulation per section");
        assert_eq!(st.dup_puts, 1, "the retransmit was acked but deduped");
        assert_eq!(ex.resends(), 0);
        read_back(&ex, &file, &a, &b);
        std::fs::remove_dir_all(file.parent().unwrap()).ok();
    }

    #[test]
    fn truncated_frame_is_nacked_and_resent_clean() {
        let (file, a, b) = sample_checkpoint("trunc");
        let shards = vec![vec![mid(0, 0), mid(0, 1)]];
        let inj = Arc::new(FaultInjector::new(&FaultPlan::new(vec![
            Fault::NetTruncate { phase: 0, path: 0 },
        ])));
        let ex = TcpExchange::start(&shards, tcp_cfg(), Some(Arc::clone(&inj))).unwrap();
        ex.publish(&publish_ctx(), &file, &[mid(0, 0), mid(0, 1)])
            .unwrap();
        let st = ex.store_stats();
        assert_eq!(st.nacks, 1, "the torn frame must be rejected");
        assert_eq!(st.puts, 2);
        assert!(ex.resends() >= 1, "nack must cost a retry");
        read_back(&ex, &file, &a, &b);
        std::fs::remove_dir_all(file.parent().unwrap()).ok();
    }

    #[test]
    fn exhausted_retries_surface_a_typed_error() {
        let (file, _, _) = sample_checkpoint("exhaust");
        let shards = vec![vec![mid(0, 0), mid(0, 1)]];
        // with zero retries the single dropped attempt is the whole
        // budget, so the failure must surface instead of being retried
        let inj = Arc::new(FaultInjector::new(&FaultPlan::new(vec![Fault::NetDrop {
            phase: 0,
            path: 0,
        }])));
        let cfg = TransportConfig {
            retries: 0,
            ..tcp_cfg()
        };
        let ex = TcpExchange::start(&shards, cfg, Some(inj)).unwrap();
        let err = ex
            .publish(&publish_ctx(), &file, &[mid(0, 0)])
            .unwrap_err();
        assert!(
            err.to_string().contains("attempts exhausted") || format!("{err:#}").contains("attempts exhausted"),
            "{err:#}"
        );
        std::fs::remove_dir_all(file.parent().unwrap()).ok();
    }

    #[test]
    fn delayed_frame_arrives_late_but_intact() {
        let (file, a, b) = sample_checkpoint("delay");
        let shards = vec![vec![mid(0, 0), mid(0, 1)]];
        let inj = Arc::new(FaultInjector::new(&FaultPlan::new(vec![Fault::NetDelay {
            phase: 0,
            path: 0,
            delay_ms: 30,
        }])));
        let ex = TcpExchange::start(&shards, tcp_cfg(), Some(Arc::clone(&inj))).unwrap();
        let t0 = std::time::Instant::now();
        ex.publish(&publish_ctx(), &file, &[mid(0, 0), mid(0, 1)])
            .unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(30), "delay applied");
        assert_eq!(ex.resends(), 0, "a delayed frame is not a failed one");
        read_back(&ex, &file, &a, &b);
        std::fs::remove_dir_all(file.parent().unwrap()).ok();
    }
}
