//! Wire framing for the TCP exchange plane.
//!
//! One frame per message, length-prefixed so a reader always knows how
//! many bytes to consume — a torn *payload* therefore desyncs nothing:
//! the lengths still frame the stream, the fletcher64 trailer fails, and
//! the server can nack and keep the connection. Layout (all integers LE):
//!
//! ```text
//! "DPSX" | kind u8 | key_len u32 | section_len u32 | payload_len u32
//!        | key bytes | section bytes | payload bytes
//!        | fletcher64(payload) u64
//! ```
//!
//! * `Put` — `key` is the client-supplied idempotency scope (the
//!   checkpoint file's canonical path); `section` the section name;
//!   `payload` the section's f32 LE bytes, exactly as a DPC2 file stores
//!   them. The `(key, section)` pair is the dedup identity: a redelivered
//!   publish (retransmit race, zombie worker) cannot double-accumulate.
//! * `Ack` — `key` echoes the put's key; section/payload empty.
//! * `Nack` — `key` carries the reason; section/payload empty.
//!
//! The payload checksum is [`crate::params::checkpoint::fletcher64`] —
//! the same function the DPC2 file format uses, so the file plane and
//! the network plane can never disagree about what "intact" means.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::params::checkpoint::fletcher64;

pub const MAGIC: [u8; 4] = *b"DPSX";
/// Caps keep a malformed or hostile header from asking the reader to
/// allocate unbounded buffers.
pub const MAX_KEY: usize = 4096;
pub const MAX_SECTION: usize = 4096;
pub const MAX_PAYLOAD: usize = 1 << 28;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    Put,
    Ack,
    Nack,
}

impl FrameKind {
    fn as_u8(self) -> u8 {
        match self {
            FrameKind::Put => 1,
            FrameKind::Ack => 2,
            FrameKind::Nack => 3,
        }
    }

    fn from_u8(b: u8) -> Option<FrameKind> {
        match b {
            1 => Some(FrameKind::Put),
            2 => Some(FrameKind::Ack),
            3 => Some(FrameKind::Nack),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub key: String,
    pub section: String,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn put(key: &str, section: &str, payload: Vec<u8>) -> Frame {
        Frame {
            kind: FrameKind::Put,
            key: key.to_string(),
            section: section.to_string(),
            payload,
        }
    }

    pub fn ack(key: &str) -> Frame {
        Frame {
            kind: FrameKind::Ack,
            key: key.to_string(),
            section: String::new(),
            payload: Vec::new(),
        }
    }

    pub fn nack(reason: String) -> Frame {
        Frame {
            kind: FrameKind::Nack,
            key: reason,
            section: String::new(),
            payload: Vec::new(),
        }
    }
}

/// A frame as received: structural decode succeeded, but the payload's
/// checksum may not have — that is the receiver's decision to make (the
/// section server nacks; a client treats it as a failed attempt).
#[derive(Debug, Clone, PartialEq)]
pub struct RecvFrame {
    pub frame: Frame,
    pub checksum_ok: bool,
}

pub fn payload_checksum(payload: &[u8]) -> u64 {
    fletcher64(payload)
}

/// Write `f` with an explicit trailer checksum. Exists for the chaos
/// harness: a truncate-in-flight fault sends a torn payload under the
/// clean bytes' checksum, exactly what a tear between checksumming and
/// the wire produces.
pub fn write_frame_unchecked<W: Write>(w: &mut W, f: &Frame, checksum: u64) -> Result<()> {
    if f.key.len() > MAX_KEY || f.section.len() > MAX_SECTION || f.payload.len() > MAX_PAYLOAD {
        bail!(
            "frame over caps: key {} section {} payload {}",
            f.key.len(),
            f.section.len(),
            f.payload.len()
        );
    }
    let mut hdr = Vec::with_capacity(17 + f.key.len() + f.section.len());
    hdr.extend_from_slice(&MAGIC);
    hdr.push(f.kind.as_u8());
    hdr.extend_from_slice(&(f.key.len() as u32).to_le_bytes());
    hdr.extend_from_slice(&(f.section.len() as u32).to_le_bytes());
    hdr.extend_from_slice(&(f.payload.len() as u32).to_le_bytes());
    hdr.extend_from_slice(f.key.as_bytes());
    hdr.extend_from_slice(f.section.as_bytes());
    w.write_all(&hdr).context("writing frame header")?;
    w.write_all(&f.payload).context("writing frame payload")?;
    w.write_all(&checksum.to_le_bytes())
        .context("writing frame checksum")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

pub fn write_frame<W: Write>(w: &mut W, f: &Frame) -> Result<()> {
    write_frame_unchecked(w, f, payload_checksum(&f.payload))
}

/// Read one frame. Structural failures (bad magic/kind, over-cap length,
/// a stream that ends mid-frame) are hard errors — the stream is
/// unusable past them. A payload checksum mismatch is NOT an error here:
/// the lengths already framed the stream, so the connection survives and
/// `checksum_ok` is false.
pub fn read_frame<R: Read>(r: &mut R) -> Result<RecvFrame> {
    let mut fixed = [0u8; 17];
    r.read_exact(&mut fixed).context("reading frame header")?;
    if fixed[0..4] != MAGIC {
        bail!("bad frame magic {:02x?}", &fixed[0..4]);
    }
    let kind = FrameKind::from_u8(fixed[4])
        .with_context(|| format!("bad frame kind {}", fixed[4]))?;
    let key_len = u32::from_le_bytes(fixed[5..9].try_into().unwrap()) as usize;
    let section_len = u32::from_le_bytes(fixed[9..13].try_into().unwrap()) as usize;
    let payload_len = u32::from_le_bytes(fixed[13..17].try_into().unwrap()) as usize;
    if key_len > MAX_KEY || section_len > MAX_SECTION || payload_len > MAX_PAYLOAD {
        bail!("frame over caps: key {key_len} section {section_len} payload {payload_len}");
    }
    let mut key = vec![0u8; key_len];
    r.read_exact(&mut key).context("reading frame key")?;
    let mut section = vec![0u8; section_len];
    r.read_exact(&mut section).context("reading frame section")?;
    let mut payload = vec![0u8; payload_len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    let mut sum = [0u8; 8];
    r.read_exact(&mut sum).context("reading frame checksum")?;
    let stored = u64::from_le_bytes(sum);
    let checksum_ok = fletcher64(&payload) == stored;
    Ok(RecvFrame {
        frame: Frame {
            kind,
            key: String::from_utf8(key).context("frame key not utf-8")?,
            section: String::from_utf8(section).context("frame section not utf-8")?,
            payload,
        },
        checksum_ok,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip() {
        let payload: Vec<u8> = (0..64u8).collect();
        for f in [
            Frame::put("/run/p0.dpc2", "delta:L0E1", payload),
            Frame::ack("/run/p0.dpc2"),
            Frame::nack("section delta:L0E1: frame checksum mismatch".into()),
        ] {
            let mut buf = Vec::new();
            write_frame(&mut buf, &f).unwrap();
            let rf = read_frame(&mut buf.as_slice()).unwrap();
            assert!(rf.checksum_ok);
            assert_eq!(rf.frame, f);
        }
    }

    #[test]
    fn torn_payload_keeps_stream_framed_but_fails_checksum() {
        let f = Frame::put("k", "s", vec![7u8; 32]);
        let clean_sum = payload_checksum(&f.payload);
        let mut torn = f.clone();
        for b in &mut torn.payload[24..] {
            *b ^= 0xFF;
        }
        let mut buf = Vec::new();
        write_frame_unchecked(&mut buf, &torn, clean_sum).unwrap();
        // a second clean frame behind the torn one on the same stream
        write_frame(&mut buf, &Frame::ack("k")).unwrap();
        let mut r = buf.as_slice();
        let first = read_frame(&mut r).unwrap();
        assert!(!first.checksum_ok, "tear must be detected");
        assert_eq!(first.frame.payload.len(), 32, "lengths still frame it");
        let second = read_frame(&mut r).unwrap();
        assert!(second.checksum_ok, "stream survives past the torn frame");
        assert_eq!(second.frame.kind, FrameKind::Ack);
    }

    #[test]
    fn structural_garbage_is_a_hard_error() {
        // bad magic
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::ack("x")).unwrap();
        buf[0] = b'Z';
        assert!(read_frame(&mut buf.as_slice()).is_err());
        // bad kind
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::ack("x")).unwrap();
        buf[4] = 9;
        assert!(read_frame(&mut buf.as_slice()).is_err());
        // over-cap payload length
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::ack("x")).unwrap();
        buf[13..17].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_frame(&mut buf.as_slice()).is_err());
        // stream ends mid-frame
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::put("k", "s", vec![1, 2, 3, 4])).unwrap();
        buf.truncate(buf.len() - 6);
        assert!(read_frame(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn oversized_frames_refuse_to_write() {
        let f = Frame::put(&"k".repeat(MAX_KEY + 1), "s", Vec::new());
        assert!(write_frame(&mut Vec::new(), &f).is_err());
    }
}
