//! Rendezvous registry: who serves which module.
//!
//! The outer-optimization plane already shards modules round-robin across
//! executors ([`crate::coordinator::outer::shard_modules`]); the registry
//! pins that ownership map to concrete endpoints so a worker can push
//! each `delta:L{l}E{e}` section *directly* to the executor that will
//! fold it — no broadcast, no broker. Built once per run from the same
//! shard list the executors are spawned from, so ownership and routing
//! cannot drift.

use std::collections::HashMap;
use std::net::SocketAddr;

use anyhow::{Context, Result};

use crate::topology::ModuleId;

#[derive(Debug, Clone)]
pub struct Rendezvous {
    owners: HashMap<ModuleId, usize>,
    endpoints: Vec<SocketAddr>,
}

impl Rendezvous {
    /// `shards[e]` is the module set executor `e` owns; `endpoints[e]`
    /// is where it listens.
    pub fn new(shards: &[Vec<ModuleId>], endpoints: Vec<SocketAddr>) -> Rendezvous {
        assert_eq!(
            shards.len(),
            endpoints.len(),
            "one endpoint per executor shard"
        );
        let mut owners = HashMap::new();
        for (e, shard) in shards.iter().enumerate() {
            for &m in shard {
                let prev = owners.insert(m, e);
                assert!(prev.is_none(), "module {m} owned by two executors");
            }
        }
        Rendezvous { owners, endpoints }
    }

    pub fn executors(&self) -> usize {
        self.endpoints.len()
    }

    /// Executor shard owning `m`'s outer state.
    pub fn owner_of(&self, m: ModuleId) -> Result<usize> {
        self.owners
            .get(&m)
            .copied()
            .with_context(|| format!("module {m} has no owning executor in the rendezvous"))
    }

    pub fn endpoint(&self, executor: usize) -> SocketAddr {
        self.endpoints[executor]
    }

    pub fn endpoint_of(&self, m: ModuleId) -> Result<SocketAddr> {
        Ok(self.endpoint(self.owner_of(m)?))
    }

    /// Group `modules` by owning executor, ascending — one push stream
    /// per executor per publish, deterministic order.
    pub fn group_by_owner(&self, modules: &[ModuleId]) -> Result<Vec<(usize, Vec<ModuleId>)>> {
        let mut by_owner: HashMap<usize, Vec<ModuleId>> = HashMap::new();
        for &m in modules {
            by_owner.entry(self.owner_of(m)?).or_default().push(m);
        }
        let mut grouped: Vec<(usize, Vec<ModuleId>)> = by_owner.into_iter().collect();
        grouped.sort_by_key(|(e, _)| *e);
        for (_, mods) in &mut grouped {
            mods.sort();
        }
        Ok(grouped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    fn mid(level: usize, expert: usize) -> ModuleId {
        ModuleId { level, expert }
    }

    #[test]
    fn ownership_covers_every_module_exactly_once() {
        let shards = vec![
            vec![mid(0, 0), mid(1, 0)],
            vec![mid(0, 1), mid(1, 1)],
        ];
        let r = Rendezvous::new(&shards, vec![addr(9001), addr(9002)]);
        assert_eq!(r.executors(), 2);
        assert_eq!(r.owner_of(mid(0, 0)).unwrap(), 0);
        assert_eq!(r.owner_of(mid(1, 1)).unwrap(), 1);
        assert_eq!(r.endpoint_of(mid(0, 1)).unwrap(), addr(9002));
        assert!(r.owner_of(mid(5, 5)).is_err(), "unknown module is loud");
    }

    #[test]
    fn grouping_is_sorted_and_complete() {
        let shards = vec![
            vec![mid(0, 0), mid(1, 0)],
            vec![mid(0, 1), mid(1, 1)],
        ];
        let r = Rendezvous::new(&shards, vec![addr(9001), addr(9002)]);
        let grouped = r
            .group_by_owner(&[mid(1, 1), mid(0, 0), mid(0, 1), mid(1, 0)])
            .unwrap();
        assert_eq!(
            grouped,
            vec![
                (0, vec![mid(0, 0), mid(1, 0)]),
                (1, vec![mid(0, 1), mid(1, 1)]),
            ]
        );
    }

    #[test]
    #[should_panic(expected = "owned by two executors")]
    fn double_ownership_is_rejected() {
        Rendezvous::new(
            &[vec![mid(0, 0)], vec![mid(0, 0)]],
            vec![addr(9001), addr(9002)],
        );
    }
}
