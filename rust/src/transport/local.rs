//! The shared-filesystem exchange plane: publication is the checkpoint's
//! own atomic temp-file + rename (already done by the time `publish` is
//! called), and reads map the DPC2 file exactly as executors always have.
//! This implementation exists so the trait's `Local` arm is provably
//! byte-identical to the pre-transport coordinator: it adds no copies,
//! no re-framing, and no extra checksum passes.

use std::path::Path;

use anyhow::Result;

use crate::params::checkpoint::SectionReader;
use crate::transport::{PublishCtx, SectionSource, SectionTransport};
use crate::topology::ModuleId;

pub struct LocalTransport;

impl SectionTransport for LocalTransport {
    fn publish(&self, _ctx: &PublishCtx, _file: &Path, _modules: &[ModuleId]) -> Result<()> {
        // The save's rename already made the sections visible to every
        // executor sharing the filesystem.
        Ok(())
    }

    fn open(&self, file: &Path) -> Result<Box<dyn SectionSource>> {
        Ok(Box::new(LocalSource {
            reader: SectionReader::open_mapped(file)?,
        }))
    }

    fn describe(&self) -> &'static str {
        "local"
    }
}

struct LocalSource {
    reader: SectionReader,
}

impl SectionSource for LocalSource {
    fn read_into(&mut self, name: &str, out: &mut Vec<f32>) -> Result<()> {
        self.reader.read_into(name, out)
    }

    fn bytes_read(&self) -> u64 {
        // Pass-through: a legacy DPC1 fallback counts the whole file at
        // open, a mapped DPC2 counts per section — the executor's
        // watermark accounting must see exactly what SectionReader saw.
        self.reader.bytes_read()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::checkpoint::Checkpoint;
    use crate::transport::open_source;

    #[test]
    fn local_plane_is_a_transparent_section_reader() {
        let dir = std::env::temp_dir().join(format!("dipaco-tlocal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("ck.dpc2");
        let mut ck = Checkpoint::new();
        ck.sections
            .push(("delta:L0E0".into(), vec![1.0, -2.5, 3.25]));
        ck.save(&file).unwrap();

        // publish is a no-op; open serves the same bytes with the same
        // accounting as a direct SectionReader
        let t = LocalTransport;
        t.publish(
            &PublishCtx {
                phase: 0,
                path: 0,
                kind: "delta".into(),
            },
            &file,
            &[crate::topology::ModuleId { level: 0, expert: 0 }],
        )
        .unwrap();
        let mut src = open_source(None, &file).unwrap();
        let mut out = Vec::new();
        src.read_into("delta:L0E0", &mut out).unwrap();
        assert_eq!(out, vec![1.0, -2.5, 3.25]);
        assert_eq!(src.bytes_read(), 12);
        assert!(src.read_into("delta:L9E9", &mut out).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
