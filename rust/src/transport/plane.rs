//! The section exchange plane (ROADMAP item 2): how published `delta:`
//! sections travel from training workers to outer-optimization executors.
//!
//! Today's coordinator rendezvouses through a shared filesystem — the
//! checkpoint's atomic temp-file + rename *is* the publication, and an
//! executor maps the DPC2 file. That is a dead end for multi-host
//! execution, so the exchange is now behind [`SectionTransport`]:
//!
//! * [`crate::transport::local::LocalTransport`] keeps the filesystem
//!   plane, byte-identical to the pre-trait behavior (`publish` is a
//!   no-op because the rename already happened; `open` maps the file).
//! * [`crate::transport::tcp::TcpExchange`] pushes each section over a
//!   framed TCP stream ([`crate::transport::frame`]) to the executor
//!   that owns its module, per the rendezvous registry
//!   ([`crate::transport::rendezvous`]).
//!
//! The reader side is deliberately the *same shape* as
//! [`crate::params::checkpoint::SectionReader`] (`read_into` into a
//! reused buffer, a `bytes_read` watermark), so the executor's I/O
//! accounting and its pinned error contexts are independent of which
//! plane served the bytes.

use std::path::Path;

use anyhow::Result;

use crate::topology::ModuleId;

/// Where a publish came from, for chaos targeting and diagnostics.
#[derive(Debug, Clone)]
pub struct PublishCtx {
    pub phase: usize,
    pub path: usize,
    /// Checkpoint kind being published (e.g. `"delta"`).
    pub kind: String,
}

/// A positioned reader over one published checkpoint's sections —
/// the transport-agnostic face of `SectionReader`.
pub trait SectionSource {
    /// Read one section into `out` (clear + fill, capacity reused),
    /// verifying integrity the same way the DPC2 reader does.
    fn read_into(&mut self, name: &str, out: &mut Vec<f32>) -> Result<()>;

    /// Payload bytes served so far (the executor's I/O watermark).
    fn bytes_read(&self) -> u64;
}

/// One section exchange plane. Implementations are shared across worker
/// and executor threads, hence `Send + Sync`.
pub trait SectionTransport: Send + Sync {
    /// Ship the `delta:` sections of `modules` from the just-saved
    /// checkpoint at `file` to their owning executors. Must be called
    /// after the checkpoint hits disk and before its DB row is inserted,
    /// so a row never references sections the plane cannot serve.
    fn publish(&self, ctx: &PublishCtx, file: &Path, modules: &[ModuleId]) -> Result<()>;

    /// Open the published checkpoint `file` for executor-side reads.
    fn open(&self, file: &Path) -> Result<Box<dyn SectionSource>>;

    /// Stable plane name for logs and benchmarks.
    fn describe(&self) -> &'static str;
}

/// Executor-side entry point: open `file` through `transport`, falling
/// back to the local filesystem plane when the run has none configured.
pub fn open_source(
    transport: Option<&dyn SectionTransport>,
    file: &Path,
) -> Result<Box<dyn SectionSource>> {
    match transport {
        Some(t) => t.open(file),
        None => crate::transport::local::LocalTransport.open(file),
    }
}
