//! Evaluation: validation perplexity with the paper's masking convention
//! (§2.4: "calculating perplexity using all but the first 32 tokens of
//! each sequence, which was used to determine the routing decision"),
//! per-path routed evaluation, and chunked frequent re-routing (§2.4.3).
//!
//! Everything is built on the `token_logprobs` entrypoint: `lp[b, j]` is
//! the logprob of token j+1 given tokens <= j, so a target index `t`
//! (token position) maps to lp column `t - 1`.

use anyhow::Result;
use std::collections::HashMap;

use crate::data::corpus::Corpus;
use crate::runtime::engine::Engine;

/// Prefix-masked NLL of ONE `[seq-1]` logprob row: targets are token
/// positions `prefix..seq`, and lp column `t - 1` scores token `t`. The
/// single source of the masking convention — `nll_masked` and the
/// serving executor both build on it.
pub fn nll_row(row: &[f32], seq: usize, prefix: usize) -> (f64, usize) {
    debug_assert_eq!(row.len(), seq - 1);
    let nll: f64 = (prefix..seq).map(|t| -(row[t - 1] as f64)).sum();
    (nll, seq - prefix)
}

/// Sum of negative logprobs + token count over targets with index >=
/// `prefix`, for the first `rows` rows of a `[batch, seq-1]` lp buffer.
pub fn nll_masked(
    lp: &[f32],
    batch: usize,
    seq: usize,
    prefix: usize,
    rows: usize,
) -> (f64, usize) {
    assert_eq!(lp.len(), batch * (seq - 1));
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for b in 0..rows.min(batch) {
        let (n, c) = nll_row(&lp[b * (seq - 1)..(b + 1) * (seq - 1)], seq, prefix);
        nll += n;
        count += c;
    }
    (nll, count)
}

/// Evaluate `theta` on `docs` at sequence length `seq` (train or eval
/// variant); returns (total nll, token count). The last partial batch is
/// padded with doc 0 and its padding rows excluded.
pub fn eval_docs(
    engine: &Engine,
    theta: &[f32],
    docs: &[usize],
    corpus: &Corpus,
    seq: usize,
) -> Result<(f64, usize)> {
    let mc = engine.model();
    let mut nll = 0.0;
    let mut count = 0usize;
    for chunk in docs.chunks(mc.batch) {
        let mut toks = Vec::with_capacity(mc.batch * seq);
        for &d in chunk {
            toks.extend_from_slice(&corpus.sequence(d, seq));
        }
        for _ in chunk.len()..mc.batch {
            toks.extend_from_slice(&corpus.sequence(docs[0], seq));
        }
        let lp = engine.token_logprobs(theta, &toks, seq)?;
        let (n, c) = nll_masked(&lp, mc.batch, seq, mc.prefix, chunk.len());
        nll += n;
        count += c;
    }
    Ok((nll, count))
}

/// Validation perplexity of a single model over `docs`.
pub fn ppl_docs(
    engine: &Engine,
    theta: &[f32],
    docs: &[usize],
    corpus: &Corpus,
    seq: usize,
) -> Result<f64> {
    let (nll, count) = eval_docs(engine, theta, docs, corpus, seq)?;
    Ok((nll / count.max(1) as f64).exp())
}

/// Routed evaluation (paper §2.6: "at test time, the paths are
/// instantiated, and served independently, with text routed to each path
/// via a router"): each doc is scored by exactly one path.
pub fn eval_routed(
    engine: &Engine,
    thetas: &HashMap<usize, Vec<f32>>,
    assign: impl Fn(usize) -> usize,
    docs: &[usize],
    corpus: &Corpus,
    seq: usize,
) -> Result<f64> {
    let mut by_path: HashMap<usize, Vec<usize>> = HashMap::new();
    for &d in docs {
        by_path.entry(assign(d)).or_default().push(d);
    }
    let mut nll = 0.0;
    let mut count = 0usize;
    for (path, group) in by_path {
        let theta = thetas
            .get(&path)
            .unwrap_or_else(|| panic!("no theta for path {path}"));
        let (n, c) = eval_docs(engine, theta, &group, corpus, seq)?;
        nll += n;
        count += c;
    }
    Ok((nll / count.max(1) as f64).exp())
}

/// Per-path token logprobs for a set of docs at eval length. Returns
/// `scores[path][doc_idx]` = full `[seq-1]` lp row per doc. Used by the
/// chunked-routing evaluator and the discriminative-router label maker.
pub fn all_path_logprobs(
    engine: &Engine,
    thetas: &HashMap<usize, Vec<f32>>,
    docs: &[usize],
    corpus: &Corpus,
    seq: usize,
) -> Result<HashMap<usize, Vec<Vec<f32>>>> {
    let mc = engine.model();
    let mut out: HashMap<usize, Vec<Vec<f32>>> = HashMap::new();
    for (&path, theta) in thetas {
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(docs.len());
        for chunk in docs.chunks(mc.batch) {
            let mut toks = Vec::with_capacity(mc.batch * seq);
            for &d in chunk {
                toks.extend_from_slice(&corpus.sequence(d, seq));
            }
            for _ in chunk.len()..mc.batch {
                toks.extend_from_slice(&corpus.sequence(docs[0], seq));
            }
            let lp = engine.token_logprobs(theta, &toks, seq)?;
            for b in 0..chunk.len() {
                rows.push(lp[b * (seq - 1)..(b + 1) * (seq - 1)].to_vec());
            }
        }
        out.insert(path, rows);
    }
    Ok(out)
}

/// Chunked frequent re-routing (paper §2.4.3, Table 3): split positions
/// `prefix..seq` into windows of `w` tokens; tokens in window i are scored
/// by path `path_of(doc_idx, i)`. With `w >= seq - prefix` this reduces to
/// routing once per sequence.
///
/// `scores` comes from [`all_path_logprobs`]; re-scoring every W from the
/// same matrices is free, which is how Table 3's sweep is generated.
pub fn ppl_chunked(
    scores: &HashMap<usize, Vec<Vec<f32>>>,
    n_docs: usize,
    seq: usize,
    prefix: usize,
    w: usize,
    path_of: impl Fn(usize, usize) -> usize,
) -> f64 {
    assert!(w >= 1);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for doc in 0..n_docs {
        let mut chunk = 0usize;
        let mut t = prefix;
        while t < seq {
            let path = path_of(doc, chunk);
            let lp = &scores[&path][doc];
            let end = (t + w).min(seq);
            for ti in t..end {
                nll -= lp[ti - 1] as f64;
                count += 1;
            }
            t = end;
            chunk += 1;
        }
    }
    (nll / count.max(1) as f64).exp()
}

/// Oracle chunked routing: pick, per chunk, the path with the best score
/// on that chunk (upper bound for Table 3's learned router).
pub fn ppl_chunked_oracle(
    scores: &HashMap<usize, Vec<Vec<f32>>>,
    n_docs: usize,
    seq: usize,
    prefix: usize,
    w: usize,
) -> f64 {
    let paths: Vec<usize> = scores.keys().copied().collect();
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for doc in 0..n_docs {
        let mut t = prefix;
        while t < seq {
            let end = (t + w).min(seq);
            let best = paths
                .iter()
                .map(|&p| -> f64 {
                    (t..end).map(|ti| scores[&p][doc][ti - 1] as f64).sum()
                })
                .fold(f64::NEG_INFINITY, f64::max);
            nll -= best;
            count += end - t;
            t = end;
        }
    }
    (nll / count.max(1) as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_masking_counts_right_targets() {
        // batch=2, seq=5, prefix=3 -> targets 3,4 per row -> lp cols 2,3
        let lp = vec![
            -1.0, -2.0, -3.0, -4.0, // row 0
            -1.5, -2.5, -3.5, -4.5, // row 1
        ];
        let (nll, count) = nll_masked(&lp, 2, 5, 3, 2);
        assert_eq!(count, 4);
        assert!((nll - (3.0 + 4.0 + 3.5 + 4.5)).abs() < 1e-9);
        // only first row
        let (nll1, c1) = nll_masked(&lp, 2, 5, 3, 1);
        assert_eq!(c1, 2);
        assert!((nll1 - 7.0).abs() < 1e-9);
    }

    #[test]
    fn chunked_reduces_to_single_route_for_large_w() {
        let mut scores = HashMap::new();
        // path 0 uniformly -1, path 1 uniformly -2 on a seq of 9
        scores.insert(0, vec![vec![-1.0f32; 8]]);
        scores.insert(1, vec![vec![-2.0f32; 8]]);
        let once = ppl_chunked(&scores, 1, 9, 3, 100, |_, _| 0);
        assert!((once - 1f64.exp()).abs() < 1e-9);
        // chunked with alternating path selection
        let alt = ppl_chunked(&scores, 1, 9, 3, 2, |_, c| c % 2);
        // windows [3,4],[5,6],[7,8]: paths 0,1,0 -> mean = (2*1+2*2+2*1)/6
        assert!((alt - (8.0f64 / 6.0).exp()).abs() < 1e-9);
    }

    #[test]
    fn oracle_at_least_as_good_as_any_fixed_path() {
        let mut scores = HashMap::new();
        scores.insert(0, vec![vec![-1.0, -9.0, -1.0, -9.0, -1.0, -9.0]]);
        scores.insert(1, vec![vec![-9.0, -1.0, -9.0, -1.0, -9.0, -1.0]]);
        let seq = 7;
        let oracle = ppl_chunked_oracle(&scores, 1, seq, 1, 1);
        let fixed0 = ppl_chunked(&scores, 1, seq, 1, 100, |_, _| 0);
        let fixed1 = ppl_chunked(&scores, 1, seq, 1, 100, |_, _| 1);
        assert!(oracle <= fixed0 && oracle <= fixed1);
        assert!((oracle - 1f64.exp()).abs() < 1e-9); // picks -1 every time
    }

    #[test]
    fn smaller_w_never_hurts_oracle() {
        // property: oracle PPL is monotone non-increasing as W shrinks
        let mut rng = crate::util::rng::Rng::new(11);
        let mut scores = HashMap::new();
        for p in 0..3 {
            scores.insert(
                p,
                vec![(0..31).map(|_| -(rng.f32() * 3.0)).collect::<Vec<f32>>(); 4]
                    .into_iter()
                    .map(|mut v| {
                        v.iter_mut().for_each(|x| *x -= 0.01);
                        v
                    })
                    .collect(),
            );
        }
        let seq = 32;
        let mut prev = f64::INFINITY;
        for w in [24, 12, 6, 3, 1] {
            let ppl = ppl_chunked_oracle(&scores, 4, seq, 8, w);
            assert!(ppl <= prev + 1e-9, "w={w} ppl={ppl} prev={prev}");
            prev = ppl;
        }
    }
}
