//! Binary checkpoints — the GFS stand-in (paper §3: workers save
//! checkpoints to the distributed file system; outer-optimization
//! executors and evaluators load them as they appear in the DB).
//!
//! Format `DPC1`: per section `[name_len u32][name utf8][len u32][f32 LE
//! data]`, with a Fletcher-64 checksum trailer so torn/corrupt writes are
//! detected (workers get preempted mid-write in the failure-injection
//! tests). Writes go through a temp file + atomic rename, matching the
//! crash-consistency contract real checkpoint stores provide.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"DPC1";

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub sections: Vec<(String, Vec<f32>)>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with(mut self, name: &str, data: Vec<f32>) -> Self {
        self.sections.push((name.to_string(), data));
        self
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| d.as_slice())
    }

    pub fn take(&mut self, name: &str) -> Option<Vec<f32>> {
        let i = self.sections.iter().position(|(n, _)| n == name)?;
        Some(self.sections.remove(i).1)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        {
            let mut buf: Vec<u8> = Vec::new();
            buf.extend_from_slice(MAGIC);
            buf.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
            for (name, data) in &self.sections {
                buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
                buf.extend_from_slice(name.as_bytes());
                buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
                for &v in data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            let sum = fletcher64(&buf);
            buf.extend_from_slice(&sum.to_le_bytes());
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&buf)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut buf = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut buf)?;
        if buf.len() < 16 || &buf[..4] != MAGIC {
            bail!("{}: not a DPC1 checkpoint", path.display());
        }
        let body = &buf[..buf.len() - 8];
        let stored = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
        if fletcher64(body) != stored {
            bail!("{}: checksum mismatch (torn write?)", path.display());
        }
        let mut pos = 4;
        let rd_u32 = |buf: &[u8], pos: &mut usize| -> Result<u32> {
            if *pos + 4 > buf.len() {
                bail!("truncated checkpoint");
            }
            let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            Ok(v)
        };
        let n_sections = rd_u32(body, &mut pos)?;
        let mut sections = Vec::with_capacity(n_sections as usize);
        for _ in 0..n_sections {
            let name_len = rd_u32(body, &mut pos)? as usize;
            if pos + name_len > body.len() {
                bail!("truncated checkpoint");
            }
            let name = std::str::from_utf8(&body[pos..pos + name_len])
                .context("bad section name")?
                .to_string();
            pos += name_len;
            let len = rd_u32(body, &mut pos)? as usize;
            if pos + 4 * len > body.len() {
                bail!("truncated checkpoint");
            }
            let mut data = Vec::with_capacity(len);
            for i in 0..len {
                data.push(f32::from_le_bytes(
                    body[pos + 4 * i..pos + 4 * i + 4].try_into().unwrap(),
                ));
            }
            pos += 4 * len;
            sections.push((name, data));
        }
        Ok(Checkpoint { sections })
    }
}

fn fletcher64(data: &[u8]) -> u64 {
    let mut a: u64 = 0;
    let mut b: u64 = 0;
    for chunk in data.chunks(4) {
        let mut w = [0u8; 4];
        w[..chunk.len()].copy_from_slice(chunk);
        a = (a + u32::from_le_bytes(w) as u64) % 0xFFFF_FFFF;
        b = (b + a) % 0xFFFF_FFFF;
    }
    (b << 32) | a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("dipaco-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip() {
        let p = tmpdir().join("a.dpc");
        let ck = Checkpoint::new()
            .with("theta", vec![1.0, -2.5, 3.25])
            .with("m", vec![0.0; 10])
            .with("loss", vec![4.2]);
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        assert_eq!(back.get("loss"), Some(&[4.2f32][..]));
    }

    #[test]
    fn detects_corruption() {
        let p = tmpdir().join("b.dpc");
        Checkpoint::new()
            .with("theta", vec![1.0; 100])
            .save(&p)
            .unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[20] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn detects_truncation() {
        let p = tmpdir().join("c.dpc");
        Checkpoint::new()
            .with("theta", vec![1.0; 100])
            .save(&p)
            .unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let p = tmpdir().join("d.dpc");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn empty_sections_ok() {
        let p = tmpdir().join("e.dpc");
        Checkpoint::new().save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap().sections.len(), 0);
    }
}
