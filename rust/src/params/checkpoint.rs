//! Binary checkpoints — the GFS stand-in (paper §3: workers save
//! checkpoints to the distributed file system; outer-optimization
//! executors and evaluators load them as they appear in the DB).
//!
//! Format `DPC2` — sectioned with a random-access directory, so an
//! outer-optimization executor can read *only the module sections it
//! owns* (paper §3.3: "the overall model is never materialized in a
//! single location") instead of parsing the whole file:
//!
//! ```text
//! [0..4)    magic "DPC2"
//! [4..8)    n_sections   u32 LE
//! [8..12)   header_len   u32 LE   (bytes from offset 0 through dir_sum)
//! per section (directory entry):
//!   name_len u32 | name utf8 | offset u64 | len u32 (f32 count) | sum u64
//! dir_sum   u64  — fletcher64 of bytes [0, header_len - 8)
//! payloads: f32 LE data at each entry's absolute `offset`
//! ```
//!
//! Per-section fletcher64 checksums plus the directory checksum detect
//! torn/corrupt writes (workers get preempted mid-write in the
//! failure-injection tests) without requiring a whole-file read. Writes
//! go through a temp file + atomic rename, matching the
//! crash-consistency contract real checkpoint stores provide.
//!
//! The previous flat format `DPC1` (sequential sections, whole-file
//! checksum trailer) still loads; [`SectionReader`] falls back to a full
//! parse for it. [`Checkpoint::save_dpc1`] is kept for the
//! backward-compat and migration tests.

use crate::config::DeltaCodec;
use anyhow::{bail, Context, Result};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

const MAGIC_V1: &[u8; 4] = b"DPC1";
const MAGIC_V2: &[u8; 4] = b"DPC2";

/// Per-writer-unique temp name: a lease-expired task can be re-executed
/// while the original writer is still alive, and two writers sharing one
/// `.tmp` inode would corrupt the published file after the first rename.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

fn tmp_path(path: &Path) -> PathBuf {
    path.with_extension(format!(
        "tmp.{}.{}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Fixed header bytes before the directory entries: magic + n_sections +
/// header_len; plus the trailing dir_sum.
const DIR_FIXED: usize = 4 + 4 + 4 + 8;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub sections: Vec<(String, Vec<f32>)>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with(mut self, name: &str, data: Vec<f32>) -> Self {
        self.sections.push((name.to_string(), data));
        self
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| d.as_slice())
    }

    pub fn take(&mut self, name: &str) -> Option<Vec<f32>> {
        let i = self.sections.iter().position(|(n, _)| n == name)?;
        Some(self.sections.remove(i).1)
    }

    /// Write as DPC2 (atomic temp-file + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let refs: Vec<(&str, &[f32])> = self
            .sections
            .iter()
            .map(|(n, d)| (n.as_str(), d.as_slice()))
            .collect();
        save_sections(path, &refs)
    }

    /// Write in the legacy DPC1 layout (sequential sections, whole-file
    /// checksum trailer). Kept so the format-migration tests can produce
    /// previous-revision files; new code must use [`Checkpoint::save`].
    pub fn save_dpc1(&self, path: &Path) -> Result<()> {
        let tmp = tmp_path(path);
        {
            let mut buf: Vec<u8> = Vec::new();
            buf.extend_from_slice(MAGIC_V1);
            buf.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
            for (name, data) in &self.sections {
                buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
                buf.extend_from_slice(name.as_bytes());
                buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
                write_f32s_le(&mut buf, data);
            }
            let sum = fletcher64(&buf);
            buf.extend_from_slice(&sum.to_le_bytes());
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&buf)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    /// Load a whole checkpoint; dispatches on the magic (DPC2 or legacy
    /// DPC1).
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let buf = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
        if buf.len() >= 4 && &buf[..4] == MAGIC_V1 {
            return load_dpc1(&buf, path);
        }
        if buf.len() < DIR_FIXED || &buf[..4] != MAGIC_V2 {
            bail!("{}: not a DPC checkpoint", path.display());
        }
        let header_len = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
        if header_len < DIR_FIXED || header_len > buf.len() {
            bail!("{}: truncated checkpoint header", path.display());
        }
        let dir = parse_directory(&buf[..header_len])
            .with_context(|| format!("reading {}", path.display()))?;
        let mut sections = Vec::with_capacity(dir.len());
        for e in dir {
            let start = e.offset as usize;
            let end = start
                .checked_add(e.len.checked_mul(4).context("section length overflow")?)
                .context("section offset overflow")?;
            if end > buf.len() {
                bail!("{}: truncated section {}", path.display(), e.name);
            }
            let bytes = &buf[start..end];
            if fletcher64(bytes) != e.sum {
                bail!(
                    "{}: section {} checksum mismatch (torn write?)",
                    path.display(),
                    e.name
                );
            }
            sections.push((e.name, read_f32s_le(bytes)));
        }
        Ok(Checkpoint { sections })
    }
}

/// Write sections directly from borrowed slices (no copies into an owned
/// [`Checkpoint`]) — the per-phase hot path assembles into reused buffers
/// and saves them straight from here.
pub fn save_sections(path: &Path, sections: &[(&str, &[f32])]) -> Result<()> {
    let mut header_len = DIR_FIXED;
    for (name, _) in sections {
        header_len += 4 + name.len() + 8 + 4 + 8;
    }
    let total_payload: usize = sections.iter().map(|(_, d)| d.len() * 4).sum();
    let mut payload: Vec<u8> = Vec::with_capacity(total_payload);
    let mut entries = Vec::with_capacity(sections.len());
    for (name, data) in sections {
        let start = payload.len();
        write_f32s_le(&mut payload, data);
        let sum = fletcher64(&payload[start..]);
        entries.push((*name, (header_len + start) as u64, data.len() as u32, sum));
    }
    let mut head: Vec<u8> = Vec::with_capacity(header_len);
    head.extend_from_slice(MAGIC_V2);
    head.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    head.extend_from_slice(&(header_len as u32).to_le_bytes());
    for (name, off, len, sum) in &entries {
        head.extend_from_slice(&(name.len() as u32).to_le_bytes());
        head.extend_from_slice(name.as_bytes());
        head.extend_from_slice(&off.to_le_bytes());
        head.extend_from_slice(&len.to_le_bytes());
        head.extend_from_slice(&sum.to_le_bytes());
    }
    let dir_sum = fletcher64(&head);
    head.extend_from_slice(&dir_sum.to_le_bytes());
    debug_assert_eq!(head.len(), header_len);
    let tmp = tmp_path(path);
    {
        let mut f =
            std::fs::File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&head)?;
        f.write_all(&payload)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

/// Random access to one section without reading the rest of the file.
pub fn load_section(path: &Path, name: &str) -> Result<Vec<f32>> {
    SectionReader::open(path)?
        .read(name)
        .with_context(|| format!("loading section {name} from {}", path.display()))
}

/// Random access to one section, decoded straight into a caller-owned
/// (typically pooled) buffer — the zero-allocation form of
/// [`load_section`].
pub fn load_section_into(path: &Path, name: &str, out: &mut Vec<f32>) -> Result<()> {
    SectionReader::open(path)?
        .read_into(name, out)
        .with_context(|| format!("loading section {name} from {}", path.display()))
}

// ---------------------------------------------------------------------------
// Lossy delta codecs (streaming outer sync).
//
// Quantized `delta:` payloads ride inside ordinary DPC2 sections: the
// encoder packs a 12-byte header (codec tag, element count, scale) plus
// the quantized elements into little-endian 4-byte words and hands them
// to [`save_sections`] as if they were f32 data. The directory `len`
// stays a word count and the per-section fletcher64 covers the packed
// bytes, so corruption detection, mmap reads, and byte accounting all
// work unchanged. Decoding is explicit: the reader knows the run's
// [`DeltaCodec`] from config and the tag check catches any mismatch
// loudly.
//
// Error feedback: [`encode_delta_feedback`] returns, along with the wire
// words, the residual `total - dequantized` — elementwise f32, exact by
// Sterbenz's lemma since the dequantized value is within half a
// quantization step of the input — which the worker carries into the
// next phase's delta. Information lost per phase is therefore bounded by
// one quantization step, not accumulated.
// ---------------------------------------------------------------------------

/// Tag space for quantized delta sections; low byte is the codec id.
const QDELTA_MAGIC: u32 = 0x5144_5400; // "QDT\0"
const QDELTA_MASK: u32 = 0xFFFF_FF00;
/// Header words before the packed payload: tag, element count, scale.
const QDELTA_HEADER_WORDS: usize = 3;

fn codec_id(codec: DeltaCodec) -> u32 {
    match codec {
        DeltaCodec::F32 => 0, // never written: f32 sections are raw
        DeltaCodec::Bf16 => 1,
        DeltaCodec::Int8 => 2,
    }
}

/// Round-to-nearest-even truncation to bfloat16. NaN payload bits are
/// forced quiet so rounding can't turn a NaN into infinity.
fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7FFF + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Encode a delta under `codec` into DPC2 section words and return the
/// wire words together with the error-feedback residual
/// (`total - dequantized`, elementwise; all zeros for the exact f32
/// codec). The caller carries the residual into the next phase's delta.
pub fn encode_delta_feedback(codec: DeltaCodec, total: &[f32]) -> (Vec<f32>, Vec<f32>) {
    match codec {
        DeltaCodec::F32 => (total.to_vec(), vec![0.0; total.len()]),
        DeltaCodec::Bf16 => {
            let n = total.len();
            let mut words = Vec::with_capacity(QDELTA_HEADER_WORDS + n.div_ceil(2));
            words.push(f32::from_bits(QDELTA_MAGIC | codec_id(codec)));
            words.push(f32::from_bits(n as u32));
            words.push(0.0); // scale unused
            let mut residual = Vec::with_capacity(n);
            for pair in total.chunks(2) {
                let mut w: u32 = 0;
                for (i, &x) in pair.iter().enumerate() {
                    let h = f32_to_bf16(x);
                    residual.push(x - bf16_to_f32(h));
                    w |= (h as u32) << (16 * i);
                }
                words.push(f32::from_bits(w));
            }
            (words, residual)
        }
        DeltaCodec::Int8 => {
            let n = total.len();
            let absmax = total.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let scale = absmax / 127.0;
            let mut words = Vec::with_capacity(QDELTA_HEADER_WORDS + n.div_ceil(4));
            words.push(f32::from_bits(QDELTA_MAGIC | codec_id(codec)));
            words.push(f32::from_bits(n as u32));
            words.push(scale);
            let mut residual = Vec::with_capacity(n);
            for quad in total.chunks(4) {
                let mut w: u32 = 0;
                for (i, &x) in quad.iter().enumerate() {
                    let q = if scale == 0.0 {
                        0i8
                    } else {
                        (x / scale).round().clamp(-127.0, 127.0) as i8
                    };
                    residual.push(x - q as f32 * scale);
                    w |= ((q as u8) as u32) << (8 * i);
                }
                words.push(f32::from_bits(w));
            }
            (words, residual)
        }
    }
}

/// Encode without keeping the residual (benches, tests).
pub fn encode_delta(codec: DeltaCodec, total: &[f32]) -> Vec<f32> {
    encode_delta_feedback(codec, total).0
}

/// Decode a delta section read off the wire. `codec` comes from run
/// config; a section whose tag disagrees (raw f32 bytes, or a different
/// quantizer) fails loudly rather than deserializing garbage.
pub fn decode_delta_into(codec: DeltaCodec, words: &[f32], out: &mut Vec<f32>) -> Result<()> {
    if codec == DeltaCodec::F32 {
        out.clear();
        out.extend_from_slice(words);
        return Ok(());
    }
    if words.len() < QDELTA_HEADER_WORDS {
        bail!("quantized delta section too short ({} words)", words.len());
    }
    let tag = words[0].to_bits();
    if tag & QDELTA_MASK != QDELTA_MAGIC {
        bail!("delta codec mismatch: expected {codec}, section is not a quantized delta");
    }
    if tag != QDELTA_MAGIC | codec_id(codec) {
        bail!(
            "delta codec mismatch: expected {codec}, section carries codec id {}",
            tag & !QDELTA_MASK
        );
    }
    let n = words[1].to_bits() as usize;
    let payload = &words[QDELTA_HEADER_WORDS..];
    let want_words = match codec {
        DeltaCodec::Bf16 => n.div_ceil(2),
        DeltaCodec::Int8 => n.div_ceil(4),
        DeltaCodec::F32 => unreachable!(),
    };
    if payload.len() != want_words {
        bail!(
            "quantized delta length mismatch: {n} elements need {want_words} payload words, found {}",
            payload.len()
        );
    }
    out.clear();
    out.reserve(n);
    match codec {
        DeltaCodec::Bf16 => {
            for i in 0..n {
                let w = payload[i / 2].to_bits();
                out.push(bf16_to_f32(((w >> (16 * (i % 2))) & 0xFFFF) as u16));
            }
        }
        DeltaCodec::Int8 => {
            let scale = words[2];
            for i in 0..n {
                let w = payload[i / 4].to_bits();
                let q = ((w >> (8 * (i % 4))) & 0xFF) as u8 as i8;
                out.push(q as f32 * scale);
            }
        }
        DeltaCodec::F32 => unreachable!(),
    }
    Ok(())
}

/// Decode into a fresh vector (tests, one-shot callers).
pub fn decode_delta(codec: DeltaCodec, words: &[f32]) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    decode_delta_into(codec, words, &mut out)?;
    Ok(out)
}

#[derive(Debug, Clone)]
struct DirEntry {
    name: String,
    /// Absolute byte offset of the payload in the file.
    offset: u64,
    /// Section length in f32 elements.
    len: usize,
    /// fletcher64 of the payload bytes.
    sum: u64,
}

/// Parse a complete DPC2 header slice (magic through dir_sum), verifying
/// the directory checksum.
fn parse_directory(head: &[u8]) -> Result<Vec<DirEntry>> {
    if head.len() < DIR_FIXED || &head[..4] != MAGIC_V2 {
        bail!("truncated section directory");
    }
    let n = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
    let header_len = u32::from_le_bytes(head[8..12].try_into().unwrap()) as usize;
    if header_len != head.len() {
        bail!("section directory length mismatch");
    }
    let body_end = header_len - 8;
    let stored = u64::from_le_bytes(head[body_end..].try_into().unwrap());
    if fletcher64(&head[..body_end]) != stored {
        bail!("section directory checksum mismatch (torn write?)");
    }
    let mut pos = 12usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if pos + 4 > body_end {
            bail!("truncated section directory");
        }
        let name_len = u32::from_le_bytes(head[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        if pos + name_len + 8 + 4 + 8 > body_end {
            bail!("truncated section directory");
        }
        let name = std::str::from_utf8(&head[pos..pos + name_len])
            .context("bad section name")?
            .to_string();
        pos += name_len;
        let offset = u64::from_le_bytes(head[pos..pos + 8].try_into().unwrap());
        pos += 8;
        let len = u32::from_le_bytes(head[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        let sum = u64::from_le_bytes(head[pos..pos + 8].try_into().unwrap());
        pos += 8;
        out.push(DirEntry {
            name,
            offset,
            len,
            sum,
        });
    }
    if pos != body_end {
        bail!("section directory size mismatch");
    }
    Ok(out)
}

/// Open-once random access over a checkpoint's sections: parses only the
/// header directory, then serves `read(name)` / `read_into(name, buf)`
/// calls. Two DPC2 backends share the same checksum discipline:
///
/// * [`SectionReader::open`] — buffered: seek + one exact payload read,
///   decoded in a single pass (no intermediate byte vector).
/// * [`SectionReader::open_mapped`] — zero-copy: the file is mmap'd
///   read-only (falling back to one whole-file read where mmap is
///   unavailable or fails) and payloads are checksummed and decoded
///   straight from the mapped bytes.
///
/// Both track payload bytes served so callers (the executor path) can
/// account I/O. For legacy DPC1 files (no directory) both fall back to a
/// full-file parse and count the whole file as read.
pub struct SectionReader {
    backend: Backend,
    dir: Vec<DirEntry>,
    bytes_read: u64,
}

enum Backend {
    /// Buffered random access: seek + exact read per section.
    File(std::fs::File),
    /// Zero-copy: payloads decoded straight from the file image.
    Mapped(FileBytes),
    /// DPC1 fallback: whole-file parse held in memory.
    Legacy(Checkpoint),
}

/// The complete file image behind a mapped reader.
enum FileBytes {
    #[cfg(unix)]
    Os(mmap_impl::Map),
    /// Fallback when mmap is unavailable (non-unix) or fails (empty
    /// file, exotic filesystem): one buffered whole read.
    Owned(Vec<u8>),
}

impl FileBytes {
    fn as_slice(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            FileBytes::Os(m) => m.as_slice(),
            FileBytes::Owned(v) => v.as_slice(),
        }
    }

    fn map_or_read(f: &std::fs::File, len: usize, path: &Path) -> Result<FileBytes> {
        #[cfg(unix)]
        if let Some(m) = mmap_impl::Map::of(f, len) {
            return Ok(FileBytes::Os(m));
        }
        let mut buf = Vec::with_capacity(len);
        let mut src = f; // `&File: Read`; cursor is at 0 on a fresh open
        src.read_to_end(&mut buf)
            .with_context(|| format!("opening {}", path.display()))?;
        Ok(FileBytes::Owned(buf))
    }
}

fn find_entry(dir: &[DirEntry], name: &str) -> Result<DirEntry> {
    dir.iter()
        .find(|e| e.name == name)
        .cloned()
        .with_context(|| format!("section {name} missing"))
}

impl SectionReader {
    pub fn open(path: &Path) -> Result<SectionReader> {
        let mut f =
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut fixed = [0u8; 12];
        f.read_exact(&mut fixed)
            .with_context(|| format!("{}: truncated checkpoint", path.display()))?;
        if &fixed[..4] == MAGIC_V1 {
            // Legacy flat format: no directory to seek by.
            let ck = Checkpoint::load(path)?;
            let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
            return Ok(SectionReader {
                backend: Backend::Legacy(ck),
                dir: Vec::new(),
                bytes_read: bytes,
            });
        }
        if &fixed[..4] != MAGIC_V2 {
            bail!("{}: not a DPC checkpoint", path.display());
        }
        let header_len = u32::from_le_bytes(fixed[8..12].try_into().unwrap()) as usize;
        // upper bound guards the pre-checksum allocation against a torn
        // header_len field (16 MiB of directory ≈ hundreds of thousands
        // of sections — far beyond any real topology)
        if header_len < DIR_FIXED || header_len > (1 << 24) {
            bail!("{}: corrupt checkpoint header", path.display());
        }
        let mut head = vec![0u8; header_len];
        head[..12].copy_from_slice(&fixed);
        f.read_exact(&mut head[12..])
            .with_context(|| format!("{}: truncated checkpoint header", path.display()))?;
        let dir = parse_directory(&head).with_context(|| format!("reading {}", path.display()))?;
        Ok(SectionReader {
            backend: Backend::File(f),
            dir,
            bytes_read: 0,
        })
    }

    /// Zero-copy open: map the whole file read-only and serve section
    /// reads from the mapped bytes (checksums included). Semantics —
    /// error strings, byte accounting, DPC1 fallback — match
    /// [`SectionReader::open`] exactly; only the I/O path differs.
    ///
    /// Lifetime note (see DESIGN.md "Hot path & memory"): the mapping
    /// lives as long as the reader. Checkpoint GC unlinks published files
    /// while executors may still hold readers — on unix the mapping keeps
    /// the inode alive until drop, so a concurrent GC pass can never make
    /// reads fault.
    pub fn open_mapped(path: &Path) -> Result<SectionReader> {
        let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let len = f
            .metadata()
            .with_context(|| format!("opening {}", path.display()))?
            .len() as usize;
        let bytes = FileBytes::map_or_read(&f, len, path)?;
        let buf = bytes.as_slice();
        if buf.len() < 12 {
            bail!("{}: truncated checkpoint", path.display());
        }
        if &buf[..4] == MAGIC_V1 {
            let ck = load_dpc1(buf, path)?;
            let total = buf.len() as u64;
            return Ok(SectionReader {
                backend: Backend::Legacy(ck),
                dir: Vec::new(),
                bytes_read: total,
            });
        }
        if &buf[..4] != MAGIC_V2 {
            bail!("{}: not a DPC checkpoint", path.display());
        }
        let header_len = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
        if header_len < DIR_FIXED || header_len > (1 << 24) {
            bail!("{}: corrupt checkpoint header", path.display());
        }
        if header_len > buf.len() {
            bail!("{}: truncated checkpoint header", path.display());
        }
        let dir =
            parse_directory(&buf[..header_len]).with_context(|| format!("reading {}", path.display()))?;
        Ok(SectionReader {
            backend: Backend::Mapped(bytes),
            dir,
            bytes_read: 0,
        })
    }

    /// Section names, in file order.
    pub fn names(&self) -> Vec<&str> {
        match &self.backend {
            Backend::Legacy(ck) => ck.sections.iter().map(|(n, _)| n.as_str()).collect(),
            _ => self.dir.iter().map(|e| e.name.as_str()).collect(),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        match &self.backend {
            Backend::Legacy(ck) => ck.get(name).is_some(),
            _ => self.dir.iter().any(|e| e.name == name),
        }
    }

    /// Length (f32 count) of a section, from the directory alone.
    pub fn len_of(&self, name: &str) -> Option<usize> {
        match &self.backend {
            Backend::Legacy(ck) => ck.get(name).map(|d| d.len()),
            _ => self.dir.iter().find(|e| e.name == name).map(|e| e.len),
        }
    }

    /// Payload bytes served so far (whole file for a legacy fallback).
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read
    }

    /// Read one section's data, verifying its checksum.
    pub fn read(&mut self, name: &str) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.read_into(name, &mut out)?;
        Ok(out)
    }

    /// Read one section into a caller-owned (typically pooled) buffer —
    /// clear + fill, capacity reused — verifying its checksum. One pass:
    /// no intermediate byte vector on any backend.
    pub fn read_into(&mut self, name: &str, out: &mut Vec<f32>) -> Result<()> {
        match &mut self.backend {
            Backend::Legacy(ck) => {
                let d = ck
                    .get(name)
                    .with_context(|| format!("section {name} missing"))?;
                out.clear();
                out.extend_from_slice(d);
                Ok(())
            }
            Backend::File(f) => {
                let e = find_entry(&self.dir, name)?;
                f.seek(SeekFrom::Start(e.offset))?;
                out.clear();
                out.resize(e.len, 0.0);
                // One-pass decode: the payload lands directly in `out`'s
                // storage as raw LE bytes, is checksummed in place, then
                // re-typed element-wise (identity on little-endian —
                // `from_le_bytes(to_ne_bytes(..))` compiles to nothing).
                let view = unsafe {
                    std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, e.len * 4)
                };
                f.read_exact(view)
                    .with_context(|| format!("section {name}: truncated payload"))?;
                if fletcher64(view) != e.sum {
                    bail!("section {name}: checksum mismatch (torn write?)");
                }
                self.bytes_read += (e.len * 4) as u64;
                for v in out.iter_mut() {
                    *v = f32::from_le_bytes(v.to_ne_bytes());
                }
                Ok(())
            }
            Backend::Mapped(bytes) => {
                let e = find_entry(&self.dir, name)?;
                let buf = bytes.as_slice();
                let start = e.offset as usize;
                let end = start
                    .checked_add(e.len.checked_mul(4).context("section length overflow")?)
                    .context("section offset overflow")?;
                if end > buf.len() {
                    bail!("section {name}: truncated payload");
                }
                let payload = &buf[start..end];
                if fletcher64(payload) != e.sum {
                    bail!("section {name}: checksum mismatch (torn write?)");
                }
                out.clear();
                out.reserve(e.len);
                out.extend(
                    payload
                        .chunks_exact(4)
                        .map(|ch| f32::from_le_bytes(ch.try_into().unwrap())),
                );
                self.bytes_read += payload.len() as u64;
                Ok(())
            }
        }
    }
}

/// Minimal read-only mmap binding, hand-declared because the vendored
/// dependency closure has only `anyhow` + `xla` (no `libc`/`memmap2`);
/// these two symbols exist in every unix libc.
#[cfg(unix)]
mod mmap_impl {
    use std::os::unix::io::AsRawFd;

    unsafe extern "C" {
        unsafe fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut core::ffi::c_void;
        unsafe fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    /// Owned read-only mapping of a whole file; unmapped on drop.
    pub struct Map {
        ptr: *mut core::ffi::c_void,
        len: usize,
    }

    // Safety: the mapping is PROT_READ for its entire lifetime, so its
    // bytes are immutable and sharing them across threads is as safe as
    // sharing a `&[u8]`.
    unsafe impl Send for Map {}
    unsafe impl Sync for Map {}

    impl Map {
        /// `None` on any failure (zero-length file is EINVAL, exotic
        /// filesystems, fd limits) — the caller falls back to a buffered
        /// whole-file read, never to an error.
        pub fn of(file: &std::fs::File, len: usize) -> Option<Map> {
            if len == 0 {
                return None;
            }
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr.is_null() || ptr as isize == -1 {
                return None; // MAP_FAILED
            }
            Some(Map { ptr, len })
        }

        pub fn as_slice(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for Map {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

fn load_dpc1(buf: &[u8], path: &Path) -> Result<Checkpoint> {
    if buf.len() < 16 || &buf[..4] != MAGIC_V1 {
        bail!("{}: not a DPC1 checkpoint", path.display());
    }
    let body = &buf[..buf.len() - 8];
    let stored = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
    if fletcher64(body) != stored {
        bail!("{}: checksum mismatch (torn write?)", path.display());
    }
    let mut pos = 4;
    let rd_u32 = |buf: &[u8], pos: &mut usize| -> Result<u32> {
        if *pos + 4 > buf.len() {
            bail!("truncated checkpoint");
        }
        let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
        *pos += 4;
        Ok(v)
    };
    let n_sections = rd_u32(body, &mut pos)?;
    let mut sections = Vec::with_capacity(n_sections as usize);
    for _ in 0..n_sections {
        let name_len = rd_u32(body, &mut pos)? as usize;
        if pos + name_len > body.len() {
            bail!("truncated checkpoint");
        }
        let name = std::str::from_utf8(&body[pos..pos + name_len])
            .context("bad section name")?
            .to_string();
        pos += name_len;
        let len = rd_u32(body, &mut pos)? as usize;
        if pos + 4 * len > body.len() {
            bail!("truncated checkpoint");
        }
        sections.push((name, read_f32s_le(&body[pos..pos + 4 * len])));
        pos += 4 * len;
    }
    Ok(Checkpoint { sections })
}

/// Bulk f32 -> LE bytes: encodes through a stack block per 1024 floats
/// instead of a 4-byte extend per element. Crate-visible: transport
/// frames carry section payloads in exactly this encoding.
pub(crate) fn write_f32s_le(out: &mut Vec<u8>, data: &[f32]) {
    let mut block = [0u8; 4096];
    out.reserve(data.len() * 4);
    for chunk in data.chunks(1024) {
        for (i, &v) in chunk.iter().enumerate() {
            block[4 * i..4 * i + 4].copy_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&block[..4 * chunk.len()]);
    }
}

/// Bulk LE bytes -> f32 into a preallocated vector (no per-element push).
fn read_f32s_le(bytes: &[u8]) -> Vec<f32> {
    debug_assert_eq!(bytes.len() % 4, 0);
    let mut out = vec![0.0f32; bytes.len() / 4];
    for (dst, src) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *dst = f32::from_le_bytes(src.try_into().unwrap());
    }
    out
}

/// The checkpoint checksum, crate-visible so the transport's wire frames
/// verify payloads with the SAME function the DPC2 file format uses —
/// one checksum implementation end to end, file plane and network plane.
pub(crate) fn fletcher64(data: &[u8]) -> u64 {
    let mut a: u64 = 0;
    let mut b: u64 = 0;
    for chunk in data.chunks(4) {
        let mut w = [0u8; 4];
        w[..chunk.len()].copy_from_slice(chunk);
        a = (a + u32::from_le_bytes(w) as u64) % 0xFFFF_FFFF;
        b = (b + a) % 0xFFFF_FFFF;
    }
    (b << 32) | a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("dipaco-ckpt-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip() {
        let p = tmpdir().join("a.dpc");
        let ck = Checkpoint::new()
            .with("theta", vec![1.0, -2.5, 3.25])
            .with("m", vec![0.0; 10])
            .with("loss", vec![4.2]);
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(ck, back);
        assert_eq!(back.get("loss"), Some(&[4.2f32][..]));
    }

    #[test]
    fn detects_directory_corruption() {
        let p = tmpdir().join("b.dpc");
        Checkpoint::new()
            .with("theta", vec![1.0; 100])
            .save(&p)
            .unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[20] ^= 0xFF; // inside the directory entry
        std::fs::write(&p, &bytes).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        assert!(SectionReader::open(&p).is_err());
    }

    #[test]
    fn detects_payload_corruption() {
        let p = tmpdir().join("b2.dpc");
        Checkpoint::new()
            .with("theta", vec![1.0; 100])
            .save(&p)
            .unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 10] ^= 0xFF; // inside the theta payload
        std::fs::write(&p, &bytes).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        // directory is intact, so the reader opens — but the section read
        // must reject the bad payload
        let mut r = SectionReader::open(&p).unwrap();
        assert!(r.read("theta").is_err());
    }

    #[test]
    fn detects_truncation() {
        let p = tmpdir().join("c.dpc");
        Checkpoint::new()
            .with("theta", vec![1.0; 100])
            .save(&p)
            .unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        let mut r = SectionReader::open(&p).unwrap();
        assert!(r.read("theta").is_err());
    }

    #[test]
    fn detects_truncated_directory() {
        let p = tmpdir().join("c2.dpc");
        Checkpoint::new()
            .with("a-section-with-a-long-name", vec![1.0; 50])
            .save(&p)
            .unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..20]).unwrap(); // mid-directory
        assert!(Checkpoint::load(&p).is_err());
        assert!(SectionReader::open(&p).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let p = tmpdir().join("d.dpc");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        assert!(SectionReader::open(&p).is_err());
    }

    #[test]
    fn empty_sections_ok() {
        let p = tmpdir().join("e.dpc");
        Checkpoint::new().save(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap().sections.len(), 0);
    }

    #[test]
    fn dpc1_files_still_load() {
        // Backward compat: files written by the previous revision (DPC1)
        // load through both entry points.
        let p = tmpdir().join("legacy.dpc");
        let ck = Checkpoint::new()
            .with("theta", (0..500).map(|i| i as f32 * 0.5).collect())
            .with("m", vec![1.25; 64]);
        ck.save_dpc1(&p).unwrap();
        assert_eq!(Checkpoint::load(&p).unwrap(), ck);
        // section access falls back to a full parse
        let mut r = SectionReader::open(&p).unwrap();
        assert!(r.has("m"));
        assert_eq!(r.len_of("theta"), Some(500));
        assert_eq!(r.read("m").unwrap(), vec![1.25; 64]);
        assert_eq!(load_section(&p, "theta").unwrap().len(), 500);
    }

    #[test]
    fn dpc1_to_dpc2_migration_roundtrip() {
        let p1 = tmpdir().join("mig1.dpc");
        let p2 = tmpdir().join("mig2.dpc");
        let ck = Checkpoint::new()
            .with("theta", (0..333).map(|i| (i as f32).sin()).collect())
            .with("loss", vec![2.5]);
        ck.save_dpc1(&p1).unwrap();
        let loaded = Checkpoint::load(&p1).unwrap();
        loaded.save(&p2).unwrap(); // re-save migrates to DPC2
        assert_eq!(&std::fs::read(&p2).unwrap()[..4], b"DPC2");
        assert_eq!(Checkpoint::load(&p2).unwrap(), ck);
    }

    #[test]
    fn section_random_access_reads_only_that_payload() {
        let p = tmpdir().join("ra.dpc");
        Checkpoint::new()
            .with("big", vec![9.0; 10_000])
            .with("small", vec![1.0, 2.0, 3.0])
            .with("other", vec![7.0; 5_000])
            .save(&p)
            .unwrap();
        let mut r = SectionReader::open(&p).unwrap();
        assert_eq!(r.names(), vec!["big", "small", "other"]);
        let small = r.read("small").unwrap();
        assert_eq!(small, vec![1.0, 2.0, 3.0]);
        // byte accounting: exactly the requested section's payload
        assert_eq!(r.bytes_read(), 3 * 4);
        let file_len = std::fs::metadata(&p).unwrap().len();
        assert!(r.bytes_read() < file_len / 100);
        // convenience helper agrees
        assert_eq!(load_section(&p, "small").unwrap(), small);
        assert!(load_section(&p, "missing").is_err());
    }

    #[test]
    fn corruption_errors_are_distinct() {
        // The chaos corruptor's three damage modes must each surface a
        // *different*, matchable error — operators (and the chaos oracle)
        // tell torn tails, flipped bits, and mangled directories apart.
        use crate::chaos::corruptor::{corrupt_file, CorruptMode};
        let dir = tmpdir();
        let theta: Vec<f32> = (0..256).map(|i| i as f32 * 0.25).collect();
        let tail = vec![1.5f32; 256];
        let write = |p: &Path| {
            save_sections(p, &[("theta", theta.as_slice()), ("tail", tail.as_slice())]).unwrap()
        };

        // payload truncation: the first section survives, the second's
        // payload is cut — a short read, NOT a checksum complaint
        let p = dir.join("x-trunc.dpc");
        write(&p);
        corrupt_file(&p, CorruptMode::TruncatePayload).unwrap();
        let mut r = SectionReader::open(&p).unwrap();
        assert_eq!(r.read("theta").unwrap(), theta);
        let e = format!("{:#}", r.read("tail").unwrap_err());
        assert!(e.contains("truncated payload"), "wrong truncation error: {e}");
        assert!(!e.contains("checksum mismatch"), "misdiagnosed as checksum: {e}");

        // payload bit-flip: directory opens fine, section read fails its
        // fletcher64 check
        let p = dir.join("x-flip.dpc");
        write(&p);
        corrupt_file(&p, CorruptMode::FlipPayloadByte).unwrap();
        let mut r = SectionReader::open(&p).unwrap();
        let e = format!("{:#}", r.read("theta").unwrap_err());
        assert!(e.contains("checksum mismatch (torn write?)"), "wrong flip error: {e}");

        // directory damage: rejected at open, before any payload is read
        let p = dir.join("x-dir.dpc");
        write(&p);
        corrupt_file(&p, CorruptMode::DamageDirectory).unwrap();
        let e = format!("{:#}", SectionReader::open(&p).unwrap_err());
        assert!(
            e.contains("section directory checksum mismatch"),
            "wrong directory error: {e}"
        );
    }

    #[test]
    fn mapped_reader_matches_buffered() {
        let p = tmpdir().join("map1.dpc");
        let big: Vec<f32> = (0..4096).map(|i| (i as f32).cos()).collect();
        let small = [1.0f32, 2.0];
        save_sections(&p, &[("big", &big), ("small", &small)]).unwrap();
        let mut buffered = SectionReader::open(&p).unwrap();
        let mut mapped = SectionReader::open_mapped(&p).unwrap();
        assert_eq!(mapped.names(), buffered.names());
        assert_eq!(mapped.len_of("big"), Some(4096));
        for name in ["big", "small"] {
            let a = buffered.read(name).unwrap();
            let b = mapped.read(name).unwrap();
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a), bits(&b), "backends disagree on {name}");
        }
        // identical byte accounting in both modes
        assert_eq!(mapped.bytes_read(), buffered.bytes_read());
        assert_eq!(mapped.bytes_read(), (4096 + 2) * 4);
    }

    #[test]
    fn read_into_reuses_buffer_and_matches_read() {
        let p = tmpdir().join("map2.dpc");
        let a: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        let b = vec![7.0f32; 10];
        save_sections(&p, &[("a", &a), ("b", &b)]).unwrap();
        type Open = fn(&Path) -> Result<SectionReader>;
        for open in [SectionReader::open as Open, SectionReader::open_mapped] {
            let mut r = open(&p).unwrap();
            let mut buf = vec![9.9f32; 3]; // dirty, wrong-sized
            r.read_into("a", &mut buf).unwrap();
            assert_eq!(buf, a);
            let cap = buf.capacity();
            r.read_into("b", &mut buf).unwrap();
            assert_eq!(buf, b);
            assert!(cap >= 1000 && buf.capacity() >= cap, "buffer must be reused");
            assert!(r.read_into("missing", &mut buf).is_err());
            assert_eq!(r.bytes_read(), (1000 + 10) * 4);
        }
        // convenience helper agrees
        let mut out = Vec::new();
        load_section_into(&p, "b", &mut out).unwrap();
        assert_eq!(out, b);
    }

    #[test]
    fn mapped_corruption_errors_match_buffered() {
        // The mapped backend must diagnose the corruptor's three damage
        // modes with the SAME error strings as the buffered one — the
        // chaos oracle matches on them.
        use crate::chaos::corruptor::{corrupt_file, CorruptMode};
        let dir = tmpdir();
        let theta: Vec<f32> = (0..256).map(|i| i as f32 * 0.25).collect();
        let tail = vec![1.5f32; 256];
        let write = |p: &Path| {
            save_sections(p, &[("theta", theta.as_slice()), ("tail", tail.as_slice())]).unwrap()
        };

        let p = dir.join("m-trunc.dpc");
        write(&p);
        corrupt_file(&p, CorruptMode::TruncatePayload).unwrap();
        let mut r = SectionReader::open_mapped(&p).unwrap();
        assert_eq!(r.read("theta").unwrap(), theta);
        let e = format!("{:#}", r.read("tail").unwrap_err());
        assert!(e.contains("truncated payload"), "wrong truncation error: {e}");
        assert!(!e.contains("checksum mismatch"), "misdiagnosed as checksum: {e}");

        let p = dir.join("m-flip.dpc");
        write(&p);
        corrupt_file(&p, CorruptMode::FlipPayloadByte).unwrap();
        let mut r = SectionReader::open_mapped(&p).unwrap();
        let e = format!("{:#}", r.read("theta").unwrap_err());
        assert!(e.contains("checksum mismatch (torn write?)"), "wrong flip error: {e}");

        let p = dir.join("m-dir.dpc");
        write(&p);
        corrupt_file(&p, CorruptMode::DamageDirectory).unwrap();
        let e = format!("{:#}", SectionReader::open_mapped(&p).unwrap_err());
        assert!(
            e.contains("section directory checksum mismatch"),
            "wrong directory error: {e}"
        );
    }

    #[test]
    fn mapped_reader_handles_dpc1_and_garbage() {
        let p = tmpdir().join("map-legacy.dpc");
        let ck = Checkpoint::new().with("theta", vec![3.0; 20]);
        ck.save_dpc1(&p).unwrap();
        let mut r = SectionReader::open_mapped(&p).unwrap();
        assert_eq!(r.read("theta").unwrap(), vec![3.0; 20]);
        let file_len = std::fs::metadata(&p).unwrap().len();
        assert_eq!(r.bytes_read(), file_len, "legacy counts the whole file");

        let g = tmpdir().join("map-garbage.dpc");
        std::fs::write(&g, b"not a checkpoint at all").unwrap();
        assert!(SectionReader::open_mapped(&g).is_err());
        let empty = tmpdir().join("map-empty.dpc");
        std::fs::write(&empty, b"").unwrap();
        assert!(SectionReader::open_mapped(&empty).is_err());
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn bf16_codec_roundtrip_error_bound() {
        let mut rng = crate::util::rng::Rng::new(0xB16);
        let xs: Vec<f32> = (0..4097).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let (words, residual) = encode_delta_feedback(DeltaCodec::Bf16, &xs);
        // ~2x wire cut (header amortizes away)
        assert!(words.len() <= xs.len() / 2 + 4, "bf16 wire too large: {}", words.len());
        let back = decode_delta(DeltaCodec::Bf16, &words).unwrap();
        assert_eq!(back.len(), xs.len());
        for ((&x, &d), &r) in xs.iter().zip(&back).zip(&residual) {
            // RNE to 8 significant bits: error at most half a bf16 ulp
            assert!(
                (x - d).abs() <= x.abs() / 256.0 + f32::MIN_POSITIVE,
                "bf16 error out of bounds: {x} -> {d}"
            );
            assert_eq!(
                (d + r).to_bits(),
                x.to_bits(),
                "error feedback must reconstruct exactly: {x} -> {d} + {r}"
            );
        }
    }

    #[test]
    fn int8_codec_roundtrip_error_bound_and_wire_size() {
        let mut rng = crate::util::rng::Rng::new(0x1A8);
        let xs: Vec<f32> = (0..10_000).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let (words, residual) = encode_delta_feedback(DeltaCodec::Int8, &xs);
        let absmax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = absmax / 127.0;
        let back = decode_delta(DeltaCodec::Int8, &words).unwrap();
        assert_eq!(back.len(), xs.len());
        for ((&x, &d), &r) in xs.iter().zip(&back).zip(&residual) {
            assert!(
                (x - d).abs() <= scale * 0.5001 + f32::MIN_POSITIVE,
                "int8 error out of bounds: {x} -> {d} (scale {scale})"
            );
            assert_eq!(
                (d + r).to_bits(),
                x.to_bits(),
                "error feedback must reconstruct exactly: {x} -> {d} + {r}"
            );
        }
        // the acceptance bar: >= 3.5x fewer wire bytes than raw f32
        let ratio = xs.len() as f64 / words.len() as f64;
        assert!(ratio >= 3.5, "int8 wire cut only {ratio:.2}x");
    }

    #[test]
    fn error_feedback_reconstructs_exactly_over_a_phase_pair() {
        // Over two phases, what was shipped plus what is still carried
        // must equal what the worker computed, bit for bit: the codec
        // defers information, it never destroys it.
        for codec in [DeltaCodec::F32, DeltaCodec::Bf16, DeltaCodec::Int8] {
            let mut rng = crate::util::rng::Rng::new(0xFEED);
            let exact1: Vec<f32> = (0..1001).map(|_| rng.normal_f32(0.0, 0.1)).collect();
            let exact2: Vec<f32> = (0..1001).map(|_| rng.normal_f32(0.0, 0.1)).collect();
            let (w1, r1) = encode_delta_feedback(codec, &exact1);
            let d1 = decode_delta(codec, &w1).unwrap();
            for i in 0..exact1.len() {
                assert_eq!((d1[i] + r1[i]).to_bits(), exact1[i].to_bits(), "{codec} phase 1");
            }
            // phase 2's delta carries phase 1's residual
            let total2: Vec<f32> = exact2.iter().zip(&r1).map(|(&e, &r)| e + r).collect();
            let (w2, r2) = encode_delta_feedback(codec, &total2);
            let d2 = decode_delta(codec, &w2).unwrap();
            for i in 0..total2.len() {
                assert_eq!((d2[i] + r2[i]).to_bits(), total2[i].to_bits(), "{codec} phase 2");
            }
            if codec == DeltaCodec::F32 {
                assert!(r1.iter().all(|&r| r == 0.0), "f32 codec is exact");
                assert_eq!(bits(&w1), bits(&exact1), "f32 codec is the identity");
            }
        }
    }

    #[test]
    fn dpc2_rejects_corrupted_quantized_section() {
        let p = tmpdir().join("qcorrupt.dpc");
        let mut rng = crate::util::rng::Rng::new(0xC0);
        let xs: Vec<f32> = (0..513).map(|_| rng.normal_f32(0.0, 0.2)).collect();
        let words = encode_delta(DeltaCodec::Int8, &xs);
        save_sections(&p, &[("delta:L0E0", &words)]).unwrap();
        // the file roundtrip is bit-exact on the wire words
        let mut r = SectionReader::open(&p).unwrap();
        let raw = r.read("delta:L0E0").unwrap();
        assert_eq!(bits(&raw), bits(&words));
        assert_eq!(
            bits(&decode_delta(DeltaCodec::Int8, &raw).unwrap()),
            bits(&decode_delta(DeltaCodec::Int8, &words).unwrap())
        );
        // flip one quantized payload byte: the ordinary DPC2 section
        // checksum must reject it before any decode happens
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let mut r = SectionReader::open(&p).unwrap();
        let e = format!("{:#}", r.read("delta:L0E0").unwrap_err());
        assert!(e.contains("checksum mismatch (torn write?)"), "wrong error: {e}");
    }

    #[test]
    fn decode_rejects_codec_mismatch() {
        let xs = vec![0.5f32; 9];
        let w8 = encode_delta(DeltaCodec::Int8, &xs);
        let wb = encode_delta(DeltaCodec::Bf16, &xs);
        let e = format!("{:#}", decode_delta(DeltaCodec::Bf16, &w8).unwrap_err());
        assert!(e.contains("delta codec mismatch"), "wrong error: {e}");
        assert!(decode_delta(DeltaCodec::Int8, &wb).is_err());
        // raw f32 words are not a quantized section
        assert!(decode_delta(DeltaCodec::Int8, &xs).is_err());
        // truncated payload is caught by the length check
        let mut short = w8.clone();
        short.pop();
        assert!(decode_delta(DeltaCodec::Int8, &short).is_err());
        // F32 decode is the identity
        assert_eq!(decode_delta(DeltaCodec::F32, &xs).unwrap(), xs);
    }

    #[test]
    fn save_sections_matches_checkpoint_save() {
        let p1 = tmpdir().join("ss1.dpc");
        let p2 = tmpdir().join("ss2.dpc");
        let a: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let b = vec![0.5f32; 7];
        Checkpoint::new()
            .with("a", a.clone())
            .with("b", b.clone())
            .save(&p1)
            .unwrap();
        save_sections(&p2, &[("a", &a), ("b", &b)]).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    }
}
