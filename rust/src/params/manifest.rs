//! Flat-parameter manifest — the contract between the JAX compile path and
//! the rust coordinator.
//!
//! `python/compile/aot.py` writes `manifest.json` next to the HLO files:
//! an ordered table of leaves `(name, offset, size, shape)` describing how
//! the flat `f32[N]` parameter vector decomposes, plus the resolved model
//! config. Everything DiPaCo does with parameters — module slicing, path
//! assembly, outer-gradient splitting, checkpointing — is range arithmetic
//! over this table.

use crate::config::ModelConfig;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct Leaf {
    pub name: String,
    pub offset: usize,
    pub size: usize,
    pub shape: Vec<usize>,
}

impl Leaf {
    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.size
    }

    /// Block index for `block{i}.*` leaves, None for stem leaves
    /// (`embed.*`, `final.*`, `head.*`).
    pub fn block(&self) -> Option<usize> {
        let rest = self.name.strip_prefix("block")?;
        let end = rest.find('.')?;
        rest[..end].parse().ok()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub model: ModelConfig,
    pub total_params: usize,
    pub leaves: Vec<Leaf>,
    pub entrypoints: Vec<String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Manifest> {
        let model = ModelConfig::from_manifest_json(v)?;
        let total = v
            .req("total_params")?
            .as_usize()
            .context("total_params")?;
        let mut leaves = Vec::new();
        let mut expect_off = 0usize;
        for lj in v.req("leaves")?.as_arr().context("leaves")? {
            let leaf = Leaf {
                name: lj.req("name")?.as_str().context("leaf name")?.to_string(),
                offset: lj.req("offset")?.as_usize().context("leaf offset")?,
                size: lj.req("size")?.as_usize().context("leaf size")?,
                shape: lj
                    .req("shape")?
                    .as_arr()
                    .context("leaf shape")?
                    .iter()
                    .filter_map(|s| s.as_usize())
                    .collect(),
            };
            if leaf.offset != expect_off {
                bail!("leaf {} offset {} != expected {}", leaf.name, leaf.offset, expect_off);
            }
            if leaf.shape.iter().product::<usize>() != leaf.size {
                bail!("leaf {} shape/size mismatch", leaf.name);
            }
            expect_off += leaf.size;
            leaves.push(leaf);
        }
        if expect_off != total {
            bail!("leaves sum {} != total_params {}", expect_off, total);
        }
        let entrypoints = v
            .get("entrypoints")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
            .unwrap_or_default();
        Ok(Manifest {
            preset: model.preset.clone(),
            model,
            total_params: total,
            leaves,
            entrypoints,
        })
    }

    pub fn leaf(&self, name: &str) -> Option<&Leaf> {
        self.leaves.iter().find(|l| l.name == name)
    }

    /// All leaves of block `i`, in offset order.
    pub fn block_leaves(&self, block: usize) -> Vec<&Leaf> {
        self.leaves.iter().filter(|l| l.block() == Some(block)).collect()
    }

    /// Stem leaves (embedding, final LN, head).
    pub fn stem_leaves(&self) -> Vec<&Leaf> {
        self.leaves.iter().filter(|l| l.block().is_none()).collect()
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;

    pub fn fake_manifest_json(n_layers: usize, d: usize) -> String {
        // Mirrors python layout() ordering for a miniature model.
        let mut leaves = Vec::new();
        let mut off = 0usize;
        let mut push = |name: String, shape: Vec<usize>, off: &mut usize| {
            let size: usize = shape.iter().product();
            leaves.push(format!(
                r#"{{"name":"{name}","offset":{off},"size":{size},"shape":[{}]}}"#,
                shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
            ));
            *off += size;
        };
        push("embed.tok".into(), vec![64, d], &mut off);
        push("embed.pos".into(), vec![48, d], &mut off);
        for i in 0..n_layers {
            for (suffix, shape) in [
                ("ln1.scale", vec![d]),
                ("ln1.bias", vec![d]),
                ("attn.wq", vec![d, d]),
                ("attn.wk", vec![d, d]),
                ("attn.wv", vec![d, d]),
                ("attn.wo", vec![d, d]),
                ("ln2.scale", vec![d]),
                ("ln2.bias", vec![d]),
                ("mlp.w1", vec![d, 2 * d]),
                ("mlp.b1", vec![2 * d]),
                ("mlp.w2", vec![2 * d, d]),
                ("mlp.b2", vec![d]),
            ] {
                push(format!("block{i}.{suffix}"), shape, &mut off);
            }
        }
        push("final.ln.scale".into(), vec![d], &mut off);
        push("final.ln.bias".into(), vec![d], &mut off);
        push("head.w".into(), vec![d, 64], &mut off);
        format!(
            r#"{{"preset":"fake","config":{{"vocab":64,"d_model":{d},"n_layers":{n_layers},
              "n_heads":2,"d_ff":{f},"seq_train":32,"seq_eval":48,"batch":2,"prefix":8,"d_head":{dh}}},
              "total_params":{off},"leaves":[{leaves}],
              "entrypoints":["init","train_step"]}}"#,
            f = 2 * d,
            dh = d / 2,
            leaves = leaves.join(",")
        )
    }

    #[test]
    fn parse_fake_manifest() {
        let m = Manifest::from_json(&Json::parse(&fake_manifest_json(2, 8)).unwrap()).unwrap();
        assert_eq!(m.model.n_layers, 2);
        assert_eq!(m.leaves.len(), 2 + 2 * 12 + 3);
        assert_eq!(
            m.leaves.iter().map(|l| l.size).sum::<usize>(),
            m.total_params
        );
    }

    #[test]
    fn block_parsing() {
        let m = Manifest::from_json(&Json::parse(&fake_manifest_json(3, 8)).unwrap()).unwrap();
        assert_eq!(m.leaf("block2.attn.wq").unwrap().block(), Some(2));
        assert_eq!(m.leaf("embed.tok").unwrap().block(), None);
        assert_eq!(m.block_leaves(1).len(), 12);
        assert_eq!(m.stem_leaves().len(), 5);
    }

    #[test]
    fn rejects_gap_in_offsets() {
        let bad = r#"{"preset":"x","config":{"vocab":4,"d_model":2,"n_layers":1,
          "n_heads":1,"d_ff":4,"seq_train":8,"seq_eval":8,"batch":1,"prefix":2,"d_head":2},
          "total_params":6,
          "leaves":[{"name":"a","offset":0,"size":2,"shape":[2]},
                    {"name":"b","offset":3,"size":3,"shape":[3]}]}"#;
        assert!(Manifest::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn real_artifact_manifest_if_present() {
        // When artifacts are built, validate the real thing end-to-end.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/test");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.model.preset, "test");
            assert!(m.total_params > 0);
            assert!(m.entrypoints.iter().any(|e| e == "train_step"));
        }
    }
}
