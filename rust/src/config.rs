//! Configuration system.
//!
//! [`ModelConfig`] mirrors `python/compile/configs.py` but is *loaded from
//! the artifact manifest* (`artifacts/<preset>/manifest.json`) so the two
//! sides cannot drift: whatever the model was compiled with is what the
//! coordinator uses.
//!
//! The remaining configs are pure-rust run settings: DiPaCo topology
//! ([`TopologySpec`]), DiLoCo outer optimization ([`DilocoConfig`]),
//! routing ([`RoutingConfig`]), corpus generation ([`CorpusConfig`]) and
//! the coordinator runtime ([`RunConfig`]). All are JSON round-trippable
//! for experiment configs and run manifests.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Model/compile-time configuration (read from `manifest.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub preset: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_train: usize,
    pub seq_eval: usize,
    pub batch: usize,
    pub prefix: usize,
    /// Steps fused per `train_steps` HLO call (0 = artifact not built
    /// with fusion; fall back to per-step dispatch).
    pub tau: usize,
}

impl ModelConfig {
    pub fn from_manifest_json(v: &Json) -> Result<Self> {
        let c = v.req("config").context("manifest missing config")?;
        let field = |k: &str| -> Result<usize> {
            c.req(k)
                .ok()
                .and_then(|x| x.as_usize())
                .with_context(|| format!("manifest config field {k}"))
        };
        Ok(ModelConfig {
            preset: v
                .req("preset")?
                .as_str()
                .context("preset not a string")?
                .to_string(),
            vocab: field("vocab")?,
            d_model: field("d_model")?,
            n_layers: field("n_layers")?,
            n_heads: field("n_heads")?,
            d_ff: field("d_ff")?,
            seq_train: field("seq_train")?,
            seq_eval: field("seq_eval")?,
            batch: field("batch")?,
            prefix: field("prefix")?,
            tau: c.get("tau").and_then(|x| x.as_usize()).unwrap_or(0),
        })
    }

    /// Tokens per training batch that count toward the loss.
    pub fn loss_tokens_per_batch(&self) -> usize {
        self.batch * (self.seq_train - self.prefix)
    }
}

/// How transformer blocks map to DiPaCo levels, and how many experts each
/// level has (paper §2.3/§2.6).
#[derive(Debug, Clone, PartialEq)]
pub struct TopologySpec {
    /// Experts per level, e.g. `[4, 4]` is a 4x4 DiPaCo (16 paths).
    /// `k = 0` is sugar for "path-specific" (`K_l` = number of paths).
    pub experts_per_level: Vec<usize>,
    /// Stem placement: which level the embedding/final/head leaves join.
    /// `Shared` pins them to a K=1 virtual level (shared by all paths,
    /// the default); `Level(i)` attaches them to level i; `PathSpecific`
    /// never communicates them (paper §4.2: "the transformer blocks
    /// 0, 5, 6, 11, and the embedding matrix are not communicated").
    pub stem: StemPlacement,
    /// Block indices (per level boundaries are derived by even split
    /// unless given explicitly).
    pub level_blocks: Option<Vec<Vec<usize>>>,
    /// Extra blocks that are path-specific regardless of level (paper
    /// §4.2 path-specific-modules variant).
    pub path_specific_blocks: Vec<usize>,
    /// Data-parallel replicas sharing the SAME module assignment: paths =
    /// replicas x prod(K_l). DiLoCo-P (paper §2.5 / Table 1) is
    /// `experts_per_level = [1], replicas = P` — P workers on P shards,
    /// every module shared, collapsed at each outer step.
    pub replicas: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StemPlacement {
    Shared,
    PathSpecific,
}

impl TopologySpec {
    /// `KxK` grid over evenly split blocks, shared stem — the paper's
    /// default configuration (e.g. 16x16 in §4.1).
    pub fn grid(experts_per_level: Vec<usize>) -> Self {
        TopologySpec {
            experts_per_level,
            stem: StemPlacement::Shared,
            level_blocks: None,
            path_specific_blocks: vec![],
            replicas: 1,
        }
    }

    /// DiLoCo with `p` data-parallel workers: one expert per level, every
    /// module shared by all paths, collapsed at each outer step.
    pub fn diloco(p: usize) -> Self {
        let mut spec = Self::grid(vec![1]);
        spec.replicas = p;
        spec
    }

    /// Flat MoE with `p` fully independent paths (paper §2.6.3):
    /// one level, `p` experts, path-specific stem.
    pub fn flat_moe(p: usize) -> Self {
        TopologySpec {
            experts_per_level: vec![p],
            stem: StemPlacement::PathSpecific,
            level_blocks: None,
            path_specific_blocks: vec![],
            replicas: 1,
        }
    }

    pub fn paths(&self) -> usize {
        self.experts_per_level.iter().product::<usize>().max(1) * self.replicas.max(1)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "experts_per_level",
                Json::arr(self.experts_per_level.iter().map(|&k| Json::num(k as f64))),
            ),
            (
                "stem",
                Json::str(match self.stem {
                    StemPlacement::Shared => "shared",
                    StemPlacement::PathSpecific => "path_specific",
                }),
            ),
            (
                "path_specific_blocks",
                Json::arr(self.path_specific_blocks.iter().map(|&b| Json::num(b as f64))),
            ),
            ("replicas", Json::num(self.replicas.max(1) as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let experts = v
            .req("experts_per_level")?
            .as_arr()
            .context("experts_per_level not an array")?
            .iter()
            .map(|j| j.as_usize().context("bad expert count"))
            .collect::<Result<Vec<_>>>()?;
        let stem = match v.get("stem").and_then(|s| s.as_str()) {
            Some("path_specific") => StemPlacement::PathSpecific,
            _ => StemPlacement::Shared,
        };
        let psb = v
            .get("path_specific_blocks")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|j| j.as_usize()).collect())
            .unwrap_or_default();
        if experts.is_empty() {
            bail!("experts_per_level empty");
        }
        Ok(TopologySpec {
            experts_per_level: experts,
            stem,
            level_blocks: None,
            path_specific_blocks: psb,
            replicas: v.get("replicas").and_then(|r| r.as_usize()).unwrap_or(1),
        })
    }
}

/// DiLoCo outer optimization (paper §2.5, §7.1).
#[derive(Debug, Clone, PartialEq)]
pub struct DilocoConfig {
    /// Inner steps per outer round (tau; paper §4.2 uses 150).
    pub inner_steps: usize,
    /// Outer Nesterov learning rate (paper: 0.7).
    pub outer_lr: f32,
    /// Outer Nesterov momentum (paper: 0.9).
    pub outer_momentum: f32,
    /// Rescale module outer-gradients by sqrt(paths through module)
    /// (paper §2.7 "Outer Gradient Norm Rescaling").
    pub norm_rescale: bool,
    /// Weigh outer gradients by shard size (paper §2.7 Eq. 2-3).
    pub loss_reweigh: bool,
    /// Peak inner (AdamW) learning rate; cosine schedule (paper: 4e-4...
    /// scaled up for the smaller model here).
    pub peak_lr: f32,
    /// Warmup steps for the inner schedule (paper: 1000).
    pub warmup_steps: usize,
    /// Total inner steps the cosine schedule decays over.
    pub total_steps: usize,
}

impl Default for DilocoConfig {
    fn default() -> Self {
        DilocoConfig {
            inner_steps: 50,
            outer_lr: 0.7,
            outer_momentum: 0.9,
            norm_rescale: true,
            loss_reweigh: true,
            peak_lr: 1e-3,
            warmup_steps: 100,
            total_steps: 2000,
        }
    }
}

impl DilocoConfig {
    /// Cosine schedule with linear warmup; `step` is 1-based.
    pub fn lr_at(&self, step: usize) -> f32 {
        let s = step as f32;
        let w = self.warmup_steps.max(1) as f32;
        if step <= self.warmup_steps {
            return self.peak_lr * s / w;
        }
        let t = ((s - w) / (self.total_steps as f32 - w).max(1.0)).min(1.0);
        let min_lr = 0.1 * self.peak_lr;
        min_lr + 0.5 * (self.peak_lr - min_lr) * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

/// Coarse-routing configuration (paper §2.4, §7.2).
#[derive(Debug, Clone, PartialEq)]
pub struct RoutingConfig {
    /// k-means iterations for the generative router.
    pub kmeans_iters: usize,
    /// Use product k-means (paper §7.3) for the generative stage.
    pub product_kmeans: bool,
    /// Overlap shards with top-n assignment at train time (paper §2.4.4;
    /// the 16x16 run uses top-2). 1 = disjoint shards.
    pub train_overlap: usize,
    /// Fraction of documents reserved as router data (paper: 0.005 of C4;
    /// higher here because the corpus is much smaller).
    pub router_data_frac: f64,
    /// Logistic-regression epochs for the discriminative router.
    pub logistic_epochs: usize,
    /// Logistic-regression learning rate.
    pub logistic_lr: f64,
    /// Calibrate class biases to the target document distribution
    /// (paper §7.2.1).
    pub calibrate_bias: bool,
}

impl Default for RoutingConfig {
    fn default() -> Self {
        RoutingConfig {
            kmeans_iters: 25,
            product_kmeans: false,
            train_overlap: 1,
            router_data_frac: 0.05,
            logistic_epochs: 60,
            logistic_lr: 0.5,
            calibrate_bias: true,
        }
    }
}

/// Synthetic multi-domain corpus (the C4 substitution — DESIGN.md).
#[derive(Debug, Clone, PartialEq)]
pub struct CorpusConfig {
    pub n_domains: usize,
    pub n_docs: usize,
    pub doc_len: (usize, usize),
    /// Zipf skew for domain weights (0 = uniform).
    pub skew: f64,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            n_domains: 16,
            n_docs: 6000,
            doc_len: (300, 700),
            skew: 0.3,
            seed: 1234,
        }
    }
}

/// Per-path circuit breaker ([`crate::serve::breaker`]): admission stops
/// routing to a path whose recent batches keep failing (or run too slow)
/// until half-open probe batches prove it healthy again.
#[derive(Debug, Clone, PartialEq)]
pub struct BreakerConfig {
    /// Master switch; a disabled breaker always admits and never trips.
    pub enabled: bool,
    /// Sliding window of recent batch outcomes consulted by trip checks.
    pub window: usize,
    /// Minimum outcomes in the window before the breaker may trip (avoids
    /// tripping a cold path on its very first error).
    pub min_samples: usize,
    /// Trip when the window's failure fraction reaches this.
    pub error_rate: f64,
    /// Trip when the window's mean batch execution time reaches this, in
    /// ms (0 = latency tripping disabled).
    pub latency_ms: f64,
    /// How long an open breaker blocks admission before probing, ms.
    pub cooldown_ms: u64,
    /// Successful probe batches required to close from half-open; any
    /// failed probe re-opens immediately.
    pub probes: usize,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            enabled: true,
            window: 32,
            min_samples: 8,
            error_rate: 0.5,
            latency_ms: 0.0,
            cooldown_ms: 1000,
            probes: 2,
        }
    }
}

impl BreakerConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::Bool(self.enabled)),
            ("window", Json::num(self.window as f64)),
            ("min_samples", Json::num(self.min_samples as f64)),
            ("error_rate", Json::num(self.error_rate)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("cooldown_ms", Json::num(self.cooldown_ms as f64)),
            ("probes", Json::num(self.probes as f64)),
        ])
    }

    pub fn from_json(v: Option<&Json>) -> Self {
        let d = BreakerConfig::default();
        let v = match v {
            Some(v) => v,
            None => return d,
        };
        let get = |k: &str, dv: usize| v.get(k).and_then(|x| x.as_usize()).unwrap_or(dv);
        let getf = |k: &str, dv: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(dv);
        BreakerConfig {
            enabled: v.get("enabled").and_then(|x| x.as_bool()).unwrap_or(d.enabled),
            window: get("window", d.window).max(1),
            min_samples: get("min_samples", d.min_samples).max(1),
            error_rate: getf("error_rate", d.error_rate),
            latency_ms: getf("latency_ms", d.latency_ms),
            cooldown_ms: get("cooldown_ms", d.cooldown_ms as usize) as u64,
            probes: get("probes", d.probes).max(1),
        }
    }
}

/// Path-worker supervision ([`crate::serve::supervisor`]): restart policy
/// for a worker whose executor panicked.
#[derive(Debug, Clone, PartialEq)]
pub struct SupervisorConfig {
    /// First restart delay after a panic, ms (doubles per consecutive
    /// panic).
    pub backoff_ms: u64,
    /// Exponential backoff cap, ms.
    pub backoff_max_ms: u64,
    /// Consecutive panics (no successful batch in between) before the
    /// path is declared `Down` and its queue is drained with errors;
    /// 0 = restart forever.
    pub max_consecutive_panics: usize,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            backoff_ms: 10,
            backoff_max_ms: 2000,
            max_consecutive_panics: 0,
        }
    }
}

impl SupervisorConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("backoff_ms", Json::num(self.backoff_ms as f64)),
            ("backoff_max_ms", Json::num(self.backoff_max_ms as f64)),
            (
                "max_consecutive_panics",
                Json::num(self.max_consecutive_panics as f64),
            ),
        ])
    }

    pub fn from_json(v: Option<&Json>) -> Self {
        let d = SupervisorConfig::default();
        let v = match v {
            Some(v) => v,
            None => return d,
        };
        let get = |k: &str, dv: usize| v.get(k).and_then(|x| x.as_usize()).unwrap_or(dv);
        SupervisorConfig {
            backoff_ms: get("backoff_ms", d.backoff_ms as usize) as u64,
            backoff_max_ms: get("backoff_max_ms", d.backoff_max_ms as usize) as u64,
            max_consecutive_panics: get("max_consecutive_panics", d.max_consecutive_panics),
        }
    }
}

/// Serving subsystem settings (paper §2.6 deployment: independent path
/// servers behind a document router — see DESIGN.md, "serve").
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Bounded per-path queue capacity (admission backpressure).
    pub queue_cap: usize,
    /// Micro-batch flush size; 0 = the engine's compiled batch shape.
    /// Values above the compiled batch are clamped to it.
    pub max_batch: usize,
    /// Micro-batch flush deadline, ms from the first queued document.
    pub max_wait_ms: u64,
    /// Backpressure policy when a path queue is full: reject immediately
    /// (true) or park admission until space frees (false).
    pub reject_on_full: bool,
    /// Park timeout for the block policy, ms; parked admissions that
    /// outlast it are rejected as overloaded.
    pub admission_timeout_ms: u64,
    /// Concurrent admission (client) threads the CLI driver and bench use
    /// to generate traffic. Path-server workers are always one per path.
    pub workers: usize,
    /// Worker housekeeping tick when its queue is idle, ms.
    pub idle_ms: u64,
    /// Enqueue deadline for a redirected (degraded-mode) request, ms: a
    /// fallback queue that cannot take it within this window sheds the
    /// request with a loud `ServeError::Shed` instead of parking.
    pub shed_deadline_ms: u64,
    /// Per-path circuit breaker consulted at admission.
    pub breaker: BreakerConfig,
    /// Path-worker restart policy.
    pub supervisor: SupervisorConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_cap: 64,
            max_batch: 0,
            max_wait_ms: 15,
            reject_on_full: false,
            admission_timeout_ms: 1000,
            workers: 4,
            idle_ms: 50,
            shed_deadline_ms: 5,
            breaker: BreakerConfig::default(),
            supervisor: SupervisorConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queue_cap", Json::num(self.queue_cap as f64)),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("max_wait_ms", Json::num(self.max_wait_ms as f64)),
            ("reject_on_full", Json::Bool(self.reject_on_full)),
            (
                "admission_timeout_ms",
                Json::num(self.admission_timeout_ms as f64),
            ),
            ("workers", Json::num(self.workers as f64)),
            ("idle_ms", Json::num(self.idle_ms as f64)),
            ("shed_deadline_ms", Json::num(self.shed_deadline_ms as f64)),
            ("breaker", self.breaker.to_json()),
            ("supervisor", self.supervisor.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let d = ServeConfig::default();
        let get = |k: &str, dv: usize| v.get(k).and_then(|x| x.as_usize()).unwrap_or(dv);
        Ok(ServeConfig {
            queue_cap: get("queue_cap", d.queue_cap).max(1),
            max_batch: get("max_batch", d.max_batch),
            max_wait_ms: get("max_wait_ms", d.max_wait_ms as usize) as u64,
            reject_on_full: v
                .get("reject_on_full")
                .and_then(|x| x.as_bool())
                .unwrap_or(d.reject_on_full),
            admission_timeout_ms: get("admission_timeout_ms", d.admission_timeout_ms as usize)
                as u64,
            workers: get("workers", d.workers).max(1),
            idle_ms: get("idle_ms", d.idle_ms as usize) as u64,
            shed_deadline_ms: get("shed_deadline_ms", d.shed_deadline_ms as usize) as u64,
            breaker: BreakerConfig::from_json(v.get("breaker")),
            supervisor: SupervisorConfig::from_json(v.get("supervisor")),
        })
    }
}

/// Wire codec for `delta:` sections in worker checkpoints (streaming
/// outer sync). Lossy codecs pair with worker-side error feedback: the
/// quantization residual is carried into the next phase's delta, so the
/// information lost per phase is bounded by one quantization step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeltaCodec {
    /// Bulk f32 LE — the exact, byte-deterministic default.
    #[default]
    F32,
    /// Round-to-nearest-even truncation to bfloat16 (2 bytes/elem, ~2x).
    Bf16,
    /// Per-section absmax-scaled int8 (1 byte/elem, ~4x).
    Int8,
}

impl DeltaCodec {
    pub fn parse(s: &str) -> Option<DeltaCodec> {
        match s {
            "f32" => Some(DeltaCodec::F32),
            "bf16" => Some(DeltaCodec::Bf16),
            "int8" => Some(DeltaCodec::Int8),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DeltaCodec::F32 => "f32",
            DeltaCodec::Bf16 => "bf16",
            DeltaCodec::Int8 => "int8",
        }
    }

    /// Whether decode(encode(x)) can differ from x.
    pub fn is_lossy(&self) -> bool {
        !matches!(self, DeltaCodec::F32)
    }
}

impl std::fmt::Display for DeltaCodec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which section exchange plane workers publish through and executors
/// read from ([`crate::transport`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportMode {
    /// Shared filesystem: the checkpoint's atomic rename IS the publish;
    /// executors map the DPC2 file. Byte-identical to the pre-transport
    /// behavior.
    #[default]
    Local,
    /// Framed TCP streams: each `delta:` section is pushed to its owning
    /// executor's endpoint (loopback rendezvous registry for now).
    Tcp,
}

impl TransportMode {
    pub fn parse(s: &str) -> Option<TransportMode> {
        match s {
            "local" => Some(TransportMode::Local),
            "tcp" => Some(TransportMode::Tcp),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TransportMode::Local => "local",
            TransportMode::Tcp => "tcp",
        }
    }
}

impl std::fmt::Display for TransportMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Section exchange plane settings ([`crate::transport`]): framing is
/// fixed (length-prefixed, fletcher64-verified); these knobs govern the
/// client's failure behavior over a poorly connected network.
#[derive(Debug, Clone, PartialEq)]
pub struct TransportConfig {
    pub mode: TransportMode,
    /// TCP connect timeout per attempt, ms.
    pub connect_timeout_ms: u64,
    /// Socket read/write timeout while awaiting an ack, ms.
    pub read_timeout_ms: u64,
    /// Re-send attempts per section after the first (a nacked or timed-out
    /// frame is retried with capped exponential backoff).
    pub retries: u32,
    /// First retry backoff, ms (doubles per attempt).
    pub backoff_ms: u64,
    /// Exponential backoff cap, ms.
    pub backoff_cap_ms: u64,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            mode: TransportMode::Local,
            connect_timeout_ms: 1000,
            read_timeout_ms: 2000,
            retries: 4,
            backoff_ms: 10,
            backoff_cap_ms: 250,
        }
    }
}

impl TransportConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str(self.mode.as_str())),
            (
                "connect_timeout_ms",
                Json::num(self.connect_timeout_ms as f64),
            ),
            ("read_timeout_ms", Json::num(self.read_timeout_ms as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("backoff_ms", Json::num(self.backoff_ms as f64)),
            ("backoff_cap_ms", Json::num(self.backoff_cap_ms as f64)),
        ])
    }

    pub fn from_json(v: Option<&Json>) -> Self {
        let d = TransportConfig::default();
        let v = match v {
            Some(v) => v,
            None => return d,
        };
        let get = |k: &str, dv: u64| {
            v.get(k)
                .and_then(|x| x.as_usize())
                .map(|x| x as u64)
                .unwrap_or(dv)
        };
        TransportConfig {
            mode: v
                .get("mode")
                .and_then(|x| x.as_str())
                .and_then(TransportMode::parse)
                .unwrap_or(d.mode),
            connect_timeout_ms: get("connect_timeout_ms", d.connect_timeout_ms).max(1),
            read_timeout_ms: get("read_timeout_ms", d.read_timeout_ms).max(1),
            retries: get("retries", d.retries as u64) as u32,
            backoff_ms: get("backoff_ms", d.backoff_ms),
            backoff_cap_ms: get("backoff_cap_ms", d.backoff_cap_ms).max(1),
        }
    }
}

/// Coordinator runtime settings (paper §3).
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Training workers in the primary pool (paper §3.4: may be fewer than
    /// paths; phases then take multiple rounds).
    pub workers: usize,
    /// Extra low-priority backup workers (paper §3.4).
    pub backup_workers: usize,
    /// Probability a worker is preempted mid-task (fault injection).
    pub preemption_prob: f64,
    /// Task lease duration before the queue reclaims it, in ms.
    pub lease_ms: u64,
    /// Simulated checkpoint-transfer delay (distant DC), in ms.
    pub transfer_delay_ms: u64,
    /// Outer-optimization executor shards (paper §3.3).
    pub outer_executors: usize,
    /// Threads for the per-phase path-assembly fan-out (1 = serial).
    pub assembly_threads: usize,
    /// Wire codec for shipped `delta:` sections.
    pub delta_codec: DeltaCodec,
    /// Staggered publication: split a path's modules into this many
    /// groups and publish each group's delta as soon as its slice of the
    /// inner steps finishes. 0 or 1 = publish everything at phase end
    /// (the classic serial exchange window).
    pub publish_groups: usize,
    /// Straggler grace window, ms: once a module has at least one
    /// contribution, an executor waits at most this long past the phase
    /// deadline for missing paths before declaring them late and applying
    /// the outer update without them (their deltas merge into the next
    /// phase). 0 = off: the outer update gates on every path.
    pub straggler_grace_ms: u64,
    /// Section exchange plane (local filesystem vs TCP rendezvous).
    pub transport: TransportConfig,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            workers: 4,
            backup_workers: 0,
            preemption_prob: 0.0,
            lease_ms: 30_000,
            transfer_delay_ms: 0,
            outer_executors: 2,
            assembly_threads: 4,
            delta_codec: DeltaCodec::F32,
            publish_groups: 0,
            straggler_grace_ms: 0,
            transport: TransportConfig::default(),
            seed: 7,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_paths() {
        assert_eq!(TopologySpec::grid(vec![4, 4]).paths(), 16);
        assert_eq!(TopologySpec::grid(vec![2, 4]).paths(), 8);
        assert_eq!(TopologySpec::diloco(8).paths(), 8);
        assert_eq!(TopologySpec::flat_moe(64).paths(), 64);
    }

    #[test]
    fn topology_json_roundtrip() {
        let t = TopologySpec {
            experts_per_level: vec![2, 4],
            stem: StemPlacement::PathSpecific,
            level_blocks: None,
            path_specific_blocks: vec![0, 3],
            replicas: 2,
        };
        let t2 = TopologySpec::from_json(&Json::parse(&t.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn lr_schedule_shape() {
        let d = DilocoConfig {
            warmup_steps: 10,
            total_steps: 100,
            peak_lr: 1.0,
            ..Default::default()
        };
        assert!(d.lr_at(1) < d.lr_at(10));
        assert!((d.lr_at(10) - 1.0).abs() < 1e-6);
        assert!(d.lr_at(50) < 1.0);
        assert!(d.lr_at(100) <= d.lr_at(50));
        assert!(d.lr_at(100) >= 0.099); // floors at 10% of peak
        // never negative, never above peak
        for s in 1..=120 {
            let lr = d.lr_at(s);
            assert!((0.0..=1.0 + 1e-6).contains(&lr), "step {s} lr {lr}");
        }
    }

    #[test]
    fn serve_config_json_roundtrip() {
        let s = ServeConfig {
            queue_cap: 128,
            max_batch: 8,
            max_wait_ms: 5,
            reject_on_full: true,
            admission_timeout_ms: 250,
            workers: 7,
            idle_ms: 9,
            shed_deadline_ms: 3,
            breaker: BreakerConfig {
                enabled: false,
                window: 16,
                min_samples: 4,
                error_rate: 0.25,
                latency_ms: 40.0,
                cooldown_ms: 500,
                probes: 3,
            },
            supervisor: SupervisorConfig {
                backoff_ms: 20,
                backoff_max_ms: 640,
                max_consecutive_panics: 5,
            },
        };
        let s2 = ServeConfig::from_json(&Json::parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(s, s2);
        // missing fields fall back to defaults, including the nested
        // breaker/supervisor objects
        let d = ServeConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(d, ServeConfig::default());
        let partial =
            ServeConfig::from_json(&Json::parse(r#"{"breaker":{"window":64}}"#).unwrap()).unwrap();
        assert_eq!(partial.breaker.window, 64);
        assert_eq!(partial.breaker.probes, BreakerConfig::default().probes);
    }

    #[test]
    fn transport_config_json_roundtrip() {
        let t = TransportConfig {
            mode: TransportMode::Tcp,
            connect_timeout_ms: 123,
            read_timeout_ms: 456,
            retries: 7,
            backoff_ms: 3,
            backoff_cap_ms: 99,
        };
        let t2 = TransportConfig::from_json(Some(&Json::parse(&t.to_json().to_string()).unwrap()));
        assert_eq!(t, t2);
        assert_eq!(TransportConfig::from_json(None), TransportConfig::default());
        let partial = TransportConfig::from_json(Some(&Json::parse(r#"{"mode":"tcp"}"#).unwrap()));
        assert_eq!(partial.mode, TransportMode::Tcp);
        assert_eq!(partial.retries, TransportConfig::default().retries);
        assert_eq!(TransportMode::parse("carrier-pigeon"), None);
    }

    #[test]
    fn delta_codec_parse_roundtrip() {
        for c in [DeltaCodec::F32, DeltaCodec::Bf16, DeltaCodec::Int8] {
            assert_eq!(DeltaCodec::parse(c.as_str()), Some(c));
        }
        assert_eq!(DeltaCodec::parse("fp8"), None);
        assert_eq!(DeltaCodec::default(), DeltaCodec::F32);
        assert!(!DeltaCodec::F32.is_lossy());
        assert!(DeltaCodec::Bf16.is_lossy());
        assert!(DeltaCodec::Int8.is_lossy());
    }

    #[test]
    fn model_config_from_manifest() {
        let j = Json::parse(
            r#"{"preset":"t","config":{"vocab":64,"d_model":16,"n_layers":2,
                "n_heads":2,"d_ff":32,"seq_train":32,"seq_eval":48,"batch":2,
                "prefix":8,"d_head":8}}"#,
        )
        .unwrap();
        let c = ModelConfig::from_manifest_json(&j).unwrap();
        assert_eq!(c.d_model, 16);
        assert_eq!(c.loss_tokens_per_batch(), 2 * (32 - 8));
    }
}
