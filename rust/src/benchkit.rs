//! Bench harness (criterion is not vendored): timed runs with warmup,
//! mean/std/percentiles, throughput, and a comparison table. All
//! `rust/benches/*.rs` targets (harness = false) build on this.

use std::time::{Duration, Instant};

use crate::util::stats::{percentile, OnlineStats};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub runs: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// Optional items/second (set via `Bencher::throughput`).
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) {
        let tp = self
            .throughput
            .map(|t| format!("  {:>10.1} items/s", t))
            .unwrap_or_default();
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}{}",
            self.name,
            fmt_dur(self.mean_s),
            fmt_dur(self.p50_s),
            fmt_dur(self.p95_s),
            format!("±{}", fmt_dur(self.std_s)),
            tp
        );
    }
}

pub fn header() {
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "mean", "p50", "p95", "std"
    );
    println!("{}", "-".repeat(90));
}

fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

pub struct Bencher {
    name: String,
    warmup: usize,
    min_runs: usize,
    max_runs: usize,
    max_total: Duration,
    items: Option<f64>,
}

impl Bencher {
    pub fn new(name: &str) -> Bencher {
        Bencher {
            name: name.to_string(),
            warmup: 2,
            min_runs: 5,
            max_runs: 50,
            max_total: Duration::from_secs(10),
            items: None,
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn runs(mut self, min: usize, max: usize) -> Self {
        self.min_runs = min;
        self.max_runs = max;
        self
    }

    pub fn budget(mut self, d: Duration) -> Self {
        self.max_total = d;
        self
    }

    /// Items processed per run (enables items/s in the report).
    pub fn throughput(mut self, items: f64) -> Self {
        self.items = Some(items);
        self
    }

    pub fn run<F: FnMut()>(self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let mut stats = OnlineStats::new();
        let start = Instant::now();
        while samples.len() < self.min_runs
            || (samples.len() < self.max_runs && start.elapsed() < self.max_total)
        {
            let t0 = Instant::now();
            f();
            let dt = t0.elapsed().as_secs_f64();
            samples.push(dt);
            stats.push(dt);
        }
        let mean = stats.mean();
        let result = BenchResult {
            name: self.name,
            runs: samples.len(),
            mean_s: mean,
            std_s: stats.std(),
            p50_s: percentile(&samples, 50.0),
            p95_s: percentile(&samples, 95.0),
            min_s: stats.min(),
            throughput: self.items.map(|n| n / mean),
        };
        result.report();
        result
    }
}

/// Print a ratio comparison ("who wins, by what factor") between results.
pub fn compare(baseline: &BenchResult, candidate: &BenchResult) {
    let speedup = baseline.mean_s / candidate.mean_s;
    println!(
        "  -> {} is {:.2}x {} than {}",
        candidate.name,
        if speedup >= 1.0 { speedup } else { 1.0 / speedup },
        if speedup >= 1.0 { "faster" } else { "slower" },
        baseline.name
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let r = Bencher::new("sleep-2ms")
            .warmup(0)
            .runs(3, 5)
            .budget(Duration::from_millis(300))
            .run(|| std::thread::sleep(Duration::from_millis(2)));
        assert!(r.mean_s >= 0.0019, "mean {}", r.mean_s);
        assert!(r.runs >= 3);
    }

    #[test]
    fn throughput_computed() {
        let r = Bencher::new("tp")
            .warmup(0)
            .runs(3, 3)
            .throughput(100.0)
            .run(|| std::thread::sleep(Duration::from_millis(1)));
        let tp = r.throughput.unwrap();
        assert!(tp > 10_000.0 && tp < 150_000.0, "tp {tp}");
    }
}
