//! Property-testing harness (proptest is not vendored).
//!
//! `forall` runs a property over N generated cases from a seeded RNG; on
//! failure it reports the case index and per-case seed so the exact case
//! reproduces with `forall_case`. Used by `rust/tests/prop_invariants.rs`
//! for the coordinator/topology invariants the brief calls out.

use crate::util::rng::Rng;

/// Run `prop` over `cases` inputs drawn by `gen`. Panics with the failing
/// seed on the first violation.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {case_seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn forall_case<T: std::fmt::Debug>(
    seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    if let Err(msg) = prop(&input) {
        panic!("case (seed {seed}) failed: {msg}\n  input: {input:?}");
    }
}

/// Common generators.
pub mod gens {
    use crate::util::rng::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.gen_range(hi - lo + 1)
    }

    pub fn f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(0.0, scale)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            "addition commutes",
            1,
            50,
            |rng| (rng.gen_range(100) as i64, rng.gen_range(100) as i64),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        forall(
            "always fails at 3",
            0,
            10,
            |rng| rng.gen_range(5),
            |&x| if x == 3 { Err("hit 3".into()) } else { Ok(()) },
        );
    }
}
