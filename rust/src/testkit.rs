//! Property-testing harness (proptest is not vendored).
//!
//! `forall` runs a property over N generated cases from a seeded RNG; on
//! failure it reports the case index and per-case seed so the exact case
//! reproduces with `forall_case`. Used by `rust/tests/prop_invariants.rs`
//! for the coordinator/topology invariants the brief calls out.

use crate::util::rng::Rng;

/// Silence the default panic printout for INTENTIONAL panics (payload
/// prefixed `"chaos-inject"`) so chaos scenarios and supervisor tests —
/// which panic executors dozens of times on purpose — don't bury real
/// failures in backtrace noise. Every other panic still reaches the
/// previous hook. Idempotent; safe under parallel test threads.
pub fn install_quiet_panic_hook() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .unwrap_or("");
            if msg.starts_with("chaos-inject") {
                return;
            }
            prev(info);
        }));
    });
}

/// Run `prop` over `cases` inputs drawn by `gen`. Panics with the failing
/// seed on the first violation.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {case_seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn forall_case<T: std::fmt::Debug>(
    seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    if let Err(msg) = prop(&input) {
        panic!("case (seed {seed}) failed: {msg}\n  input: {input:?}");
    }
}

/// Common generators.
pub mod gens {
    use crate::util::rng::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.gen_range(hi - lo + 1)
    }

    pub fn f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(0.0, scale)).collect()
    }

    /// A shuffled at-least-once delivery order over `n` items: every item
    /// appears 1..=max_dups times, in random positions. Models redundant
    /// checkpoint publication (lease-expiry re-execution, DB replay) for
    /// the dedup properties.
    pub fn delivery_schedule(rng: &mut Rng, n: usize, max_dups: usize) -> Vec<usize> {
        let mut sched = Vec::new();
        for i in 0..n {
            let dups = 1 + rng.gen_range(max_dups);
            for _ in 0..dups {
                sched.push(i);
            }
        }
        rng.shuffle(&mut sched);
        sched
    }
}

/// Synthetic routing fixtures shared by the serve unit tests, the serve
/// integration tests, and `bench_serve`: a k-means router whose
/// centroids are the one-hot basis, so feature `e_p` deterministically
/// routes to path `p`.
pub mod routers {
    use crate::routing::kmeans::KMeans;
    use crate::routing::router::Router;

    pub fn one_hot_router(paths: usize) -> Router {
        let centroids = (0..paths)
            .map(|p| (0..paths).map(|j| if j == p { 1.0 } else { 0.0 }).collect())
            .collect();
        Router::KMeans(KMeans { centroids })
    }

    pub fn one_hot(paths: usize, p: usize) -> Vec<f32> {
        (0..paths).map(|j| if j == p { 1.0 } else { 0.0 }).collect()
    }
}

/// Synthetic path executors for serve tests (one definition, used by the
/// `serve::server` unit tests AND `rust/tests/integration_serve.rs`).
pub mod exec {
    use crate::serve::server::PathExecutor;
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    /// Records the (path, first token) of every REAL row it scores, so a
    /// test can prove which path a document actually EXECUTED on — the
    /// regression probe for the old batch-major routing bug. Optionally
    /// sleeps per batch to simulate compute.
    pub struct LoggingExec {
        pub path: usize,
        pub batch: usize,
        pub seq: usize,
        pub delay: Duration,
        pub log: Arc<Mutex<Vec<(usize, i32)>>>,
    }

    impl PathExecutor for LoggingExec {
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq(&self) -> usize {
            self.seq
        }
        fn forward(&mut self, toks: &[i32], rows: usize) -> anyhow::Result<Vec<(f64, usize)>> {
            assert_eq!(
                toks.len(),
                self.batch * self.seq,
                "unpadded batch reached executor"
            );
            assert!(rows >= 1 && rows <= self.batch);
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            let mut g = self.log.lock().unwrap();
            for b in 0..rows {
                g.push((self.path, toks[b * self.seq]));
            }
            Ok((0..rows).map(|_| (1.0, self.seq - 1)).collect())
        }
    }

    /// One LoggingExec per path, all feeding a shared log.
    #[allow(clippy::type_complexity)]
    pub fn logging_fleet(
        paths: usize,
        batch: usize,
        seq: usize,
        delay: Duration,
    ) -> (Vec<LoggingExec>, Arc<Mutex<Vec<(usize, i32)>>>) {
        let log = Arc::new(Mutex::new(Vec::new()));
        let execs = (0..paths)
            .map(|path| LoggingExec {
                path,
                batch,
                seq,
                delay,
                log: Arc::clone(&log),
            })
            .collect();
        (execs, log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            "addition commutes",
            1,
            50,
            |rng| (rng.gen_range(100) as i64, rng.gen_range(100) as i64),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        forall(
            "always fails at 3",
            0,
            10,
            |rng| rng.gen_range(5),
            |&x| if x == 3 { Err("hit 3".into()) } else { Ok(()) },
        );
    }
}
