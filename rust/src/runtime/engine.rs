//! PJRT runtime — loads the AOT artifacts and executes them.
//!
//! One [`Engine`] per model preset: it owns the PJRT CPU client, parses
//! each `*.hlo.txt` through `HloModuleProto::from_text_file` (HLO TEXT is
//! the interchange format — see python/compile/aot.py), compiles each
//! entrypoint once, and exposes typed wrappers. This is the ONLY module
//! that touches the `xla` crate; everything above deals in `Vec<f32>` /
//! `Vec<i32>`.
//!
//! Thread safety: the crate's wrapper types are raw-pointer newtypes and
//! not `Send`/`Sync`-annotated, but the underlying PJRT CPU client and
//! loaded executables are thread-safe and immutable after compilation
//! (executions are const on the C++ side and the CPU client multiplexes
//! its own thread pool). [`Engine`] is therefore marked `Send + Sync`
//! so the worker pool can share one compiled executable per entrypoint.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::config::ModelConfig;
use crate::params::manifest::Manifest;

pub struct Engine {
    pub manifest: Manifest,
    pub dir: PathBuf,
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

// SAFETY: see module docs — PJRT CPU client/executables are internally
// synchronized; the wrapper structs are only lacking the auto-trait
// annotations because they hold raw pointers.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

/// Entrypoints loaded eagerly by [`Engine::load`]. Others (e.g.
/// `grad_step` for the sync ablation) load on demand via
/// [`Engine::ensure_loaded`].
pub const CORE_ENTRYPOINTS: &[&str] = &[
    "init",
    "train_step",
    "token_logprobs_train",
    "token_logprobs_eval",
    "features",
];

impl Engine {
    /// Load + compile the core entrypoints of `artifacts/<preset>/`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut engine = Engine {
            manifest,
            dir: dir.to_path_buf(),
            client,
            exes: HashMap::new(),
        };
        for ep in CORE_ENTRYPOINTS {
            engine.ensure_loaded(ep)?;
        }
        // Optional fused-step artifact (§Perf): present when the manifest
        // was built with tau > 0; older artifacts fall back to train_step.
        if engine.model().tau > 0 {
            let _ = engine.ensure_loaded("train_steps");
        }
        Ok(engine)
    }

    pub fn model(&self) -> &ModelConfig {
        &self.manifest.model
    }

    pub fn has(&self, entrypoint: &str) -> bool {
        self.exes.contains_key(entrypoint)
    }

    /// Compile `entrypoint` if not already resident.
    pub fn ensure_loaded(&mut self, entrypoint: &str) -> Result<()> {
        if self.exes.contains_key(entrypoint) {
            return Ok(());
        }
        let path = self.dir.join(format!("{entrypoint}.hlo.txt"));
        if !path.exists() {
            bail!(
                "entrypoint {entrypoint:?} not in {} (run `make artifacts`)",
                self.dir.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {entrypoint}: {e:?}"))?;
        self.exes.insert(entrypoint.to_string(), exe);
        Ok(())
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.exes
            .get(name)
            .with_context(|| format!("entrypoint {name:?} not loaded"))
    }

    /// Run an entrypoint with positional literals; returns the flattened
    /// tuple elements.
    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(name)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    fn f32_vec(v: &[f32]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    fn tokens_literal(&self, tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
        if tokens.len() != batch * seq {
            bail!("token buffer {} != batch {batch} x seq {seq}", tokens.len());
        }
        let vocab = self.model().vocab as i32;
        if let Some(&bad) = tokens.iter().find(|&&t| t < 0 || t >= vocab) {
            bail!("token {bad} out of vocab range 0..{vocab} (silent NaN source)");
        }
        xla::Literal::vec1(tokens)
            .reshape(&[batch as i64, seq as i64])
            .map_err(|e| anyhow!("reshaping tokens: {e:?}"))
    }

    // ------------------------------------------------------- entrypoints

    /// Fresh parameter vector from a seed (GPT-2-style init in the HLO).
    pub fn init(&self, seed: u32) -> Result<Vec<f32>> {
        let out = self.run("init", &[xla::Literal::scalar(seed)])?;
        let theta = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        if theta.len() != self.manifest.total_params {
            bail!("init returned {} params, manifest says {}", theta.len(), self.manifest.total_params);
        }
        Ok(theta)
    }

    /// One inner AdamW step (paper Algorithm 1 lines 5-9).
    /// `step` is 1-based; `lr` comes from the cosine schedule in rust.
    pub fn train_step(
        &self,
        theta: &[f32],
        m: &[f32],
        v: &[f32],
        step: f32,
        lr: f32,
        tokens: &[i32],
    ) -> Result<TrainStepOut> {
        let mc = self.model();
        let args = [
            Self::f32_vec(theta),
            Self::f32_vec(m),
            Self::f32_vec(v),
            xla::Literal::scalar(step),
            xla::Literal::scalar(lr),
            self.tokens_literal(tokens, mc.batch, mc.seq_train)?,
        ];
        let out = self.run("train_step", &args)?;
        Ok(TrainStepOut {
            theta: out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            m: out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            v: out[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            loss: out[3].get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        })
    }

    /// `tau` fused inner steps in ONE dispatch (lax.scan inside the HLO;
    /// §Perf optimization — see EXPERIMENTS.md). `lrs.len()` must equal the
    /// artifact's tau; tokens is `[tau, batch, seq_train]` flattened.
    pub fn train_steps(
        &self,
        theta: &[f32],
        m: &[f32],
        v: &[f32],
        start_step: f32,
        lrs: &[f32],
        tokens: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mc = self.model();
        let tau = mc.tau;
        if lrs.len() != tau {
            bail!("lrs length {} != artifact tau {tau}", lrs.len());
        }
        if tokens.len() != tau * mc.batch * mc.seq_train {
            bail!("token buffer wrong size for fused train_steps");
        }
        let vocab = mc.vocab as i32;
        if let Some(&bad) = tokens.iter().find(|&&t| t < 0 || t >= vocab) {
            bail!("token {bad} out of vocab range 0..{vocab}");
        }
        let toks = xla::Literal::vec1(tokens)
            .reshape(&[tau as i64, mc.batch as i64, mc.seq_train as i64])
            .map_err(|e| anyhow!("reshaping scan tokens: {e:?}"))?;
        let args = [
            Self::f32_vec(theta),
            Self::f32_vec(m),
            Self::f32_vec(v),
            xla::Literal::scalar(start_step),
            Self::f32_vec(lrs),
            toks,
        ];
        let out = self.run("train_steps", &args)?;
        Ok((
            out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            out[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            out[3].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    /// Raw gradient + loss (fully-synchronous ablation, paper §4.5).
    pub fn grad_step(&self, theta: &[f32], tokens: &[i32]) -> Result<(Vec<f32>, f32)> {
        let mc = self.model();
        let args = [
            Self::f32_vec(theta),
            self.tokens_literal(tokens, mc.batch, mc.seq_train)?,
        ];
        let out = self.run("grad_step", &args)?;
        Ok((
            out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            out[1].get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    /// AdamW update from a pre-aggregated gradient (sync ablation).
    pub fn adam_update(
        &self,
        theta: &[f32],
        m: &[f32],
        v: &[f32],
        g: &[f32],
        step: f32,
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let args = [
            Self::f32_vec(theta),
            Self::f32_vec(m),
            Self::f32_vec(v),
            Self::f32_vec(g),
            xla::Literal::scalar(step),
            xla::Literal::scalar(lr),
        ];
        let out = self.run("adam_update", &args)?;
        Ok((
            out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            out[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            out[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    /// Per-token logprobs `[batch, seq-1]` (flattened): logp of token j+1
    /// given tokens <= j. `seq` selects the train- or eval-length variant.
    pub fn token_logprobs(&self, theta: &[f32], tokens: &[i32], seq: usize) -> Result<Vec<f32>> {
        let mc = self.model();
        let name = if seq == mc.seq_train {
            "token_logprobs_train"
        } else if seq == mc.seq_eval {
            "token_logprobs_eval"
        } else {
            bail!("no token_logprobs artifact for seq {seq}");
        };
        let args = [
            Self::f32_vec(theta),
            self.tokens_literal(tokens, mc.batch, seq)?,
        ];
        let out = self.run(name, &args)?;
        let lp = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        if lp.len() != mc.batch * (seq - 1) {
            bail!("logprobs size {} != batch x (seq-1)", lp.len());
        }
        Ok(lp)
    }

    /// Router features `z` `[batch, d_model]` (flattened) from prefix
    /// tokens `[batch, prefix]`.
    pub fn features(&self, theta: &[f32], prefix_tokens: &[i32]) -> Result<Vec<f32>> {
        let mc = self.model();
        let args = [
            Self::f32_vec(theta),
            self.tokens_literal(prefix_tokens, mc.batch, mc.prefix)?,
        ];
        let out = self.run("features", &args)?;
        let z = out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        if z.len() != mc.batch * mc.d_model {
            bail!("features size {} != batch x d_model", z.len());
        }
        Ok(z)
    }
}

#[derive(Debug, Clone)]
pub struct TrainStepOut {
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub loss: f32,
}

/// Resolve `artifacts/<preset>` relative to the crate root, allowing
/// override via `DIPACO_ARTIFACTS`.
pub fn artifact_dir(preset: &str) -> PathBuf {
    let root = std::env::var("DIPACO_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    Path::new(&root).join(preset)
}
