//! Router feature extraction (paper §7.2.1): "the feature for the router
//! is always the average of the hidden state from the last transformer
//! block from the initial LM over the first 32 tokens of a document."
//!
//! Implemented via the `features` HLO entrypoint of the base (pretrained)
//! model; documents are batched through PJRT, the last partial batch
//! padded and its pad rows dropped.

use anyhow::Result;

use crate::data::corpus::Corpus;
use crate::runtime::engine::Engine;

/// Extract z for each doc id. Returns rows aligned with `docs`.
pub fn extract_features(
    engine: &Engine,
    base_theta: &[f32],
    docs: &[usize],
    corpus: &Corpus,
) -> Result<Vec<Vec<f32>>> {
    let mc = engine.model();
    let mut out = Vec::with_capacity(docs.len());
    for chunk in docs.chunks(mc.batch) {
        let mut toks = Vec::with_capacity(mc.batch * mc.prefix);
        for &d in chunk {
            let mut p = corpus.prefix(d, mc.prefix).to_vec();
            p.resize(mc.prefix, 0);
            toks.extend_from_slice(&p);
        }
        for _ in chunk.len()..mc.batch {
            toks.extend(std::iter::repeat(0).take(mc.prefix));
        }
        let z = engine.features(base_theta, &toks)?;
        for b in 0..chunk.len() {
            out.push(z[b * mc.d_model..(b + 1) * mc.d_model].to_vec());
        }
    }
    Ok(out)
}

/// Featurize an arbitrary 32-token window (for eval-time chunked routing,
/// §2.4.3/§7.2.2): the window is the LAST `prefix` tokens before position
/// `end` of the document's token stream.
pub fn window_features(
    engine: &Engine,
    base_theta: &[f32],
    windows: &[Vec<i32>],
) -> Result<Vec<Vec<f32>>> {
    let mc = engine.model();
    let mut out = Vec::with_capacity(windows.len());
    for chunk in windows.chunks(mc.batch) {
        let mut toks = Vec::with_capacity(mc.batch * mc.prefix);
        for w in chunk {
            let mut p = w.clone();
            p.resize(mc.prefix, 0);
            toks.extend_from_slice(&p[..mc.prefix]);
        }
        for _ in chunk.len()..mc.batch {
            toks.extend(std::iter::repeat(0).take(mc.prefix));
        }
        let z = engine.features(base_theta, &toks)?;
        for b in 0..chunk.len() {
            out.push(z[b * mc.d_model..(b + 1) * mc.d_model].to_vec());
        }
    }
    Ok(out)
}
