//! Coarse offline routing (paper §2.4, §7.2): generative sharding
//! (k-means / product k-means on prefix features), discriminative
//! re-sharding (the EM-style alternation of §2.4.2), shard overlap
//! (§2.4.4), and the eval-time chunk router (§2.4.3/§7.2.2).

use anyhow::Result;
use std::collections::HashMap;

use crate::config::RoutingConfig;
use crate::data::corpus::Corpus;
use crate::data::dataset::Sharding;
use crate::routing::kmeans::{KMeans, ProductKMeans};
use crate::routing::logistic::{Logistic, TrainOpts};
use crate::runtime::engine::Engine;
use crate::util::rng::Rng;

/// A trained router: maps prefix features to path ids.
#[derive(Debug, Clone)]
pub enum Router {
    KMeans(KMeans),
    ProductKMeans(ProductKMeans),
    Discriminative(Logistic),
}

impl Router {
    pub fn assign(&self, z: &[f32]) -> usize {
        match self {
            Router::KMeans(m) => m.assign(z),
            Router::ProductKMeans(m) => m.assign(z),
            Router::Discriminative(m) => m.predict(z),
        }
    }

    pub fn assign_top_n(&self, z: &[f32], n: usize) -> Vec<usize> {
        match self {
            Router::KMeans(m) => m.assign_top_n(z, n),
            Router::ProductKMeans(m) => m.assign_top_n(z, n),
            Router::Discriminative(m) => m.predict_top_n(z, n),
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Router::KMeans(_) => "kmeans",
            Router::ProductKMeans(_) => "product_kmeans",
            Router::Discriminative(_) => "discriminative",
        }
    }

    /// Raw per-path affinity scores, higher = better: negated squared
    /// distance for the generative routers, logits for the discriminative
    /// one. `scores(z)[assign(z)]` is the maximum (first index wins ties,
    /// matching `assign`).
    pub fn scores(&self, z: &[f32]) -> Vec<f64> {
        match self {
            Router::KMeans(m) => m.scores(z),
            Router::ProductKMeans(m) => m.scores(z),
            Router::Discriminative(m) => m.logits(z),
        }
    }

    /// Every path ranked best-first with its score. `ranked(z)[0].0 ==
    /// assign(z)`; the tail is the degraded-mode fallback order (the
    /// "runner-up" path is `ranked(z)[1].0`). The sort is stable, so ties
    /// break toward lower path ids — deterministic across runs.
    pub fn ranked(&self, z: &[f32]) -> Vec<(usize, f64)> {
        let mut v: Vec<(usize, f64)> = self.scores(z).into_iter().enumerate().collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

/// Fit the generative router on train-split features (paper §2.4.1).
/// `grid` carries (k1, k2) for product k-means; plain k-means uses k1*k2.
pub fn fit_generative(
    features: &[Vec<f32>],
    k: usize,
    grid: Option<(usize, usize)>,
    cfg: &RoutingConfig,
    rng: &mut Rng,
) -> Router {
    match grid {
        Some((k1, k2)) if cfg.product_kmeans => {
            assert_eq!(k1 * k2, k);
            Router::ProductKMeans(ProductKMeans::fit(features, k1, k2, cfg.kmeans_iters, rng))
        }
        _ => Router::KMeans(KMeans::fit(features, k, cfg.kmeans_iters, rng)),
    }
}

/// Shard documents by a router with optional top-n overlap (paper §2.4.4).
/// `features[i]` corresponds to `docs[i]`.
pub fn shard_by_router(
    router: &Router,
    docs: &[usize],
    features: &[Vec<f32>],
    k: usize,
    overlap: usize,
    holdout_frac: f64,
    seed: u64,
) -> Sharding {
    let assignments: Vec<(usize, Vec<usize>)> = docs
        .iter()
        .zip(features)
        .map(|(&d, z)| (d, router.assign_top_n(z, overlap.max(1))))
        .collect();
    let mut sharding = Sharding::from_assignments(k, &assignments, holdout_frac, seed);
    // Guard: a path with an empty shard cannot train. Give any empty shard
    // the documents of the largest shard (parameter duplication is benign;
    // the paper's bias calibration exists to avoid this situation).
    let largest = (0..k)
        .max_by_key(|&i| sharding.shards[i].len())
        .unwrap_or(0);
    let donor = sharding.shards[largest].clone();
    for s in sharding.shards.iter_mut() {
        if s.docs.is_empty() {
            s.docs = donor.docs.clone();
            s.holdout = donor.holdout.clone();
        }
    }
    sharding
}

/// Per-document path scores on the router split: summed logprob of each
/// document under each path (paper §7.2.1's S_ijp summed over j).
/// Returns `scores[doc_idx][path]`.
pub fn score_router_docs(
    engine: &Engine,
    thetas: &HashMap<usize, Vec<f32>>,
    docs: &[usize],
    corpus: &Corpus,
) -> Result<Vec<Vec<f64>>> {
    let mc = engine.model();
    let seq = mc.seq_train;
    let lp = crate::eval::all_path_logprobs(engine, thetas, docs, corpus, seq)?;
    let paths: usize = thetas.len();
    let mut out = vec![vec![0.0f64; paths]; docs.len()];
    for (p, rows) in &lp {
        for (i, row) in rows.iter().enumerate() {
            // sum over targets past the routing prefix
            let s: f64 = (mc.prefix..seq).map(|t| row[t - 1] as f64).sum();
            out[i][*p] = s;
        }
    }
    Ok(out)
}

/// One discriminative phase (paper §2.4.2 / §7.2.1):
/// 1. score router-split docs under every path -> argmax labels,
/// 2. fit a K-class logistic regressor features -> label,
/// 3. calibrate biases toward the target document distribution.
pub fn fit_discriminative(
    features: &[Vec<f32>],
    scores: &[Vec<f64>],
    k: usize,
    cfg: &RoutingConfig,
) -> Router {
    let labels: Vec<usize> = scores
        .iter()
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect();
    let mut model = Logistic::fit(
        features,
        &labels,
        k,
        &TrainOpts {
            epochs: cfg.logistic_epochs,
            lr: cfg.logistic_lr,
            ..Default::default()
        },
    );
    if cfg.calibrate_bias {
        // Target: the label distribution itself (smoothed), so no path is
        // starved relative to what the scores say it deserves.
        let mut target = vec![1.0f64; k];
        for &l in &labels {
            target[l] += 1.0;
        }
        model.calibrate_bias(features, &target, 15);
    }
    Router::Discriminative(model)
}

/// Routing diagnostics: fraction of doc pairs from the same ground-truth
/// domain that land in the same shard (purity proxy; diagnostics only).
pub fn domain_alignment(corpus: &Corpus, docs: &[usize], assign: &[usize]) -> f64 {
    let mut by_domain: HashMap<usize, Vec<usize>> = HashMap::new();
    for (i, &d) in docs.iter().enumerate() {
        by_domain
            .entry(corpus.docs[d].domain)
            .or_default()
            .push(assign[i]);
    }
    let mut agree = 0usize;
    let mut total = 0usize;
    for (_, shards) in by_domain {
        let mut counts: HashMap<usize, usize> = HashMap::new();
        for s in &shards {
            *counts.entry(*s).or_default() += 1;
        }
        let max = counts.values().max().copied().unwrap_or(0);
        agree += max;
        total += shards.len();
    }
    agree as f64 / total.max(1) as f64
}

/// Eval-time chunk router (paper §2.4.3, §7.2.2): predicts the best path
/// for chunk i+1 from the features of (the last 32 tokens of) chunk i.
///
/// Substitution note (DESIGN.md): the paper finetunes a transformer
/// transducer for this; we train a logistic head on the same features the
/// document router uses, with labels = argmax path score on the *next*
/// window, which preserves the mechanism (cheap scoring-mode router
/// invoked between chunks) at this model scale.
pub struct ChunkRouter {
    pub model: Logistic,
}

impl ChunkRouter {
    /// Train from router-split docs. `w` is the label window size L
    /// (paper found L = chunk size works best).
    pub fn train(
        engine: &Engine,
        base_theta: &[f32],
        thetas: &HashMap<usize, Vec<f32>>,
        docs: &[usize],
        corpus: &Corpus,
        w: usize,
        cfg: &RoutingConfig,
    ) -> Result<ChunkRouter> {
        let mc = engine.model();
        let seq = mc.seq_eval;
        let k = thetas.len();
        let lp = crate::eval::all_path_logprobs(engine, thetas, docs, corpus, seq)?;
        let mut feats: Vec<Vec<f32>> = Vec::new();
        let mut labels: Vec<usize> = Vec::new();
        let mut windows: Vec<Vec<i32>> = Vec::new();
        let mut pending: Vec<usize> = Vec::new(); // label per window
        for (i, &d) in docs.iter().enumerate() {
            let toks = corpus.sequence(d, seq);
            // chunk boundaries at prefix, prefix+w, ...
            let mut t = mc.prefix;
            while t + 1 < seq {
                let end = (t + w).min(seq);
                // label: best path on window [t, end)
                let best = (0..k)
                    .max_by(|&a, &b| {
                        let sa: f64 = (t..end).map(|ti| lp[&a][i][ti - 1] as f64).sum();
                        let sb: f64 = (t..end).map(|ti| lp[&b][i][ti - 1] as f64).sum();
                        sa.partial_cmp(&sb).unwrap()
                    })
                    .unwrap();
                // feature: last `prefix` tokens before t
                let lo = t.saturating_sub(mc.prefix);
                windows.push(toks[lo..t].to_vec());
                pending.push(best);
                t = end;
            }
        }
        let zs = crate::routing::features::window_features(engine, base_theta, &windows)?;
        feats.extend(zs);
        labels.extend(pending);
        let model = Logistic::fit(
            &feats,
            &labels,
            k,
            &TrainOpts {
                epochs: cfg.logistic_epochs,
                lr: cfg.logistic_lr,
                ..Default::default()
            },
        );
        Ok(ChunkRouter { model })
    }

    /// Select paths per chunk for evaluation docs. Returns
    /// `choice[doc][chunk]`.
    pub fn route_docs(
        &self,
        engine: &Engine,
        base_theta: &[f32],
        docs: &[usize],
        corpus: &Corpus,
        w: usize,
    ) -> Result<Vec<Vec<usize>>> {
        let mc = engine.model();
        let seq = mc.seq_eval;
        let mut windows: Vec<Vec<i32>> = Vec::new();
        let mut spans: Vec<usize> = Vec::new(); // chunks per doc
        for &d in docs {
            let toks = corpus.sequence(d, seq);
            let mut t = mc.prefix;
            let mut n = 0;
            while t < seq {
                let lo = t.saturating_sub(mc.prefix);
                windows.push(toks[lo..t].to_vec());
                n += 1;
                t = (t + w).min(seq);
                if t == seq {
                    break;
                }
            }
            spans.push(n);
        }
        let zs = crate::routing::features::window_features(engine, base_theta, &windows)?;
        let mut out = Vec::with_capacity(docs.len());
        let mut cursor = 0;
        for n in spans {
            let choices = zs[cursor..cursor + n]
                .iter()
                .map(|z| self.model.predict(z))
                .collect();
            cursor += n;
            out.push(choices);
        }
        Ok(out)
    }
}

/// Convenience: assignment map doc -> path from a router + features.
pub fn assignments_of(
    router: &Router,
    docs: &[usize],
    features: &[Vec<f32>],
) -> HashMap<usize, usize> {
    docs.iter()
        .zip(features)
        .map(|(&d, z)| (d, router.assign(z)))
        .collect()
}

/// Route validation docs given a router (features must be extracted with
/// the same base model used at fit time).
pub fn route_docs(
    engine: &Engine,
    base_theta: &[f32],
    router: &Router,
    docs: &[usize],
    corpus: &Corpus,
) -> Result<HashMap<usize, usize>> {
    let zs = crate::routing::features::extract_features(engine, base_theta, docs, corpus)?;
    Ok(assignments_of(router, docs, &zs))
}

/// Full sharding for training: route train docs with overlap (paper: the
/// 16x16 run uses top-2 at train time, never at eval).
pub fn shard_for_training(
    engine: &Engine,
    base_theta: &[f32],
    router: &Router,
    corpus: &Corpus,
    k: usize,
    cfg: &RoutingConfig,
    holdout_frac: f64,
    seed: u64,
) -> Result<Sharding> {
    let zs =
        crate::routing::features::extract_features(engine, base_theta, &corpus.train, corpus)?;
    Ok(shard_by_router(
        router,
        &corpus.train,
        &zs,
        k,
        cfg.train_overlap,
        holdout_frac,
        seed,
    ))
}

/// Sanity metric: accuracy of a discriminative router against argmax
/// labels on held-out scored docs.
pub fn router_label_accuracy(router: &Router, features: &[Vec<f32>], scores: &[Vec<f64>]) -> f64 {
    let correct = features
        .iter()
        .zip(scores)
        .filter(|(z, row)| {
            let label = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            router.assign(z) == label
        })
        .count();
    correct as f64 / features.len().max(1) as f64
}

/// Build a path->theta map with contiguous path ids checked.
pub fn thetas_map(thetas: Vec<Vec<f32>>) -> HashMap<usize, Vec<f32>> {
    thetas.into_iter().enumerate().collect()
}

#[allow(dead_code)]
fn _assert_send() {
    fn is_send<T: Send>() {}
    is_send::<Router>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusConfig;

    fn fake_features(n: usize, k: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut zs = Vec::new();
        let mut doms = Vec::new();
        for i in 0..n {
            let dom = i % k;
            let z: Vec<f32> = (0..8)
                .map(|j| if j == dom { 5.0 } else { 0.0 } + rng.normal_f32(0.0, 0.3))
                .collect();
            zs.push(z);
            doms.push(dom);
        }
        (zs, doms)
    }

    #[test]
    fn generative_sharding_respects_overlap() {
        let (zs, _) = fake_features(120, 4, 1);
        let mut rng = Rng::new(2);
        let router = fit_generative(&zs, 4, None, &RoutingConfig::default(), &mut rng);
        let docs: Vec<usize> = (0..120).collect();
        let s1 = shard_by_router(&router, &docs, &zs, 4, 1, 0.0, 3);
        let s2 = shard_by_router(&router, &docs, &zs, 4, 2, 0.0, 3);
        assert_eq!(s1.total_docs(), 120);
        assert_eq!(s2.total_docs(), 240); // top-2 duplicates every doc
    }

    #[test]
    fn discriminative_learns_argmax_labels() {
        let (zs, doms) = fake_features(200, 4, 4);
        // scores: the "right" path scores higher
        let scores: Vec<Vec<f64>> = doms
            .iter()
            .map(|&d| (0..4).map(|p| if p == d { -10.0 } else { -20.0 }).collect())
            .collect();
        let router = fit_discriminative(&zs, &scores, 4, &RoutingConfig::default());
        assert!(router_label_accuracy(&router, &zs, &scores) > 0.95);
    }

    #[test]
    fn empty_shard_guard() {
        let (zs, _) = fake_features(50, 2, 5);
        let mut rng = Rng::new(6);
        // force k=8 shards over 2 real clusters — some will be empty-ish
        let router = fit_generative(&zs, 8, None, &RoutingConfig::default(), &mut rng);
        let docs: Vec<usize> = (0..50).collect();
        let s = shard_by_router(&router, &docs, &zs, 8, 1, 0.1, 7);
        assert!(s.shards.iter().all(|sh| !sh.docs.is_empty()));
    }

    #[test]
    fn ranked_agrees_with_assign_and_top_n() {
        let (zs, doms) = fake_features(80, 4, 11);
        let mut rng = Rng::new(12);
        let scores: Vec<Vec<f64>> = doms
            .iter()
            .map(|&d| (0..4).map(|p| if p == d { -10.0 } else { -20.0 }).collect())
            .collect();
        let routers = vec![
            fit_generative(&zs, 4, None, &RoutingConfig::default(), &mut rng),
            fit_generative(
                &zs,
                4,
                Some((2, 2)),
                &RoutingConfig {
                    product_kmeans: true,
                    ..Default::default()
                },
                &mut rng,
            ),
            fit_discriminative(&zs, &scores, 4, &RoutingConfig::default()),
        ];
        for router in &routers {
            for z in zs.iter().take(25) {
                let ranked = router.ranked(z);
                assert_eq!(ranked.len(), 4, "{}", router.kind());
                // best-first, consistent with assign and assign_top_n
                assert_eq!(ranked[0].0, router.assign(z), "{}", router.kind());
                let order: Vec<usize> = ranked.iter().map(|(p, _)| *p).collect();
                assert_eq!(
                    &order[..2],
                    router.assign_top_n(z, 2).as_slice(),
                    "{}",
                    router.kind()
                );
                assert!(
                    ranked.windows(2).all(|w| w[0].1 >= w[1].1),
                    "{} scores not descending: {ranked:?}",
                    router.kind()
                );
                // every path appears exactly once
                let mut seen = order.clone();
                seen.sort_unstable();
                assert_eq!(seen, vec![0, 1, 2, 3]);
            }
        }
    }

    #[test]
    fn domain_alignment_metric() {
        let corpus = Corpus::synthetic(&CorpusConfig {
            n_domains: 2,
            n_docs: 40,
            doc_len: (60, 80),
            skew: 0.0,
            seed: 8,
        });
        let docs: Vec<usize> = (0..40).collect();
        // perfect assignment: shard == domain
        let perfect: Vec<usize> = docs.iter().map(|&d| corpus.docs[d].domain).collect();
        assert!((domain_alignment(&corpus, &docs, &perfect) - 1.0).abs() < 1e-9);
        // constant assignment: alignment is 1.0 trivially per-domain too
        let constant: Vec<usize> = vec![0; 40];
        assert!((domain_alignment(&corpus, &docs, &constant) - 1.0).abs() < 1e-9);
        // random-ish split halves agreement
        let alternating: Vec<usize> = (0..40).map(|i| i % 2).collect();
        assert!(domain_alignment(&corpus, &docs, &alternating) < 0.8);
    }
}

// ---------------------------------------------------------------------------
// Router persistence (drivers cache trained runs under results/)
// ---------------------------------------------------------------------------

impl Router {
    /// Serialize into a checkpoint file (section names encode the kind).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        use crate::params::checkpoint::Checkpoint;
        let mut ck = Checkpoint::new();
        match self {
            Router::KMeans(m) => {
                for (i, c) in m.centroids.iter().enumerate() {
                    ck = ck.with(&format!("kmeans.c{i}"), c.clone());
                }
            }
            Router::ProductKMeans(m) => {
                for (i, c) in m.left.centroids.iter().enumerate() {
                    ck = ck.with(&format!("pkm.left.c{i}"), c.clone());
                }
                for (i, c) in m.right.centroids.iter().enumerate() {
                    ck = ck.with(&format!("pkm.right.c{i}"), c.clone());
                }
            }
            Router::Discriminative(m) => {
                for (c, w) in m.w.iter().enumerate() {
                    ck = ck.with(&format!("disc.w{c}"), w.clone());
                }
                ck = ck.with("disc.b", m.b.clone());
            }
        }
        ck.save(path).map_err(|e| anyhow::anyhow!("{e:#}"))
    }

    pub fn load(path: &std::path::Path) -> Result<Router> {
        use crate::params::checkpoint::Checkpoint;
        use crate::routing::kmeans::{KMeans, ProductKMeans};
        let ck = Checkpoint::load(path)?;
        let collect = |prefix: &str| -> Vec<Vec<f32>> {
            let mut out = Vec::new();
            for i in 0.. {
                match ck.get(&format!("{prefix}{i}")) {
                    Some(c) => out.push(c.to_vec()),
                    None => break,
                }
            }
            out
        };
        if !collect("kmeans.c").is_empty() {
            return Ok(Router::KMeans(KMeans { centroids: collect("kmeans.c") }));
        }
        if !collect("pkm.left.c").is_empty() {
            let left = KMeans { centroids: collect("pkm.left.c") };
            let right = KMeans { centroids: collect("pkm.right.c") };
            let split = left.centroids[0].len();
            return Ok(Router::ProductKMeans(ProductKMeans::from_parts(left, right, split)));
        }
        let w = collect("disc.w");
        if !w.is_empty() {
            let b = ck.get("disc.b").map(|b| b.to_vec()).unwrap_or_default();
            let k = w.len();
            let d = w[0].len();
            return Ok(Router::Discriminative(crate::routing::logistic::Logistic { w, b, k, d }));
        }
        anyhow::bail!("{}: unrecognized router checkpoint", path.display())
    }
}

#[cfg(test)]
mod persist_tests {
    use super::*;

    #[test]
    fn router_save_load_roundtrip() {
        let tmp = std::env::temp_dir().join(format!("dipaco-router-{}.dpc", std::process::id()));
        let km = crate::routing::kmeans::KMeans {
            centroids: vec![vec![1.0, 2.0], vec![-1.0, 0.5], vec![3.0, 3.0]],
        };
        let r = Router::KMeans(km);
        r.save(&tmp).unwrap();
        let back = Router::load(&tmp).unwrap();
        assert_eq!(back.kind(), "kmeans");
        for z in [[1.1f32, 2.0], [-0.9, 0.4], [2.9, 3.1]] {
            assert_eq!(r.assign(&z), back.assign(&z));
        }
        // discriminative
        let (zs, labels): (Vec<Vec<f32>>, Vec<usize>) = (0..40)
            .map(|i| {
                let c = i % 2;
                (vec![c as f32 * 4.0 + (i % 5) as f32 * 0.01, 1.0], c)
            })
            .unzip();
        let lg = crate::routing::logistic::Logistic::fit(
            &zs,
            &labels,
            2,
            &crate::routing::logistic::TrainOpts::default(),
        );
        let r = Router::Discriminative(lg);
        r.save(&tmp).unwrap();
        let back = Router::load(&tmp).unwrap();
        assert_eq!(back.kind(), "discriminative");
        for z in &zs {
            assert_eq!(r.assign(z), back.assign(z));
        }
    }
}
