//! k-means and Product k-means — the generative routers (paper §2.4.1,
//! §7.3).
//!
//! Features are the LM's prefix embeddings z (extracted via the `features`
//! HLO entrypoint); the sequence with prefix z is assigned to shard
//! `argmin_i ||z - c_i||^2` (paper Eq. 1). Product k-means splits the
//! feature vector into two halves clustered independently; the pair of
//! assignments indexes `k1 x k2` shards, matching DiPaCo's two-level
//! module grid.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct KMeans {
    pub centroids: Vec<Vec<f32>>,
}

impl KMeans {
    /// Lloyd's algorithm with k-means++ seeding. Empty clusters are
    /// re-seeded from the point farthest from its centroid.
    pub fn fit(data: &[Vec<f32>], k: usize, iters: usize, rng: &mut Rng) -> KMeans {
        assert!(!data.is_empty() && k > 0 && k <= data.len());
        let mut centroids = plus_plus_init(data, k, rng);
        let mut assign = vec![0usize; data.len()];
        for _ in 0..iters {
            let mut changed = false;
            for (i, x) in data.iter().enumerate() {
                let a = nearest(&centroids, x).0;
                if a != assign[i] {
                    assign[i] = a;
                    changed = true;
                }
            }
            // recompute centroids
            let d = data[0].len();
            let mut sums = vec![vec![0.0f64; d]; k];
            let mut counts = vec![0usize; k];
            for (i, x) in data.iter().enumerate() {
                counts[assign[i]] += 1;
                for (s, &v) in sums[assign[i]].iter_mut().zip(x.iter()) {
                    *s += v as f64;
                }
            }
            for c in 0..k {
                if counts[c] == 0 {
                    // re-seed on the worst-fit point
                    let far = (0..data.len())
                        .max_by(|&a, &b| {
                            let da = dist2(&centroids[assign[a]], &data[a]);
                            let db = dist2(&centroids[assign[b]], &data[b]);
                            da.partial_cmp(&db).unwrap()
                        })
                        .unwrap();
                    centroids[c] = data[far].clone();
                } else {
                    centroids[c] = sums[c]
                        .iter()
                        .map(|&s| (s / counts[c] as f64) as f32)
                        .collect();
                }
            }
            if !changed {
                break;
            }
        }
        KMeans { centroids }
    }

    pub fn k(&self) -> usize {
        self.centroids.len()
    }

    /// Hard assignment (paper Eq. 1).
    pub fn assign(&self, x: &[f32]) -> usize {
        nearest(&self.centroids, x).0
    }

    /// Indices of the n nearest centroids, nearest first (top-n shard
    /// overlap, paper §2.4.4).
    pub fn assign_top_n(&self, x: &[f32], n: usize) -> Vec<usize> {
        let mut d: Vec<(usize, f32)> = self
            .centroids
            .iter()
            .enumerate()
            .map(|(i, c)| (i, dist2(c, x)))
            .collect();
        d.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        d.into_iter().take(n).map(|(i, _)| i).collect()
    }

    /// Sum of squared distances to assigned centroids.
    pub fn inertia(&self, data: &[Vec<f32>]) -> f64 {
        data.iter()
            .map(|x| nearest(&self.centroids, x).1 as f64)
            .sum()
    }

    /// Per-centroid affinity scores, higher = better (negated squared
    /// distance). `argmax(scores) == assign` including tie-breaking
    /// (first index wins both ways). Degraded-mode routing uses these to
    /// find the runner-up path when the best path's breaker is open.
    pub fn scores(&self, x: &[f32]) -> Vec<f64> {
        self.centroids.iter().map(|c| -(dist2(c, x) as f64)).collect()
    }
}

fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

fn nearest(centroids: &[Vec<f32>], x: &[f32]) -> (usize, f32) {
    let mut best = (0, f32::INFINITY);
    for (i, c) in centroids.iter().enumerate() {
        let d = dist2(c, x);
        if d < best.1 {
            best = (i, d);
        }
    }
    best
}

fn plus_plus_init(data: &[Vec<f32>], k: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    let mut centroids = vec![data[rng.gen_range(data.len())].clone()];
    while centroids.len() < k {
        let d2: Vec<f64> = data
            .iter()
            .map(|x| nearest(&centroids, x).1 as f64 + 1e-12)
            .collect();
        centroids.push(data[rng.categorical(&d2)].clone());
    }
    centroids
}

/// Product k-means (paper §7.3): cluster each half of the feature vector
/// independently; the pair (i, j) indexes k1*k2 shards at sqrt cost.
#[derive(Debug, Clone)]
pub struct ProductKMeans {
    pub left: KMeans,
    pub right: KMeans,
    split: usize,
}

impl ProductKMeans {
    /// Reconstruct from serialized halves (router persistence).
    pub fn from_parts(left: KMeans, right: KMeans, split: usize) -> Self {
        ProductKMeans { left, right, split }
    }

    pub fn fit(data: &[Vec<f32>], k1: usize, k2: usize, iters: usize, rng: &mut Rng) -> Self {
        let d = data[0].len();
        let split = d / 2;
        let lefts: Vec<Vec<f32>> = data.iter().map(|x| x[..split].to_vec()).collect();
        let rights: Vec<Vec<f32>> = data.iter().map(|x| x[split..].to_vec()).collect();
        ProductKMeans {
            left: KMeans::fit(&lefts, k1, iters, rng),
            right: KMeans::fit(&rights, k2, iters, rng),
            split,
        }
    }

    pub fn k(&self) -> usize {
        self.left.k() * self.right.k()
    }

    pub fn assign(&self, x: &[f32]) -> usize {
        let i = self.left.assign(&x[..self.split]);
        let j = self.right.assign(&x[self.split..]);
        i * self.right.k() + j
    }

    pub fn assign_top_n(&self, x: &[f32], n: usize) -> Vec<usize> {
        // rank pairs by summed half-distances
        let mut scored: Vec<(usize, f32)> = Vec::with_capacity(self.k());
        for (i, ci) in self.left.centroids.iter().enumerate() {
            let di = dist2(ci, &x[..self.split]);
            for (j, cj) in self.right.centroids.iter().enumerate() {
                let dj = dist2(cj, &x[self.split..]);
                scored.push((i * self.right.k() + j, di + dj));
            }
        }
        scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        scored.into_iter().take(n).map(|(i, _)| i).collect()
    }

    /// Per-pair affinity scores indexed `i * k2 + j`, higher = better
    /// (negated sum of half squared distances); `argmax == assign`.
    pub fn scores(&self, x: &[f32]) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.k());
        for ci in &self.left.centroids {
            let di = dist2(ci, &x[..self.split]);
            for cj in &self.right.centroids {
                let dj = dist2(cj, &x[self.split..]);
                out.push(-((di + dj) as f64));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(k: usize, per: usize, d: usize, sep: f32, seed: u64) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut data = Vec::new();
        let mut labels = Vec::new();
        let centers: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, sep)).collect())
            .collect();
        for (ci, c) in centers.iter().enumerate() {
            for _ in 0..per {
                data.push(c.iter().map(|&m| rng.normal_f32(m, 0.3)).collect());
                labels.push(ci);
            }
        }
        (data, labels)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (data, labels) = blobs(4, 60, 8, 5.0, 1);
        let mut rng = Rng::new(2);
        let km = KMeans::fit(&data, 4, 30, &mut rng);
        // purity: each true cluster maps to a single centroid
        for c in 0..4 {
            let assigns: Vec<usize> = data
                .iter()
                .zip(&labels)
                .filter(|(_, &l)| l == c)
                .map(|(x, _)| km.assign(x))
                .collect();
            let first = assigns[0];
            let agree = assigns.iter().filter(|&&a| a == first).count();
            assert!(agree as f64 / assigns.len() as f64 > 0.95);
        }
    }

    #[test]
    fn inertia_decreases_with_k() {
        let (data, _) = blobs(4, 40, 4, 3.0, 3);
        let mut rng = Rng::new(4);
        let i2 = KMeans::fit(&data, 2, 20, &mut rng).inertia(&data);
        let i8 = KMeans::fit(&data, 8, 20, &mut rng).inertia(&data);
        assert!(i8 < i2);
    }

    #[test]
    fn top_n_starts_with_argmin() {
        let (data, _) = blobs(3, 30, 4, 4.0, 5);
        let mut rng = Rng::new(6);
        let km = KMeans::fit(&data, 3, 20, &mut rng);
        for x in data.iter().take(20) {
            let top = km.assign_top_n(x, 2);
            assert_eq!(top[0], km.assign(x));
            assert_eq!(top.len(), 2);
            assert_ne!(top[0], top[1]);
        }
    }

    #[test]
    fn no_empty_clusters() {
        let (data, _) = blobs(2, 50, 4, 4.0, 7);
        let mut rng = Rng::new(8);
        let km = KMeans::fit(&data, 6, 25, &mut rng);
        let mut counts = vec![0usize; 6];
        for x in &data {
            counts[km.assign(x)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn product_kmeans_covers_grid() {
        let (data, _) = blobs(4, 50, 8, 4.0, 9);
        let mut rng = Rng::new(10);
        let pk = ProductKMeans::fit(&data, 2, 2, 20, &mut rng);
        assert_eq!(pk.k(), 4);
        let mut seen = std::collections::HashSet::new();
        for x in &data {
            let a = pk.assign(x);
            assert!(a < 4);
            seen.insert(a);
            let top = pk.assign_top_n(x, 3);
            assert_eq!(top[0], a);
        }
        assert!(seen.len() >= 2);
    }
}
