//! Multinomial logistic regression — the discriminative router
//! (paper §2.4.2, §7.2.1).
//!
//! "The router is always trained using a K class linear logistic
//! classifier with argmax_p sum_j S_ijp as the target and g(document) as
//! the feature." Trained by mini-batch SGD with momentum on softmax
//! cross-entropy; optionally calibrates per-class biases so the predicted
//! document-to-path distribution matches a target distribution (the paper
//! adds "a bias term to match the target document-to-path distribution"
//! because rare paths were starved after regression).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Logistic {
    /// Row-major [k][d] weights.
    pub w: Vec<Vec<f32>>,
    pub b: Vec<f32>,
    pub k: usize,
    pub d: usize,
}

pub struct TrainOpts {
    pub epochs: usize,
    pub lr: f64,
    pub l2: f64,
    pub batch: usize,
    pub seed: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            epochs: 60,
            lr: 0.5,
            l2: 1e-4,
            batch: 32,
            seed: 17,
        }
    }
}

impl Logistic {
    pub fn fit(data: &[Vec<f32>], labels: &[usize], k: usize, opts: &TrainOpts) -> Logistic {
        assert_eq!(data.len(), labels.len());
        assert!(!data.is_empty());
        let d = data[0].len();
        // standardize features for conditioning
        let (mu, sigma) = standardize_stats(data);
        let mut model = Logistic {
            w: vec![vec![0.0; d]; k],
            b: vec![0.0; k],
            k,
            d,
        };
        let mut vel_w = vec![vec![0.0f64; d]; k];
        let mut vel_b = vec![0.0f64; k];
        let momentum = 0.9;
        let mut rng = Rng::new(opts.seed);
        let mut order: Vec<usize> = (0..data.len()).collect();
        let n = data.len() as f64;
        for epoch in 0..opts.epochs {
            rng.shuffle(&mut order);
            let lr = opts.lr / (1.0 + 0.05 * epoch as f64);
            for chunk in order.chunks(opts.batch) {
                let mut gw = vec![vec![0.0f64; d]; k];
                let mut gb = vec![0.0f64; k];
                for &i in chunk {
                    let x = normalize(&data[i], &mu, &sigma);
                    let p = model.softmax_std(&x);
                    for c in 0..k {
                        let err = p[c] - if labels[i] == c { 1.0 } else { 0.0 };
                        gb[c] += err;
                        for (g, &xv) in gw[c].iter_mut().zip(x.iter()) {
                            *g += err * xv as f64;
                        }
                    }
                }
                let scale = 1.0 / chunk.len() as f64;
                for c in 0..k {
                    for j in 0..d {
                        let g = gw[c][j] * scale + opts.l2 * model.w[c][j] as f64 / n;
                        vel_w[c][j] = momentum * vel_w[c][j] - lr * g;
                        model.w[c][j] += vel_w[c][j] as f32;
                    }
                    vel_b[c] = momentum * vel_b[c] - lr * gb[c] * scale;
                    model.b[c] += vel_b[c] as f32;
                }
            }
        }
        // Fold standardization into the weights so predict() takes raw x.
        model.fold_standardization(&mu, &sigma);
        model
    }

    fn fold_standardization(&mut self, mu: &[f32], sigma: &[f32]) {
        for c in 0..self.k {
            let mut shift = 0.0f32;
            for j in 0..self.d {
                let w = self.w[c][j] / sigma[j];
                shift += w * mu[j];
                self.w[c][j] = w;
            }
            self.b[c] -= shift;
        }
    }

    fn softmax_std(&self, x_std: &[f32]) -> Vec<f64> {
        let logits: Vec<f64> = (0..self.k)
            .map(|c| {
                self.b[c] as f64
                    + self.w[c]
                        .iter()
                        .zip(x_std)
                        .map(|(&w, &x)| w as f64 * x as f64)
                        .sum::<f64>()
            })
            .collect();
        softmax(&logits)
    }

    pub fn logits(&self, x: &[f32]) -> Vec<f64> {
        (0..self.k)
            .map(|c| {
                self.b[c] as f64
                    + self.w[c]
                        .iter()
                        .zip(x)
                        .map(|(&w, &x)| w as f64 * x as f64)
                        .sum::<f64>()
            })
            .collect()
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        argmax(&self.logits(x))
    }

    /// Top-n classes by logit, best first.
    pub fn predict_top_n(&self, x: &[f32], n: usize) -> Vec<usize> {
        let lg = self.logits(x);
        let mut idx: Vec<usize> = (0..self.k).collect();
        idx.sort_by(|&a, &b| lg[b].partial_cmp(&lg[a]).unwrap());
        idx.truncate(n);
        idx
    }

    pub fn accuracy(&self, data: &[Vec<f32>], labels: &[usize]) -> f64 {
        let correct = data
            .iter()
            .zip(labels)
            .filter(|(x, &l)| self.predict(x) == l)
            .count();
        correct as f64 / data.len() as f64
    }

    /// Adjust biases so that the predicted class distribution over `data`
    /// matches `target` (unnormalized). Iterative proportional fitting on
    /// the bias terms — paper §7.2.1's remedy for starved paths.
    pub fn calibrate_bias(&mut self, data: &[Vec<f32>], target: &[f64], iters: usize) {
        let t_total: f64 = target.iter().sum();
        for _ in 0..iters {
            let mut counts = vec![1e-9f64; self.k]; // smoothed
            for x in data {
                counts[self.predict(x)] += 1.0;
            }
            let n: f64 = data.len() as f64;
            let mut max_ratio: f64 = 1.0;
            for c in 0..self.k {
                let want = (target[c] / t_total).max(1e-9);
                let have = counts[c] / n;
                let ratio = want / have;
                self.b[c] += (ratio.ln() as f32) * 0.5;
                max_ratio = max_ratio.max(ratio.max(1.0 / ratio));
            }
            if max_ratio < 1.15 {
                break;
            }
        }
    }

    /// Predicted class histogram over a dataset.
    pub fn class_histogram(&self, data: &[Vec<f32>]) -> Vec<usize> {
        let mut h = vec![0usize; self.k];
        for x in data {
            h[self.predict(x)] += 1;
        }
        h
    }
}

fn standardize_stats(data: &[Vec<f32>]) -> (Vec<f32>, Vec<f32>) {
    let d = data[0].len();
    let n = data.len() as f64;
    let mut mu = vec![0.0f64; d];
    for x in data {
        for (m, &v) in mu.iter_mut().zip(x) {
            *m += v as f64;
        }
    }
    mu.iter_mut().for_each(|m| *m /= n);
    let mut var = vec![0.0f64; d];
    for x in data {
        for ((s, &v), m) in var.iter_mut().zip(x).zip(&mu) {
            *s += (v as f64 - m) * (v as f64 - m);
        }
    }
    let sigma: Vec<f32> = var
        .iter()
        .map(|&v| ((v / n).sqrt() as f32).max(1e-6))
        .collect();
    (mu.iter().map(|&m| m as f32).collect(), sigma)
}

fn normalize(x: &[f32], mu: &[f32], sigma: &[f32]) -> Vec<f32> {
    x.iter()
        .zip(mu)
        .zip(sigma)
        .map(|((&v, &m), &s)| (v - m) / s)
        .collect()
}

fn softmax(logits: &[f64]) -> Vec<f64> {
    let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = logits.iter().map(|&l| (l - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

fn argmax(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(k: usize, per: usize, d: usize, sep: f32, seed: u64) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, sep)).collect())
            .collect();
        let mut data = Vec::new();
        let mut labels = Vec::new();
        for (ci, c) in centers.iter().enumerate() {
            for _ in 0..per {
                data.push(c.iter().map(|&m| rng.normal_f32(m, 0.4)).collect());
                labels.push(ci);
            }
        }
        (data, labels)
    }

    #[test]
    fn separable_blobs_high_accuracy() {
        let (data, labels) = blobs(4, 80, 8, 3.0, 1);
        let m = Logistic::fit(&data, &labels, 4, &TrainOpts::default());
        assert!(m.accuracy(&data, &labels) > 0.97);
    }

    #[test]
    fn top_n_consistent_with_predict() {
        let (data, labels) = blobs(3, 40, 6, 3.0, 2);
        let m = Logistic::fit(&data, &labels, 3, &TrainOpts::default());
        for x in data.iter().take(20) {
            let top = m.predict_top_n(x, 2);
            assert_eq!(top[0], m.predict(x));
            assert_eq!(top.len(), 2);
        }
    }

    #[test]
    fn bias_calibration_matches_target() {
        // Train on imbalanced but overlapping data, calibrate to uniform.
        let (mut data, mut labels) = blobs(2, 200, 4, 0.5, 3);
        let (d2, l2) = blobs(2, 40, 4, 0.5, 4);
        data.extend(d2);
        labels.extend(l2);
        let mut m = Logistic::fit(&data, &labels, 2, &TrainOpts::default());
        m.calibrate_bias(&data, &[0.5, 0.5], 20);
        let h = m.class_histogram(&data);
        let frac = h[0] as f64 / data.len() as f64;
        assert!((frac - 0.5).abs() < 0.15, "frac {frac}");
    }

    #[test]
    fn logits_finite() {
        let (data, labels) = blobs(2, 20, 4, 2.0, 5);
        let m = Logistic::fit(&data, &labels, 2, &TrainOpts::default());
        for x in &data {
            assert!(m.logits(x).iter().all(|l| l.is_finite()));
        }
    }
}
