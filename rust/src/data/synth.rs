//! Synthetic multi-domain corpus generator — the C4 substitution.
//!
//! Each domain is a distinct order-2 Markov source over a shared 28-char
//! alphabet (a-z, space, period). Transition tables are sparse (few likely
//! successors per bigram context) and seeded per domain, so:
//!
//! * documents are low-entropy and learnable by the small LM in hundreds
//!   of steps;
//! * the domain of a document is identifiable from a short prefix (the
//!   premise behind DiPaCo's 32-token coarse routing);
//! * specialists (paths) genuinely beat a generalist of the same size,
//!   and flat MoE overfits when shards get small — the behaviours the
//!   paper's tables measure.
//!
//! Domain weights follow a Zipf-like skew so shards have unequal sizes,
//! exercising the loss-reweighing correction (paper §2.7 Eq. 2-3).

use crate::util::rng::Rng;

pub const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz .";

/// Number of successor candidates per bigram context. Smaller = lower
/// entropy = more domain-separable text.
const SUCCESSORS: usize = 3;

#[derive(Debug, Clone)]
pub struct Domain {
    pub id: usize,
    /// For each bigram context (a*28+b): candidate successors and weights.
    table: Vec<[(u8, f32); SUCCESSORS]>,
}

impl Domain {
    pub fn generate(id: usize, rng: &mut Rng) -> Domain {
        let a = ALPHABET.len();
        // Each domain prefers a (seeded) subset of the alphabet: successor
        // candidates are drawn from the preferred set with high probability.
        // This gives domains strong character-level signatures (like real
        // topical domains' vocabularies), which is what makes prefix-based
        // coarse routing viable (paper §2.4).
        let preferred = rng.sample_indices(a, a / 2);
        let mut table = Vec::with_capacity(a * a);
        for _ctx in 0..a * a {
            let mut entry = [(0u8, 0.0f32); SUCCESSORS];
            let mut total = 0.0;
            let mut used = [usize::MAX; SUCCESSORS];
            for (si, slot) in entry.iter_mut().enumerate() {
                let cand = loop {
                    let c = if rng.f64() < 0.85 {
                        preferred[rng.gen_range(preferred.len())]
                    } else {
                        rng.gen_range(a)
                    };
                    if !used[..si].contains(&c) {
                        break c;
                    }
                };
                used[si] = cand;
                let w = 0.2 + rng.f32();
                *slot = (ALPHABET[cand], w);
                total += w;
            }
            for slot in entry.iter_mut() {
                slot.1 /= total;
            }
            table.push(entry);
        }
        Domain { id, table }
    }

    fn ctx_index(&self, prev2: u8, prev1: u8) -> usize {
        let pos = |c: u8| ALPHABET.iter().position(|&x| x == c).unwrap_or(0);
        pos(prev2) * ALPHABET.len() + pos(prev1)
    }

    pub fn sample_text(&self, len: usize, rng: &mut Rng) -> String {
        let mut out = Vec::with_capacity(len);
        let mut p2 = ALPHABET[rng.gen_range(ALPHABET.len())];
        let mut p1 = ALPHABET[rng.gen_range(ALPHABET.len())];
        out.push(p2);
        out.push(p1);
        while out.len() < len {
            let entry = &self.table[self.ctx_index(p2, p1)];
            let weights: Vec<f64> = entry.iter().map(|&(_, w)| w as f64).collect();
            let next = entry[rng.categorical(&weights)].0;
            out.push(next);
            p2 = p1;
            p1 = next;
        }
        String::from_utf8(out).unwrap()
    }

    /// Per-character entropy of the source in nats (average over contexts,
    /// unweighted). Lower bound on achievable LM loss on this domain.
    pub fn entropy_nats(&self) -> f64 {
        let mut total = 0.0;
        for entry in &self.table {
            let mut h = 0.0;
            for &(_, w) in entry {
                if w > 0.0 {
                    h -= (w as f64) * (w as f64).ln();
                }
            }
            total += h;
        }
        total / self.table.len() as f64
    }
}

#[derive(Debug, Clone)]
pub struct Document {
    pub text: String,
    /// Ground-truth domain id — used only for diagnostics (routing
    /// accuracy), never by the model or router.
    pub domain: usize,
}

/// Generate `n_docs` documents across `n_domains` Zipf(skew)-weighted
/// domains. Document lengths are uniform in `doc_len`.
pub fn generate_corpus(
    n_domains: usize,
    n_docs: usize,
    doc_len: (usize, usize),
    skew: f64,
    seed: u64,
) -> Vec<Document> {
    let root = Rng::new(seed);
    let drng = root.fork(0xD0);
    let domains: Vec<Domain> = (0..n_domains)
        .map(|i| Domain::generate(i, &mut drng.fork(i as u64)))
        .collect();
    let weights: Vec<f64> = (1..=n_domains)
        .map(|r| 1.0 / (r as f64).powf(skew))
        .collect();
    let mut rng = root.fork(0xD1);
    (0..n_docs)
        .map(|_| {
            let d = rng.categorical(&weights);
            let len = doc_len.0 + rng.gen_range(doc_len.1 - doc_len.0 + 1);
            Document {
                text: domains[d].sample_text(len, &mut rng),
                domain: d,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = generate_corpus(4, 20, (100, 200), 0.5, 9);
        let b = generate_corpus(4, 20, (100, 200), 0.5, 9);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.text, y.text);
            assert_eq!(x.domain, y.domain);
        }
    }

    #[test]
    fn lengths_in_range() {
        for d in generate_corpus(2, 50, (300, 700), 0.0, 1) {
            assert!((300..=700).contains(&d.text.len()));
            assert!(d.text.bytes().all(|b| ALPHABET.contains(&b)));
        }
    }

    #[test]
    fn domains_are_distinguishable() {
        // Character-bigram distributions of two domains must differ far
        // more across domains than within a domain.
        let rng = Rng::new(3);
        let d0 = Domain::generate(0, &mut rng.fork(0));
        let d1 = Domain::generate(1, &mut rng.fork(1));
        let hist = |s: &str| {
            let mut h = vec![0.0f64; 28 * 28];
            let b = s.as_bytes();
            let pos = |c: u8| ALPHABET.iter().position(|&x| x == c).unwrap();
            for w in b.windows(2) {
                h[pos(w[0]) * 28 + pos(w[1])] += 1.0;
            }
            let t: f64 = h.iter().sum();
            h.iter_mut().for_each(|x| *x /= t);
            h
        };
        let l2 = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let a1 = hist(&d0.sample_text(4000, &mut rng.fork(10)));
        let a2 = hist(&d0.sample_text(4000, &mut rng.fork(11)));
        let b1 = hist(&d1.sample_text(4000, &mut rng.fork(12)));
        let within = l2(&a1, &a2);
        let across = l2(&a1, &b1);
        assert!(
            across > 5.0 * within,
            "across {across} should dwarf within {within}"
        );
    }

    #[test]
    fn entropy_is_low_but_positive() {
        let mut rng = Rng::new(4);
        let d = Domain::generate(0, &mut rng);
        let h = d.entropy_nats();
        // 3 successors max -> at most ln(3) nats
        assert!(h > 0.1 && h <= 3f64.ln() + 1e-9, "h = {h}");
    }

    #[test]
    fn skew_produces_imbalance() {
        let docs = generate_corpus(8, 4000, (100, 101), 1.0, 5);
        let mut counts = vec![0usize; 8];
        for d in &docs {
            counts[d.domain] += 1;
        }
        assert!(counts[0] > counts[7] * 2, "{counts:?}");
    }
}
