//! Corpus container: documents tokenized once, split into train /
//! validation / router-data subsets (paper §7.2.1 reserves a router split),
//! exposed as token slices for sequence packing.

use crate::config::CorpusConfig;
use crate::data::synth::{self, Document};
use crate::data::tokenizer::{ByteTokenizer, Tokenizer};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Split {
    Train,
    Valid,
    Router,
}

#[derive(Debug)]
pub struct Corpus {
    pub docs: Vec<TokenizedDoc>,
    pub train: Vec<usize>,
    pub valid: Vec<usize>,
    pub router: Vec<usize>,
    pub n_domains: usize,
}

#[derive(Debug, Clone)]
pub struct TokenizedDoc {
    pub tokens: Vec<i32>,
    pub domain: usize,
}

impl Corpus {
    /// Generate, tokenize and split the synthetic corpus.
    /// Fractions: 80% train, 10% valid, 10% router data.
    pub fn synthetic(cfg: &CorpusConfig) -> Corpus {
        let docs = synth::generate_corpus(
            cfg.n_domains,
            cfg.n_docs,
            cfg.doc_len,
            cfg.skew,
            cfg.seed,
        );
        Self::from_documents(docs, cfg.n_domains, cfg.seed)
    }

    pub fn from_documents(docs: Vec<Document>, n_domains: usize, seed: u64) -> Corpus {
        let tok = ByteTokenizer;
        let docs: Vec<TokenizedDoc> = docs
            .into_iter()
            .map(|d| TokenizedDoc {
                tokens: tok.encode(&d.text),
                domain: d.domain,
            })
            .collect();
        let mut order: Vec<usize> = (0..docs.len()).collect();
        Rng::new(seed ^ 0x5115).shuffle(&mut order);
        let n = docs.len();
        let n_valid = n / 10;
        let n_router = n / 10;
        let n_train = n - n_valid - n_router;
        let train = order[..n_train].to_vec();
        let valid = order[n_train..n_train + n_valid].to_vec();
        let router = order[n_train + n_valid..].to_vec();
        Corpus {
            docs,
            train,
            valid,
            router,
            n_domains,
        }
    }

    pub fn split(&self, s: Split) -> &[usize] {
        match s {
            Split::Train => &self.train,
            Split::Valid => &self.valid,
            Split::Router => &self.router,
        }
    }

    /// First `prefix` tokens of a document (router context, paper §2.4).
    pub fn prefix(&self, doc: usize, prefix: usize) -> &[i32] {
        let t = &self.docs[doc].tokens;
        &t[..prefix.min(t.len())]
    }

    /// First `seq` tokens (training/eval window). Documents are generated
    /// longer than `seq_eval`, so this never pads in practice; short docs
    /// are right-padded with byte 0.
    pub fn sequence(&self, doc: usize, seq: usize) -> Vec<i32> {
        let t = &self.docs[doc].tokens;
        let mut out = Vec::with_capacity(seq);
        out.extend_from_slice(&t[..seq.min(t.len())]);
        out.resize(seq, 0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Corpus {
        Corpus::synthetic(&CorpusConfig {
            n_domains: 4,
            n_docs: 100,
            doc_len: (60, 90),
            skew: 0.0,
            seed: 11,
        })
    }

    #[test]
    fn splits_partition_docs() {
        let c = tiny();
        let mut all: Vec<usize> = c
            .train
            .iter()
            .chain(c.valid.iter())
            .chain(c.router.iter())
            .copied()
            .collect();
        all.sort();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
        assert_eq!(c.valid.len(), 10);
        assert_eq!(c.router.len(), 10);
    }

    #[test]
    fn sequences_padded_and_truncated() {
        let c = tiny();
        let s = c.sequence(c.train[0], 64);
        assert_eq!(s.len(), 64);
        let long = c.sequence(c.train[0], 2000);
        assert_eq!(long.len(), 2000);
        assert_eq!(*long.last().unwrap(), 0); // padded tail
    }

    #[test]
    fn deterministic_splits() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.train, b.train);
        assert_eq!(a.router, b.router);
    }

    #[test]
    fn prefix_is_prefix_of_sequence() {
        let c = tiny();
        let d = c.train[3];
        let p = c.prefix(d, 16).to_vec();
        let s = c.sequence(d, 32);
        assert_eq!(&s[..16], &p[..]);
    }
}
