//! Byte-level tokenizer (vocab = 256).
//!
//! Stands in for the paper's 32k SentencePiece vocabulary: at this model
//! scale a subword vocabulary would dominate the parameter budget, and the
//! routing/optimization claims under test are tokenizer-agnostic. The
//! trait keeps the door open for richer tokenizers.

pub trait Tokenizer: Send + Sync {
    fn vocab_size(&self) -> usize;
    fn encode(&self, text: &str) -> Vec<i32>;
    fn decode(&self, tokens: &[i32]) -> String;
}

#[derive(Debug, Default, Clone)]
pub struct ByteTokenizer;

impl Tokenizer for ByteTokenizer {
    fn vocab_size(&self) -> usize {
        256
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .map(|&t| u8::try_from(t).unwrap_or(b'?'))
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "the quick brown fox. 0123";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn tokens_in_vocab() {
        let t = ByteTokenizer;
        for tok in t.encode("héllo") {
            assert!((0..256).contains(&tok));
        }
    }

    #[test]
    fn out_of_range_decodes_lossy() {
        let t = ByteTokenizer;
        assert_eq!(t.decode(&[104, 105, 300]), "hi?");
    }
}
