//! Shards and batch iterators.
//!
//! A [`Shard`] is the set of document ids routed to one path (paper §2.3:
//! "the subset of data that is routed to path j will be called the j-th
//! shard D_j"). [`Sharding`] holds all shards for a run plus the per-shard
//! holdout used by early stopping (paper §2.7). [`BatchSampler`] draws
//! fixed-shape `i32` token batches for the PJRT train-step executable.

use crate::data::corpus::Corpus;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Default)]
pub struct Shard {
    /// Document ids (into `Corpus::docs`).
    pub docs: Vec<usize>,
    /// Held-out docs for early stopping (disjoint from `docs`).
    pub holdout: Vec<usize>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.docs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }
}

#[derive(Debug, Clone, Default)]
pub struct Sharding {
    pub shards: Vec<Shard>,
}

impl Sharding {
    /// Build shards from an assignment `doc -> one-or-more shard ids`
    /// (top-n overlap, paper §2.4.4), carving `holdout_frac` of each shard
    /// into its early-stopping holdout.
    pub fn from_assignments(
        n_shards: usize,
        assignments: &[(usize, Vec<usize>)],
        holdout_frac: f64,
        seed: u64,
    ) -> Sharding {
        let mut shards = vec![Shard::default(); n_shards];
        for (doc, sids) in assignments {
            for &s in sids {
                shards[s].docs.push(*doc);
            }
        }
        let root = Rng::new(seed ^ 0x54a6d);
        for (i, sh) in shards.iter_mut().enumerate() {
            let mut rng = root.fork(i as u64);
            rng.shuffle(&mut sh.docs);
            let n_hold = ((sh.docs.len() as f64) * holdout_frac).floor() as usize;
            sh.holdout = sh.docs.split_off(sh.docs.len() - n_hold);
        }
        shards
            .iter_mut()
            .for_each(|s| s.docs.sort_unstable());
        Sharding { shards }
    }

    /// Single shard holding every train document (dense/DiLoCo baselines).
    pub fn single(corpus: &Corpus, holdout_frac: f64, seed: u64) -> Sharding {
        let assignments: Vec<(usize, Vec<usize>)> =
            corpus.train.iter().map(|&d| (d, vec![0])).collect();
        Self::from_assignments(1, &assignments, holdout_frac, seed)
    }

    /// `k` random shards of roughly equal size (uninformed baseline /
    /// DiLoCo data parallelism).
    pub fn random(corpus: &Corpus, k: usize, holdout_frac: f64, seed: u64) -> Sharding {
        let mut rng = Rng::new(seed ^ 0xda7a);
        let assignments: Vec<(usize, Vec<usize>)> = corpus
            .train
            .iter()
            .map(|&d| (d, vec![rng.gen_range(k)]))
            .collect();
        Self::from_assignments(k, &assignments, holdout_frac, seed)
    }

    pub fn sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.len()).collect()
    }

    pub fn total_docs(&self) -> usize {
        self.sizes().iter().sum()
    }
}

/// Samples fixed-shape batches `[batch, seq]` (flattened row-major) from a
/// shard, reshuffling each epoch. Deterministic given the seed.
#[derive(Debug)]
pub struct BatchSampler {
    docs: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub batch: usize,
    pub seq: usize,
}

impl BatchSampler {
    pub fn new(docs: &[usize], batch: usize, seq: usize, seed: u64) -> BatchSampler {
        assert!(!docs.is_empty(), "empty shard");
        let mut rng = Rng::new(seed ^ 0xba7c4);
        let mut docs = docs.to_vec();
        rng.shuffle(&mut docs);
        BatchSampler {
            docs,
            cursor: 0,
            rng,
            batch,
            seq,
        }
    }

    /// Next flattened `[batch * seq]` token buffer (+ the doc ids used).
    pub fn next_batch(&mut self, corpus: &Corpus) -> (Vec<i32>, Vec<usize>) {
        let mut out = Vec::with_capacity(self.batch * self.seq);
        let mut ids = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor >= self.docs.len() {
                self.rng.shuffle(&mut self.docs);
                self.cursor = 0;
            }
            let d = self.docs[self.cursor];
            self.cursor += 1;
            ids.push(d);
            out.extend_from_slice(&corpus.sequence(d, self.seq));
        }
        (out, ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusConfig;

    fn corpus() -> Corpus {
        Corpus::synthetic(&CorpusConfig {
            n_domains: 4,
            n_docs: 200,
            doc_len: (80, 120),
            skew: 0.0,
            seed: 3,
        })
    }

    #[test]
    fn random_sharding_partitions_train() {
        let c = corpus();
        let s = Sharding::random(&c, 4, 0.0, 1);
        assert_eq!(s.total_docs(), c.train.len());
        assert!(s.sizes().iter().all(|&n| n > 20));
    }

    #[test]
    fn holdout_disjoint() {
        let c = corpus();
        let s = Sharding::random(&c, 2, 0.2, 1);
        for sh in &s.shards {
            for h in &sh.holdout {
                assert!(!sh.docs.contains(h));
            }
            assert!(!sh.holdout.is_empty());
        }
    }

    #[test]
    fn overlap_duplicates_docs() {
        let c = corpus();
        let assignments: Vec<(usize, Vec<usize>)> =
            c.train.iter().map(|&d| (d, vec![0, 1])).collect();
        let s = Sharding::from_assignments(2, &assignments, 0.0, 1);
        assert_eq!(s.shards[0].len(), c.train.len());
        assert_eq!(s.shards[1].len(), c.train.len());
    }

    #[test]
    fn sampler_shapes_and_coverage() {
        let c = corpus();
        let s = Sharding::single(&c, 0.0, 1);
        let mut bs = BatchSampler::new(&s.shards[0].docs, 4, 32, 5);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            let (buf, ids) = bs.next_batch(&c);
            assert_eq!(buf.len(), 4 * 32);
            assert_eq!(ids.len(), 4);
            seen.extend(ids);
        }
        // with 200 batches of 4 over ~160 train docs, all get sampled
        assert_eq!(seen.len(), c.train.len());
    }

    #[test]
    fn sampler_deterministic() {
        let c = corpus();
        let docs = c.train.clone();
        let mut a = BatchSampler::new(&docs, 2, 16, 9);
        let mut b = BatchSampler::new(&docs, 2, 16, 9);
        for _ in 0..5 {
            assert_eq!(a.next_batch(&c).0, b.next_batch(&c).0);
        }
    }
}
