//! Experiment output: CSV writers and run summaries for `results/` and
//! EXPERIMENTS.md. Every experiment driver funnels through these so the
//! paper tables regenerate reproducibly.

use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

pub struct CsvWriter {
    file: std::fs::File,
    pub path: PathBuf,
    columns: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file =
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter {
            file,
            path: path.to_path_buf(),
            columns: header.len(),
        })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        assert_eq!(values.len(), self.columns, "column count mismatch");
        writeln!(self.file, "{}", values.join(","))?;
        self.file.flush()?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Pretty console table matching the paper's row layout.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (w, cell) in widths.iter_mut().zip(row) {
            *w = (*w).max(cell.len());
        }
    }
    println!("\n== {title} ==");
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect::<Vec<_>>()
            .join(" | ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-+-"));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// JSON run summary (appended to results/ for EXPERIMENTS.md bookkeeping).
pub fn write_summary(path: &Path, entries: Vec<(&str, Json)>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, Json::obj(entries).to_string_pretty())
        .with_context(|| format!("writing {}", path.display()))
}

/// Results directory (crate-rooted, override with DIPACO_RESULTS).
pub fn results_dir() -> PathBuf {
    std::env::var("DIPACO_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let p = std::env::temp_dir().join(format!("dipaco-csv-{}.csv", std::process::id()));
        let mut w = CsvWriter::create(&p, &["step", "loss"]).unwrap();
        w.rowf(&[1.0, 2.5]).unwrap();
        w.row(&["2".into(), "2.25".into()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("step,loss\n"));
    }

    #[test]
    #[should_panic]
    fn csv_rejects_bad_width() {
        let p = std::env::temp_dir().join(format!("dipaco-csv2-{}.csv", std::process::id()));
        let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
        let _ = w.rowf(&[1.0]);
    }
}
