//! Thread-safe buffer pool: a bounded free-list of `Vec<T>` so
//! steady-state phases and serving reuse allocations instead of churning
//! the allocator once per module per phase (ISSUE 8 / ROADMAP item 5).
//!
//! Ownership rule (see DESIGN.md "Hot path & memory"): a [`PooledBuf`]
//! owns its `Vec` for its whole lifetime and returns it to the pool on
//! drop — cleared, capacity intact. Buffers never alias, and the pool
//! never hands the same `Vec` to two takers, so pooled code is exactly as
//! data-race-free as the allocating code it replaces. Retention is
//! bounded (`max_retained`) so a burst of large buffers can't pin memory
//! forever; beyond the bound, drops fall through to the allocator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Free-list of reusable `Vec<T>` buffers. Cheap to share via `Arc`.
#[derive(Debug)]
pub struct Pool<T> {
    free: Mutex<Vec<Vec<T>>>,
    max_retained: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Take/return counters, for tests asserting steady-state reuse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Takes served from the free-list.
    pub hits: u64,
    /// Takes that had to allocate a fresh `Vec`.
    pub misses: u64,
    /// Buffers currently parked in the free-list.
    pub idle: usize,
}

impl<T> Pool<T> {
    /// A pool retaining at most `max_retained` idle buffers.
    pub fn new(max_retained: usize) -> Arc<Self> {
        Arc::new(Pool {
            free: Mutex::new(Vec::new()),
            max_retained,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    /// Take a buffer with at least `cap` capacity (empty, len 0). Served
    /// from the free-list when possible; the returned guard gives the
    /// buffer back on drop. Associated fn (not a method) because the
    /// guard must hold its own `Arc` handle to the pool.
    pub fn take(pool: &Arc<Self>, cap: usize) -> PooledBuf<T> {
        let reused = pool.free.lock().unwrap().pop();
        let mut buf = match reused {
            Some(b) => {
                pool.hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                pool.misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        };
        if buf.capacity() < cap {
            buf.reserve(cap - buf.len());
        }
        PooledBuf {
            buf: Some(buf),
            pool: Arc::clone(pool),
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            idle: self.free.lock().unwrap().len(),
        }
    }

    fn put_back(&self, mut buf: Vec<T>) {
        buf.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_retained {
            free.push(buf);
        }
        // else: drop, letting the allocator reclaim it (bounded retention).
    }
}

impl<T> Default for Pool<T> {
    fn default() -> Self {
        Pool {
            free: Mutex::new(Vec::new()),
            max_retained: 64,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }
}

/// RAII guard over a pooled `Vec<T>`; derefs to the `Vec` so call sites
/// read like plain vector code. Returns the buffer on drop.
#[derive(Debug)]
pub struct PooledBuf<T> {
    buf: Option<Vec<T>>,
    pool: Arc<Pool<T>>,
}

impl<T> PooledBuf<T> {
    /// Detach the buffer from the pool (it will NOT be returned).
    pub fn into_inner(mut self) -> Vec<T> {
        self.buf.take().expect("buffer already detached")
    }
}

impl<T> std::ops::Deref for PooledBuf<T> {
    type Target = Vec<T>;
    fn deref(&self) -> &Vec<T> {
        self.buf.as_ref().expect("buffer already detached")
    }
}

impl<T> std::ops::DerefMut for PooledBuf<T> {
    fn deref_mut(&mut self) -> &mut Vec<T> {
        self.buf.as_mut().expect("buffer already detached")
    }
}

impl<T> Drop for PooledBuf<T> {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            self.pool.put_back(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_reuses_capacity() {
        let pool: Arc<Pool<f32>> = Pool::new(8);
        let cap_after_first;
        {
            let mut b = Pool::take(&pool, 1000);
            b.resize(1000, 1.0f32);
            cap_after_first = b.capacity();
        } // returned
        for _ in 0..10 {
            let b = Pool::take(&pool, 1000);
            assert!(b.is_empty(), "pooled buffer must come back cleared");
            assert!(b.capacity() >= cap_after_first, "capacity must survive");
        }
        let s = pool.stats();
        assert_eq!(s.misses, 1, "only the first take allocates");
        assert_eq!(s.hits, 10);
        assert_eq!(s.idle, 1);
    }

    #[test]
    fn retention_is_bounded() {
        let pool: Arc<Pool<u8>> = Pool::new(2);
        let bufs: Vec<_> = (0..5).map(|_| Pool::take(&pool, 16)).collect();
        drop(bufs);
        assert_eq!(pool.stats().idle, 2, "free-list capped at max_retained");
    }

    #[test]
    fn into_inner_detaches() {
        let pool: Arc<Pool<i32>> = Pool::new(4);
        let mut b = Pool::take(&pool, 4);
        b.push(42);
        let v = b.into_inner();
        assert_eq!(v, vec![42]);
        assert_eq!(pool.stats().idle, 0, "detached buffer is not returned");
    }

    #[test]
    fn concurrent_takes_never_alias() {
        let pool: Arc<Pool<u64>> = Pool::new(32);
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let pool = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                for i in 0..200u64 {
                    let mut b = Pool::take(&pool, 64);
                    let tag = t * 1_000_000 + i;
                    b.resize(64, tag);
                    assert!(b.iter().all(|&x| x == tag), "aliased buffer");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = pool.stats();
        assert_eq!(s.hits + s.misses, 8 * 200);
    }
}
