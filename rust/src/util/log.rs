//! Leveled stderr logger. Level from `DIPACO_LOG` (error|warn|info|debug),
//! default info. Timestamps are seconds since process start (monotonic).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let l = match std::env::var("DIPACO_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        _ => 2,
    };
    LEVEL.store(l, Ordering::Relaxed);
    l
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(l: Level, component: &str, msg: std::fmt::Arguments<'_>) {
    if (l as u8) <= level() {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{:9.3}s {} {}] {}", elapsed(), tag, component, msg);
    }
}

#[macro_export]
macro_rules! info {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $component, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $component, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $component, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotonic() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }

    #[test]
    fn set_level_filters() {
        set_level(Level::Error);
        // just exercise the paths; output goes to stderr
        log(Level::Debug, "test", format_args!("suppressed"));
        log(Level::Error, "test", format_args!("shown"));
        set_level(Level::Info);
    }
}
