//! Keyed barrier (paper §3.2): in the multi-host SPMD setting, DiPaCo
//! synchronizes task-queue writes by blocking "until each program running
//! on their host [has] made a call with the same unique key". This is the
//! single-process equivalent: `wait(key)` blocks until `parties` callers
//! have arrived with that key, then releases them all and retires the key.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

pub struct KeyedBarrier {
    parties: usize,
    state: Mutex<HashMap<String, BarrierState>>,
    cv: Condvar,
}

struct BarrierState {
    arrived: usize,
    generation: u64,
}

impl KeyedBarrier {
    pub fn new(parties: usize) -> Self {
        assert!(parties > 0);
        KeyedBarrier {
            parties,
            state: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
        }
    }

    /// Block until `parties` threads call `wait` with the same `key`.
    /// Returns true for exactly one caller per release (the "leader").
    pub fn wait(&self, key: &str) -> bool {
        let mut guard = self.state.lock().unwrap();
        let entry = guard.entry(key.to_string()).or_insert(BarrierState {
            arrived: 0,
            generation: 0,
        });
        entry.arrived += 1;
        let gen = entry.generation;
        if entry.arrived == self.parties {
            // release this generation
            entry.arrived = 0;
            entry.generation += 1;
            self.cv.notify_all();
            return true;
        }
        while guard.get(key).map(|e| e.generation) == Some(gen) {
            guard = self.cv.wait(guard).unwrap();
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn releases_when_all_arrive() {
        let b = Arc::new(KeyedBarrier::new(4));
        let leaders = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let b = Arc::clone(&b);
                let leaders = Arc::clone(&leaders);
                let done = Arc::clone(&done);
                s.spawn(move || {
                    if b.wait("ckpt-42") {
                        leaders.fetch_add(1, Ordering::SeqCst);
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(done.load(Ordering::SeqCst), 4);
        assert_eq!(leaders.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn keys_are_independent_and_reusable() {
        let b = Arc::new(KeyedBarrier::new(2));
        for round in 0..3 {
            let key = format!("phase-{round}");
            std::thread::scope(|s| {
                for _ in 0..2 {
                    let b = Arc::clone(&b);
                    let key = key.clone();
                    s.spawn(move || {
                        b.wait(&key);
                    });
                }
            });
        }
    }
}
