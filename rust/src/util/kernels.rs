//! Fused, autovectorizable f32 kernels for the per-phase hot loops
//! (ISSUE 8 / ROADMAP item 5).
//!
//! Every kernel here is **bit-exact** against its `_scalar` reference:
//! the chaos convergence-equivalence oracles digest module stores and
//! demand bit-identical f32 trajectories per seed, so the only
//! transformations allowed are ones that keep each element's arithmetic
//! literally unchanged — fixed-width chunking of elementwise loops (so
//! LLVM can keep the bounds checks out of the body and vectorize it) and
//! hoisting loop-invariant scalars (`powf` bias corrections in AdamW).
//! Reassociating reductions, reciprocal-multiplying divisions, or FMA
//! contraction would all change low bits and are deliberately absent.
//!
//! The `_scalar` references stay public: the property tests in this
//! module prove bitwise equality on random sizes (including
//! non-multiple-of-chunk tails), and `bench_train_step` times fused vs
//! scalar so the speedup is a measured number, not a claim.

/// Elements per unrolled chunk. 8 f32 lanes = one AVX2 register; the
/// array conversion below removes bounds checks inside the chunk body.
const LANES: usize = 8;

/// Nesterov outer step, fused: `v <- mu v + g; p <- p - lr (g + mu v)`.
/// Uses the *updated* velocity in the parameter update, matching
/// [`nesterov_scalar`] bit for bit.
pub fn nesterov_step(params: &mut [f32], vel: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    assert_eq!(params.len(), vel.len());
    assert_eq!(params.len(), g.len());
    let main = params.len() - params.len() % LANES;
    let (pm, pt) = params.split_at_mut(main);
    let (vm, vt) = vel.split_at_mut(main);
    let (gm, gt) = g.split_at(main);
    for ((pc, vc), gc) in pm
        .chunks_exact_mut(LANES)
        .zip(vm.chunks_exact_mut(LANES))
        .zip(gm.chunks_exact(LANES))
    {
        let pc: &mut [f32; LANES] = pc.try_into().unwrap();
        let vc: &mut [f32; LANES] = vc.try_into().unwrap();
        let gc: &[f32; LANES] = gc.try_into().unwrap();
        for i in 0..LANES {
            let v = mu * vc[i] + gc[i];
            vc[i] = v;
            pc[i] -= lr * (gc[i] + mu * v);
        }
    }
    for ((p, v), &gi) in pt.iter_mut().zip(vt.iter_mut()).zip(gt) {
        let vn = mu * *v + gi;
        *v = vn;
        *p -= lr * (gi + mu * vn);
    }
}

/// Scalar reference for [`nesterov_step`] (the pre-fusion loop).
pub fn nesterov_scalar(params: &mut [f32], vel: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    assert_eq!(params.len(), vel.len());
    assert_eq!(params.len(), g.len());
    for ((p, v), &gi) in params.iter_mut().zip(vel.iter_mut()).zip(g) {
        *v = mu * *v + gi;
        *p -= lr * (gi + mu * *v);
    }
}

/// Weighted accumulate, fused: `sum[i] += (delta[i] as f64 * w) as f32`.
/// The widen-to-f64 product then round-to-f32 is the accumulator's
/// contract (weights are shard sizes, far outside f32-exact range).
pub fn accumulate(sum: &mut [f32], delta: &[f32], w: f64) {
    assert_eq!(sum.len(), delta.len());
    let main = sum.len() - sum.len() % LANES;
    let (sm, st) = sum.split_at_mut(main);
    let (dm, dt) = delta.split_at(main);
    for (sc, dc) in sm.chunks_exact_mut(LANES).zip(dm.chunks_exact(LANES)) {
        let sc: &mut [f32; LANES] = sc.try_into().unwrap();
        let dc: &[f32; LANES] = dc.try_into().unwrap();
        for i in 0..LANES {
            sc[i] += (dc[i] as f64 * w) as f32;
        }
    }
    for (s, &d) in st.iter_mut().zip(dt) {
        *s += (d as f64 * w) as f32;
    }
}

/// Scalar reference for [`accumulate`].
pub fn accumulate_scalar(sum: &mut [f32], delta: &[f32], w: f64) {
    assert_eq!(sum.len(), delta.len());
    for (s, &d) in sum.iter_mut().zip(delta) {
        *s += (d as f64 * w) as f32;
    }
}

/// `out[i] = src[i] * factor` into a reused buffer (the allocation-free
/// form of `OuterAccumulator::average`).
pub fn scale_into(src: &[f32], factor: f32, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(src.len());
    let main = src.len() - src.len() % LANES;
    for sc in src[..main].chunks_exact(LANES) {
        let sc: &[f32; LANES] = sc.try_into().unwrap();
        let mut block = [0.0f32; LANES];
        for i in 0..LANES {
            block[i] = sc[i] * factor;
        }
        out.extend_from_slice(&block);
    }
    for &s in &src[main..] {
        out.push(s * factor);
    }
}

/// AdamW update, fused: bias corrections `1 - b^step` are hoisted out of
/// the loop (they are loop-invariant — the scalar reference recomputes
/// `powf` per element, which costs more than the rest of the update
/// combined), every per-element op is unchanged.
#[allow(clippy::too_many_arguments)]
pub fn adamw(
    theta: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    decay_mask: &[f32],
    step: f32,
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
    wd: f32,
) {
    assert_eq!(theta.len(), m.len());
    assert_eq!(theta.len(), v.len());
    assert_eq!(theta.len(), g.len());
    assert_eq!(theta.len(), decay_mask.len());
    let bc1 = 1.0 - b1.powf(step);
    let bc2 = 1.0 - b2.powf(step);
    let main = theta.len() - theta.len() % LANES;
    let (tm, tt) = theta.split_at_mut(main);
    let (mm, mt) = m.split_at_mut(main);
    let (vm, vt) = v.split_at_mut(main);
    let (gm, gt) = g.split_at(main);
    let (km, kt) = decay_mask.split_at(main);
    for ((((tc, mc), vc), gc), kc) in tm
        .chunks_exact_mut(LANES)
        .zip(mm.chunks_exact_mut(LANES))
        .zip(vm.chunks_exact_mut(LANES))
        .zip(gm.chunks_exact(LANES))
        .zip(km.chunks_exact(LANES))
    {
        let tc: &mut [f32; LANES] = tc.try_into().unwrap();
        let mc: &mut [f32; LANES] = mc.try_into().unwrap();
        let vc: &mut [f32; LANES] = vc.try_into().unwrap();
        let gc: &[f32; LANES] = gc.try_into().unwrap();
        let kc: &[f32; LANES] = kc.try_into().unwrap();
        for i in 0..LANES {
            mc[i] = b1 * mc[i] + (1.0 - b1) * gc[i];
            vc[i] = b2 * vc[i] + (1.0 - b2) * gc[i] * gc[i];
            let mhat = mc[i] / bc1;
            let vhat = vc[i] / bc2;
            tc[i] -= lr * (mhat / (vhat.sqrt() + eps) + wd * kc[i] * tc[i]);
        }
    }
    for i in 0..tt.len() {
        mt[i] = b1 * mt[i] + (1.0 - b1) * gt[i];
        vt[i] = b2 * vt[i] + (1.0 - b2) * gt[i] * gt[i];
        let mhat = mt[i] / bc1;
        let vhat = vt[i] / bc2;
        tt[i] -= lr * (mhat / (vhat.sqrt() + eps) + wd * kt[i] * tt[i]);
    }
}

/// Scalar reference for [`adamw`] — the original per-element loop with
/// `powf` recomputed per element, exactly as `train/sync.rs` shipped it.
#[allow(clippy::too_many_arguments)]
pub fn adamw_scalar(
    theta: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    decay_mask: &[f32],
    step: f32,
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
    wd: f32,
) {
    for i in 0..theta.len() {
        m[i] = b1 * m[i] + (1.0 - b1) * g[i];
        v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
        let mhat = m[i] / (1.0 - b1.powf(step));
        let vhat = v[i] / (1.0 - b2.powf(step));
        theta[i] -= lr * (mhat / (vhat.sqrt() + eps) + wd * decay_mask[i] * theta[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, gens};

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    // Sizes straddling the chunk width: empty, sub-chunk, exact multiples,
    // and off-by-one tails around them.
    fn gen_len(rng: &mut crate::util::rng::Rng) -> usize {
        match rng.gen_range(4) {
            0 => rng.gen_range(LANES), // 0..LANES: pure tail
            1 => LANES * (1 + rng.gen_range(4)), // exact multiple
            2 => LANES * (1 + rng.gen_range(4)) + 1 + rng.gen_range(LANES - 1),
            _ => 1 + rng.gen_range(1000),
        }
    }

    #[test]
    fn nesterov_fused_is_bit_identical() {
        forall(
            "fused nesterov == scalar nesterov (bitwise)",
            101,
            60,
            |rng| {
                let n = gen_len(rng);
                (
                    gens::f32_vec(rng, n, 1.0),
                    gens::f32_vec(rng, n, 0.5),
                    gens::f32_vec(rng, n, 0.1),
                    rng.f64() as f32,
                    rng.f64() as f32,
                )
            },
            |(p0, v0, g, lr, mu)| {
                let (mut pa, mut va) = (p0.clone(), v0.clone());
                let (mut pb, mut vb) = (p0.clone(), v0.clone());
                nesterov_step(&mut pa, &mut va, g, *lr, *mu);
                nesterov_scalar(&mut pb, &mut vb, g, *lr, *mu);
                if bits(&pa) != bits(&pb) || bits(&va) != bits(&vb) {
                    return Err(format!("diverged at n={}", p0.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn accumulate_fused_is_bit_identical() {
        forall(
            "fused accumulate == scalar accumulate (bitwise)",
            202,
            60,
            |rng| {
                let n = gen_len(rng);
                (
                    gens::f32_vec(rng, n, 1.0),
                    gens::f32_vec(rng, n, 1.0),
                    1.0 + rng.f64() * 100.0,
                )
            },
            |(s0, d, w)| {
                let mut a = s0.clone();
                let mut b = s0.clone();
                accumulate(&mut a, d, *w);
                accumulate_scalar(&mut b, d, *w);
                if bits(&a) != bits(&b) {
                    return Err(format!("diverged at n={}", s0.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn scale_into_is_bit_identical_and_reuses_buffer() {
        forall(
            "scale_into == map-collect scale (bitwise)",
            303,
            60,
            |rng| (gens::f32_vec(rng, gen_len(rng), 2.0), rng.f64() as f32),
            |(src, factor)| {
                let want: Vec<f32> = src.iter().map(|&s| s * factor).collect();
                let mut out = vec![7.0f32; 3]; // dirty, wrong-sized buffer
                scale_into(src, *factor, &mut out);
                if bits(&out) != bits(&want) {
                    return Err(format!("diverged at n={}", src.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn adamw_fused_is_bit_identical() {
        forall(
            "fused adamw == scalar adamw (bitwise)",
            404,
            40,
            |rng| {
                let n = gen_len(rng);
                let mask: Vec<f32> = (0..n).map(|_| (rng.gen_range(2)) as f32).collect();
                (
                    gens::f32_vec(rng, n, 1.0),
                    gens::f32_vec(rng, n, 0.1),
                    (0..n)
                        .map(|_| rng.normal_f32(0.0, 0.1).abs())
                        .collect::<Vec<f32>>(),
                    gens::f32_vec(rng, n, 0.5),
                    mask,
                    1.0 + rng.gen_range(500) as f32,
                )
            },
            |(t0, m0, v0, g, mask, step)| {
                let (mut ta, mut ma, mut va) = (t0.clone(), m0.clone(), v0.clone());
                let (mut tb, mut mb, mut vb) = (t0.clone(), m0.clone(), v0.clone());
                adamw(
                    &mut ta, &mut ma, &mut va, g, mask, *step, 1e-3, 0.9, 0.999, 1e-8, 0.1,
                );
                adamw_scalar(
                    &mut tb, &mut mb, &mut vb, g, mask, *step, 1e-3, 0.9, 0.999, 1e-8, 0.1,
                );
                if bits(&ta) != bits(&tb) || bits(&ma) != bits(&mb) || bits(&va) != bits(&vb) {
                    return Err(format!("diverged at n={} step={step}", t0.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tail_only_and_empty_inputs() {
        // Degenerate shapes the chunked split must handle: 0 and < LANES.
        for n in [0usize, 1, LANES - 1, LANES, LANES + 1] {
            let g: Vec<f32> = (0..n).map(|i| i as f32 * 0.25 - 1.0).collect();
            let (mut pa, mut va) = (vec![1.0f32; n], vec![0.5f32; n]);
            let (mut pb, mut vb) = (vec![1.0f32; n], vec![0.5f32; n]);
            nesterov_step(&mut pa, &mut va, &g, 0.7, 0.9);
            nesterov_scalar(&mut pb, &mut vb, &g, 0.7, 0.9);
            assert_eq!(bits(&pa), bits(&pb), "n={n}");
            assert_eq!(bits(&va), bits(&vb), "n={n}");
        }
    }
}
