//! Tiny CLI argument parser (clap is not vendored).
//!
//! Grammar: `dipaco <subcommand> [--key value]... [--flag]...`
//! Values never start with `--`; everything else is a positional.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.options.insert(key.to_string(), v);
                    }
                    _ => out.flags.push(key.to_string()),
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a float, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train extra --steps 100 --preset path --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize("steps", 0), 100);
        assert_eq!(a.get("preset"), Some("path"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse("eval");
        assert_eq!(a.usize("steps", 7), 7);
        assert_eq!(a.f64("lr", 0.5), 0.5);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("x --a 1 --b");
        assert_eq!(a.get("a"), Some("1"));
        assert!(a.flag("b"));
    }
}
