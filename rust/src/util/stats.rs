//! Small statistics helpers shared by metrics, eval, and the bench harness.

/// Streaming mean/variance (Welford).
#[derive(Debug, Default, Clone)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Percentile by linear interpolation, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Exponential moving average over a series (for loss-curve smoothing).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

/// Perplexity from summed negative log-likelihood over `tokens` tokens.
pub fn ppl(total_nll: f64, tokens: f64) -> f64 {
    (total_nll / tokens.max(1.0)).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ema_smooths() {
        let xs = [0.0, 10.0, 10.0, 10.0];
        let e = ema(&xs, 0.5);
        assert_eq!(e[0], 0.0);
        assert!(e[1] > 0.0 && e[1] < 10.0);
        assert!(e[3] > e[1]);
    }

    #[test]
    fn ppl_identity() {
        // nll = ln(4) per token -> ppl 4
        assert!((ppl(4.0 * 4f64.ln(), 4.0) - 4.0).abs() < 1e-12);
    }
}
