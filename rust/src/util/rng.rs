//! Deterministic RNG substrate (the `rand` crate is not vendored).
//!
//! xoshiro256** seeded via SplitMix64, with the distributions this project
//! needs: uniform ints/floats, normals (Box–Muller), shuffles, categorical
//! sampling, and stream forking so every worker / shard / domain derives an
//! independent, reproducible stream from a run seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream, e.g. `run_rng.fork(path_id as u64)`.
    pub fn fork(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        Rng::new(splitmix64(&mut sm))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`; unbiased via rejection.
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.gen_range(i + 1));
        }
    }

    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.gen_range(v.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive mass");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// `k` distinct indices from `[0, n)` (floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j + 1);
            if out.contains(&t) {
                out.push(j);
            } else {
                out.push(t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn fork_streams_are_independent() {
        let root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
        // deterministic per stream id
        let mut a2 = root.fork(0);
        assert_eq!(xs[0], a2.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.gen_range(7);
            assert!(v < 7);
        }
        let u = r.f64();
        assert!((0.0..1.0).contains(&u));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 3];
        for _ in 0..30000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!((counts[2] as f64 / 30000.0 - 0.7).abs() < 0.02);
        assert!((counts[0] as f64 / 30000.0 - 0.1).abs() < 0.02);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let idx = r.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(s.iter().all(|&i| i < 100));
    }
}
