//! Minimal JSON parser/serializer (serde is not in the vendored crate set).
//!
//! Used for: `artifacts/<preset>/manifest.json`, run configs, the
//! checkpoint-metadata DB persistence, and `results/*.json` summaries.
//! Supports the full JSON grammar except `\u` surrogate pairs are combined
//! but lone surrogates are replaced with U+FFFD.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing field {key:?}"),
            pos: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    // -------------------------------------------------------------- parse

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: input.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---------------------------------------------------------- serialize

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    nl(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    nl(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn nl(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let code = 0x10000
                                        + ((hi - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00) & 0x3FF);
                                    char::from_u32(code).unwrap_or('\u{FFFD}')
                                } else {
                                    '\u{FFFD}'
                                }
                            } else {
                                char::from_u32(hi).unwrap_or('\u{FFFD}')
                            };
                            out.push(c);
                            continue; // hex4 advanced past the digits
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"block0.attn.wq","offset":1024,"shape":[64,64],"f":-0.25,"ok":true,"s":"\"quoted\" \\ path\n"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = Json::Num(1048576.0);
        assert_eq!(v.to_string(), "1048576");
        assert_eq!(Json::parse("1048576").unwrap().as_usize(), Some(1048576));
    }
}
