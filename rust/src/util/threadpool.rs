//! Thread-pool / parallel-map substrate (tokio is not vendored).
//!
//! The coordinator runs dedicated threads for long-lived actors (workers,
//! executors, monitor); this module provides the shared utilities: a
//! fixed-size pool for fire-and-forget jobs and a chunked `parallel_map`
//! built on `std::thread::scope` for data-parallel phases (feature
//! extraction, evaluation fan-out).

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    sender: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool {
            sender: Some(tx),
            handles,
        }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Drop the sender and join all workers.
    pub fn join(mut self) {
        self.sender.take();
        for h in self.handles.drain(..) {
            h.join().expect("pool worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.sender.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Parallel map preserving input order. `threads == 1` runs inline, which
/// keeps small jobs cheap and makes tests deterministic.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let n = items.len();
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads.min(n));
    std::thread::scope(|s| {
        for (items_chunk, out_chunk) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
            let f = &f;
            s.spawn(move || {
                for (item, slot) in items_chunk.iter().zip(out_chunk.iter_mut()) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..137).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_empty() {
        let items: Vec<i32> = vec![];
        assert!(parallel_map(&items, 4, |&x| x).is_empty());
    }
}
