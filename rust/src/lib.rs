//! # DiPaCo: Distributed Path Composition — reproduction library
//!
//! Rust L3 coordinator for the DiPaCo system (Douillard et al., 2024):
//! modular sparsely-activated language models whose *paths* (compositions
//! of per-level expert modules) are trained almost independently on
//! pre-sharded data and kept in sync with per-module DiLoCo outer
//! optimization.
//!
//! The compute (L2 transformer + L1 Pallas attention kernel) is AOT-lowered
//! from JAX to HLO text at build time (`make artifacts`) and executed here
//! via PJRT ([`runtime::engine::Engine`]); Python never runs after that.
//!
//! Layer map (see DESIGN.md for the full inventory):
//! * [`util`] — substrates built in-repo because only the `xla` crate's
//!   dependency closure is vendored: JSON, RNG, CLI, thread pool, stats,
//!   logging, keyed barrier, buffer pool, fused f32 kernels.
//! * [`data`] — byte tokenizer, synthetic multi-domain corpus (the C4
//!   substitution), sequence packing, shard storage.
//! * [`routing`] — coarse offline routing: k-means / product k-means
//!   (generative), multinomial logistic regression (discriminative),
//!   EM alternation, overlapping shards, eval-time chunked re-routing.
//! * [`params`] / [`topology`] — flat-parameter manifest, module/level/path
//!   algebra, per-path parameter assembly and per-module delta splitting.
//! * [`optim`] — per-module Nesterov outer optimizer with outer-gradient
//!   norm rescaling and shard-size loss reweighing (paper §2.7).
//! * [`runtime`] — PJRT engine loading `artifacts/*.hlo.txt`.
//! * [`coordinator`] — the paper's §3 infrastructure: fault-tolerant task
//!   queue (ack/nack leases, retry-after delays, idempotency keys,
//!   priority lanes), worker pool (+ backup pool, preemption injection),
//!   checkpoint DB, sharded outer-optimization executors with online
//!   averaging, health monitor, phase orchestration of Algorithm 1.
//! * [`transport`] — the section exchange plane (ROADMAP item 2): a
//!   [`transport::SectionTransport`] trait over how published `delta:`
//!   sections travel from workers to executors — the local
//!   shared-filesystem plane (byte-identical to mapping the DPC2 file)
//!   and a framed-TCP plane with fletcher64-verified length-prefixed
//!   frames, a module-shard rendezvous registry, timeouts, and
//!   capped-backoff retry.
//! * [`chaos`] — fault-injection harness: seeded fault plans, an injector
//!   threaded through worker/publication hooks, a DPC2 corruptor, an
//!   engine-free coordinator simulation, and convergence-equivalence
//!   oracles demanding bit-identical recovery or loud abort. Also covers
//!   the serving plane: executor panic/wedge/slow-batch fault plans with
//!   no-hung-ticket oracles.
//! * [`train`] — end-to-end pipelines: dense baseline, DiLoCo, flat MoE,
//!   DiPaCo, and the fully-synchronous ablation (§4.5).
//! * [`eval`] — validation perplexity (prefix-masked), frequent re-routing,
//!   early stopping.
//! * [`serve`] — test-time path serving (paper §2.6): per-document router
//!   admission, bounded per-path queues, deadline micro-batching, one
//!   path-server worker per path owning only its own theta. Self-healing:
//!   supervised workers (panic capture + backoff restarts), per-path
//!   circuit breakers, and degraded-mode routing to the router's
//!   runner-up path with deadline-based load shedding.
//! * [`benchkit`] / [`testkit`] — criterion/proptest stand-ins.

pub mod util {
    pub mod barrier;
    pub mod cli;
    pub mod json;
    pub mod kernels;
    pub mod log;
    pub mod pool;
    pub mod rng;
    pub mod stats;
    pub mod threadpool;
}

pub mod config;

pub mod data {
    pub mod corpus;
    pub mod dataset;
    pub mod synth;
    pub mod tokenizer;
}

pub mod routing {
    pub mod features;
    pub mod kmeans;
    pub mod logistic;
    pub mod router;
}

pub mod params {
    pub mod checkpoint;
    pub mod manifest;
}

pub mod topology;

pub mod optim;

pub mod runtime {
    pub mod engine;
}

pub mod coordinator {
    pub mod db;
    pub mod monitor;
    pub mod outer;
    pub mod phases;
    pub mod queue;
    pub mod task;
    pub mod worker;
}

pub mod transport {
    pub mod frame;
    pub mod local;
    pub mod rendezvous;
    pub mod tcp;

    mod plane;
    pub use plane::{open_source, PublishCtx, SectionSource, SectionTransport};
}

pub mod chaos {
    pub mod corruptor;
    pub mod injector;
    pub mod oracle;
    pub mod plan;
    pub mod sim;
}

pub mod train {
    pub mod dense;
    pub mod dipaco;
    pub mod pipeline;
    pub mod sync;
}

pub mod eval;
pub mod metrics;

pub mod serve {
    pub mod batcher;
    pub mod breaker;
    pub mod request;
    pub mod server;
    pub mod stats;
    pub mod supervisor;
}

pub mod benchkit;
pub mod testkit;
