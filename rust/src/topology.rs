//! Module / level / path algebra — the heart of DiPaCo (paper §2.3, §2.6).
//!
//! A [`Topology`] partitions the flat parameter vector into *levels* (sets
//! of leaf ranges), gives each level `K_l` expert modules, and defines the
//! path set `P = prod K_l` over the grid levels. Special levels:
//!
//! * the **stem** (embedding, final LN, head) is either shared by all
//!   paths (K=1) or path-specific (K=P, never communicated — paper §4.2);
//! * **path-specific blocks** (paper §2.6.1 / Figure 5) form a K=P level;
//! * a 1-level K=1 topology is exactly DiLoCo; a 1-level K=P topology
//!   with a path-specific stem is the flat MoE baseline (§2.6.3).
//!
//! [`ModuleStore`] owns the global copy of every module's parameters and
//! performs the two hot operations: *assemble* (modules -> theta_path, run
//! before each inner phase) and *split* (Delta theta_path -> per-module
//! outer gradients, run after).

use crate::config::{StemPlacement, TopologySpec};
use crate::params::checkpoint::Checkpoint;
use crate::params::manifest::Manifest;
use std::collections::HashMap;
use std::ops::Range;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModuleId {
    pub level: usize,
    pub expert: usize,
}

impl std::fmt::Display for ModuleId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}E{}", self.level, self.expert)
    }
}

impl ModuleId {
    /// Parse the canonical `L{l}E{e}` form (inverse of `Display`).
    pub fn parse(s: &str) -> Option<ModuleId> {
        let rest = s.strip_prefix('L')?;
        let (l, e) = rest.split_once('E')?;
        Some(ModuleId {
            level: l.parse().ok()?,
            expert: e.parse().ok()?,
        })
    }

    /// DPC2 checkpoint section carrying this module's outer gradient
    /// (`delta:L{l}E{e}` — the worker->executor exchange unit).
    pub fn delta_section(&self) -> String {
        format!("delta:{self}")
    }

    /// Inverse of [`ModuleId::delta_section`].
    pub fn parse_delta_section(name: &str) -> Option<ModuleId> {
        ModuleId::parse(name.strip_prefix("delta:")?)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum LevelKind {
    /// Mixed-radix grid dimension `dim` (0-based) of the DiPaCo grid.
    Grid { dim: usize },
    /// Stem shared by all paths (K = 1).
    SharedStem,
    /// One private copy per path (K = P): path-specific stem or blocks.
    PathSpecific,
}

#[derive(Debug, Clone)]
pub struct Level {
    pub name: String,
    pub kind: LevelKind,
    pub k: usize,
    /// Theta ranges owned by this level, ascending and disjoint.
    pub segments: Vec<Range<usize>>,
    /// Total floats per module of this level.
    pub size: usize,
}

#[derive(Debug, Clone)]
pub struct Topology {
    pub levels: Vec<Level>,
    pub paths: usize,
    pub total_params: usize,
    /// Experts per grid dimension, most-significant first.
    grid_dims: Vec<usize>,
    /// prod(grid_dims); replicas share grid assignments modulo this.
    grid_paths: usize,
}

impl Topology {
    pub fn build(manifest: &Manifest, spec: &TopologySpec) -> Topology {
        let n_blocks = manifest.model.n_layers;
        let n_grid = spec.experts_per_level.len();
        assert!(n_grid >= 1, "need at least one level");
        assert!(
            spec.experts_per_level.iter().all(|&k| k >= 1),
            "expert counts must be >= 1"
        );
        let grid_paths: usize = spec.experts_per_level.iter().product();
        let paths = grid_paths * spec.replicas.max(1);

        // Blocks not claimed as path-specific, split evenly (front-loaded)
        // across grid levels in order.
        let shared_blocks: Vec<usize> = (0..n_blocks)
            .filter(|b| !spec.path_specific_blocks.contains(b))
            .collect();
        assert!(
            shared_blocks.len() >= n_grid,
            "fewer shared blocks than levels"
        );
        let per = shared_blocks.len() / n_grid;
        let extra = shared_blocks.len() % n_grid;

        let segs_for_blocks = |blocks: &[usize]| -> Vec<Range<usize>> {
            let mut segs: Vec<Range<usize>> = Vec::new();
            for &b in blocks {
                for leaf in manifest.block_leaves(b) {
                    segs.push(leaf.range());
                }
            }
            coalesce(segs)
        };

        let mut levels = Vec::new();

        // Stem level.
        let stem_segs = coalesce(
            manifest
                .stem_leaves()
                .iter()
                .map(|l| l.range())
                .collect(),
        );
        let stem_size: usize = stem_segs.iter().map(|r| r.len()).sum();
        levels.push(Level {
            name: "stem".into(),
            kind: match spec.stem {
                StemPlacement::Shared => LevelKind::SharedStem,
                StemPlacement::PathSpecific => LevelKind::PathSpecific,
            },
            k: match spec.stem {
                StemPlacement::Shared => 1,
                StemPlacement::PathSpecific => paths,
            },
            segments: stem_segs,
            size: stem_size,
        });

        // Grid levels over consecutive chunks of shared blocks.
        let mut cursor = 0usize;
        for (dim, &k) in spec.experts_per_level.iter().enumerate() {
            let take = per + usize::from(dim < extra);
            let blocks = &shared_blocks[cursor..cursor + take];
            cursor += take;
            let segments = segs_for_blocks(blocks);
            let size = segments.iter().map(|r| r.len()).sum();
            levels.push(Level {
                name: format!("level{dim}(blocks {blocks:?})"),
                kind: LevelKind::Grid { dim },
                k,
                segments,
                size,
            });
        }

        // Path-specific blocks level.
        if !spec.path_specific_blocks.is_empty() {
            let mut blocks = spec.path_specific_blocks.clone();
            blocks.sort_unstable();
            blocks.dedup();
            let segments = segs_for_blocks(&blocks);
            let size = segments.iter().map(|r| r.len()).sum();
            levels.push(Level {
                name: format!("path_specific(blocks {blocks:?})"),
                kind: LevelKind::PathSpecific,
                k: paths,
                segments,
                size,
            });
        }

        let topo = Topology {
            levels,
            paths,
            total_params: manifest.total_params,
            grid_dims: spec.experts_per_level.clone(),
            grid_paths,
        };
        debug_assert_eq!(topo.covered_params(), manifest.total_params);
        topo
    }

    fn covered_params(&self) -> usize {
        self.levels
            .iter()
            .map(|l| l.segments.iter().map(|r| r.len()).sum::<usize>())
            .sum()
    }

    /// Which expert of `level` path `path` uses.
    pub fn expert_of(&self, path: usize, level: usize) -> usize {
        debug_assert!(path < self.paths);
        match self.levels[level].kind {
            LevelKind::SharedStem => 0,
            LevelKind::PathSpecific => path,
            LevelKind::Grid { dim } => {
                // mixed radix over path % grid_paths (replicas repeat the
                // grid pattern), most-significant dim first.
                let q = path % self.grid_paths;
                let mut stride = 1usize;
                for &k in &self.grid_dims[dim + 1..] {
                    stride *= k;
                }
                (q / stride) % self.grid_dims[dim]
            }
        }
    }

    /// Module ids a path traverses, one per level.
    pub fn modules_of_path(&self, path: usize) -> Vec<ModuleId> {
        (0..self.levels.len())
            .map(|l| ModuleId {
                level: l,
                expert: self.expert_of(path, l),
            })
            .collect()
    }

    /// All module ids in the topology.
    pub fn all_modules(&self) -> Vec<ModuleId> {
        let mut out = Vec::new();
        for (l, level) in self.levels.iter().enumerate() {
            for e in 0..level.k {
                out.push(ModuleId { level: l, expert: e });
            }
        }
        out
    }

    /// Paths through module (paper: P_{l,e}); uniform across experts of a
    /// level by construction.
    pub fn paths_through(&self, m: ModuleId) -> usize {
        self.paths / self.levels[m.level].k
    }

    /// Paths that traverse the given module.
    pub fn paths_of_module(&self, m: ModuleId) -> Vec<usize> {
        (0..self.paths)
            .filter(|&p| self.expert_of(p, m.level) == m.expert)
            .collect()
    }

    /// Gather a level's segments from a flat vector.
    pub fn extract(&self, level: usize, theta: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.extract_into(level, theta, &mut out);
        out
    }

    /// [`Topology::extract`] into a reused buffer — the per-phase hot
    /// paths call this once per module per path and must not allocate a
    /// fresh vector each time.
    pub fn extract_into(&self, level: usize, theta: &[f32], out: &mut Vec<f32>) {
        let lv = &self.levels[level];
        out.clear();
        out.reserve(lv.size);
        for r in &lv.segments {
            out.extend_from_slice(&theta[r.clone()]);
        }
    }

    /// Assemble a path's theta from the module store into a reused buffer
    /// (no `total_params` allocation per path).
    pub fn assemble_into(&self, store: &ModuleStore, path: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.total_params, 0.0);
        for m in self.modules_of_path(path) {
            self.scatter(m.level, store.get(m), out);
        }
    }

    /// Worker-side outer gradients for one path: per traversed module, the
    /// slices of `before - after` (paper Algorithm 1 line 13). Subtraction
    /// happens segment-by-segment — no `total_params`-sized intermediate.
    pub fn split_delta(
        &self,
        path: usize,
        before: &[f32],
        after: &[f32],
    ) -> Vec<(ModuleId, Vec<f32>)> {
        debug_assert_eq!(before.len(), after.len());
        self.modules_of_path(path)
            .into_iter()
            .map(|m| {
                let lv = &self.levels[m.level];
                let mut delta = Vec::with_capacity(lv.size);
                for r in &lv.segments {
                    delta.extend(
                        before[r.clone()]
                            .iter()
                            .zip(&after[r.clone()])
                            .map(|(b, a)| b - a),
                    );
                }
                (m, delta)
            })
            .collect()
    }

    /// The worker->executor exchange unit for one path: a checkpoint with
    /// one `delta:L{l}E{e}` section per traversed module, plus the module
    /// list for the DB row's metadata. The single writer of this layout —
    /// the production worker, the outer tests, and the benches all build
    /// their files here so the format can't silently diverge.
    pub fn delta_checkpoint(
        &self,
        path: usize,
        before: &[f32],
        after: &[f32],
    ) -> (Checkpoint, Vec<ModuleId>) {
        let parts = self.split_delta(path, before, after);
        let mut modules = Vec::with_capacity(parts.len());
        let mut ck = Checkpoint::new();
        for (mid, delta) in parts {
            modules.push(mid);
            ck = ck.with(&mid.delta_section(), delta);
        }
        (ck, modules)
    }

    /// One module's `before - after` delta into a reused buffer — the
    /// single-module counterpart of [`Topology::split_delta`], used by
    /// the streaming worker to publish a group without computing the
    /// remaining modules' deltas yet.
    pub fn module_delta_into(&self, m: ModuleId, before: &[f32], after: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(before.len(), after.len());
        let lv = &self.levels[m.level];
        out.clear();
        out.reserve(lv.size);
        for r in &lv.segments {
            out.extend(
                before[r.clone()]
                    .iter()
                    .zip(&after[r.clone()])
                    .map(|(b, a)| b - a),
            );
        }
    }

    /// Split a path's traversed modules into `groups` contiguous
    /// level-order chunks for staggered publication: group `g` publishes
    /// as soon as inner-step boundary `g` passes. `groups` is clamped to
    /// `[1, modules]`; when modules don't divide evenly the extra modules
    /// go to the EARLIER groups, so later (still-training) groups stay
    /// small and the tail publish is cheap.
    pub fn publish_groups(&self, path: usize, groups: usize) -> Vec<Vec<ModuleId>> {
        let mods = self.modules_of_path(path);
        let g = groups.clamp(1, mods.len());
        let base = mods.len() / g;
        let extra = mods.len() % g;
        let mut out = Vec::with_capacity(g);
        let mut it = mods.into_iter();
        for i in 0..g {
            let take = base + usize::from(i < extra);
            out.push(it.by_ref().take(take).collect());
        }
        out
    }

    /// Scatter module data back into a flat vector.
    pub fn scatter(&self, level: usize, data: &[f32], theta: &mut [f32]) {
        let lv = &self.levels[level];
        debug_assert_eq!(data.len(), lv.size);
        let mut pos = 0;
        for r in &lv.segments {
            theta[r.clone()].copy_from_slice(&data[pos..pos + r.len()]);
            pos += r.len();
        }
    }

    /// Total parameters of the whole mixture (the paper's "Total
    /// Parameters" column in Table 1): each module counted once.
    pub fn mixture_params(&self) -> usize {
        self.levels.iter().map(|l| l.k * l.size).sum()
    }
}

fn coalesce(mut segs: Vec<Range<usize>>) -> Vec<Range<usize>> {
    segs.sort_by_key(|r| r.start);
    let mut out: Vec<Range<usize>> = Vec::new();
    for s in segs {
        match out.last_mut() {
            Some(last) if last.end == s.start => last.end = s.end,
            _ => out.push(s),
        }
    }
    out
}

/// Global copy of every module's parameters (paper: theta(l,e) without the
/// path index) plus assembly/splitting between module space and path space.
#[derive(Debug, Clone)]
pub struct ModuleStore {
    pub modules: HashMap<ModuleId, Vec<f32>>,
}

impl ModuleStore {
    /// Initialize every module from a single base theta (paper Algorithm 1:
    /// all paths start from the pretrained model).
    pub fn from_base(topo: &Topology, theta: &[f32]) -> ModuleStore {
        assert_eq!(theta.len(), topo.total_params);
        let mut modules = HashMap::new();
        for m in topo.all_modules() {
            modules.insert(m, topo.extract(m.level, theta));
        }
        ModuleStore { modules }
    }

    /// theta for a path: gather its module of each level.
    pub fn assemble(&self, topo: &Topology, path: usize) -> Vec<f32> {
        let mut theta = Vec::new();
        topo.assemble_into(self, path, &mut theta);
        theta
    }

    pub fn get(&self, m: ModuleId) -> &[f32] {
        &self.modules[&m]
    }

    pub fn get_mut(&mut self, m: ModuleId) -> &mut Vec<f32> {
        self.modules.get_mut(&m).expect("unknown module")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn manifest() -> Manifest {
        let j = crate::params::manifest::tests::fake_manifest_json(4, 8);
        Manifest::from_json(&Json::parse(&j).unwrap()).unwrap()
    }

    #[test]
    fn grid_2x2_structure() {
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::grid(vec![2, 2]));
        assert_eq!(t.paths, 4);
        assert_eq!(t.levels.len(), 3); // stem + 2 grid
        assert_eq!(t.levels[0].k, 1);
        assert_eq!(t.levels[1].k, 2);
        // coverage: every param in exactly one level
        let mut seen = vec![0u8; m.total_params];
        for l in &t.levels {
            for r in &l.segments {
                for i in r.clone() {
                    seen[i] += 1;
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn publish_groups_partition_modules_in_order() {
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::grid(vec![2, 2]));
        for path in 0..t.paths {
            let mods = t.modules_of_path(path);
            for groups in [0, 1, 2, mods.len(), mods.len() + 3] {
                let gs = t.publish_groups(path, groups);
                assert_eq!(gs.len(), groups.clamp(1, mods.len()));
                assert!(gs.iter().all(|g| !g.is_empty()));
                // concatenation == modules_of_path, same order
                let flat: Vec<ModuleId> = gs.concat();
                assert_eq!(flat, mods);
                // front-loaded: group sizes are non-increasing
                for w in gs.windows(2) {
                    assert!(w[0].len() >= w[1].len());
                }
            }
        }
    }

    #[test]
    fn module_delta_into_matches_split_delta() {
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::grid(vec![2, 2]));
        let before: Vec<f32> = (0..m.total_params).map(|i| (i % 13) as f32 * 0.1).collect();
        let after: Vec<f32> = before.iter().map(|v| v * 0.99 + 0.01).collect();
        for path in 0..t.paths {
            let whole = t.split_delta(path, &before, &after);
            let mut buf = Vec::new();
            for (mid, delta) in whole {
                t.module_delta_into(mid, &before, &after, &mut buf);
                assert_eq!(buf, delta, "module {mid} delta mismatch");
            }
        }
    }

    #[test]
    fn mixed_radix_expert_assignment() {
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::grid(vec![2, 2]));
        // level indices: 0 stem, 1 dim0, 2 dim1
        let digits: Vec<(usize, usize)> = (0..4)
            .map(|p| (t.expert_of(p, 1), t.expert_of(p, 2)))
            .collect();
        assert_eq!(digits, vec![(0, 0), (0, 1), (1, 0), (1, 1)]);
        for p in 0..4 {
            assert_eq!(t.expert_of(p, 0), 0); // shared stem
        }
    }

    #[test]
    fn paths_through_counts() {
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::grid(vec![2, 2]));
        assert_eq!(t.paths_through(ModuleId { level: 0, expert: 0 }), 4);
        assert_eq!(t.paths_through(ModuleId { level: 1, expert: 0 }), 2);
        let p = t.paths_of_module(ModuleId { level: 1, expert: 1 });
        assert_eq!(p, vec![2, 3]);
    }

    #[test]
    fn flat_moe_is_fully_path_specific() {
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::flat_moe(8));
        assert_eq!(t.paths, 8);
        for l in &t.levels {
            assert_eq!(l.k, if matches!(l.kind, LevelKind::Grid { .. }) { 8 } else { 8 });
        }
        // mixture has 8 full copies
        assert_eq!(t.mixture_params(), 8 * m.total_params);
    }

    #[test]
    fn diloco_collapses_everything() {
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::diloco(4));
        assert_eq!(t.paths, 4);
        // every module is shared by all 4 replicas
        assert_eq!(t.mixture_params(), m.total_params);
        for mid in t.all_modules() {
            assert_eq!(t.paths_through(mid), 4);
        }
        // all replicas assemble the identical theta
        let theta: Vec<f32> = (0..m.total_params).map(|i| i as f32).collect();
        let store = ModuleStore::from_base(&t, &theta);
        assert_eq!(store.assemble(&t, 0), store.assemble(&t, 3));
    }

    #[test]
    fn path_specific_blocks_form_level() {
        let m = manifest();
        let mut spec = TopologySpec::grid(vec![2]);
        spec.path_specific_blocks = vec![0, 3];
        let t = Topology::build(&m, &spec);
        assert_eq!(t.levels.len(), 3);
        let ps = t.levels.last().unwrap();
        assert!(matches!(ps.kind, LevelKind::PathSpecific));
        assert_eq!(ps.k, 2);
        // grid level only covers blocks 1,2
        assert_eq!(t.paths_through(ModuleId { level: 2, expert: 0 }), 1);
    }

    #[test]
    fn assemble_identity_from_base() {
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::grid(vec![2, 2]));
        let theta: Vec<f32> = (0..m.total_params).map(|i| i as f32).collect();
        let store = ModuleStore::from_base(&t, &theta);
        for p in 0..t.paths {
            assert_eq!(store.assemble(&t, p), theta, "path {p}");
        }
    }

    #[test]
    fn split_delta_roundtrip() {
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::grid(vec![2, 2]));
        let before: Vec<f32> = (0..m.total_params).map(|i| i as f32).collect();
        let after: Vec<f32> = before.iter().map(|v| v * 0.5 + 1.0).collect();
        let parts = t.split_delta(3, &before, &after);
        // scatter all parts back: must equal before-after elementwise
        let mut recon = vec![0.0f32; m.total_params];
        for (mid, data) in &parts {
            t.scatter(mid.level, data, &mut recon);
        }
        for i in 0..recon.len() {
            assert!((recon[i] - (before[i] - after[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn module_id_parse_roundtrip() {
        let m = ModuleId { level: 3, expert: 11 };
        assert_eq!(ModuleId::parse(&m.to_string()), Some(m));
        assert_eq!(ModuleId::parse_delta_section(&m.delta_section()), Some(m));
        assert_eq!(ModuleId::parse("E1L2"), None);
        assert_eq!(ModuleId::parse_delta_section("theta"), None);
    }

    #[test]
    fn into_variants_match_allocating_ones() {
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::grid(vec![2, 2]));
        let theta: Vec<f32> = (0..m.total_params).map(|i| (i % 13) as f32).collect();
        let store = ModuleStore::from_base(&t, &theta);
        let mut buf = vec![99.0f32; 3]; // dirty, wrong-sized buffer
        for p in 0..t.paths {
            t.assemble_into(&store, p, &mut buf);
            assert_eq!(buf, store.assemble(&t, p), "path {p}");
        }
        let mut seg = vec![1.0f32; 1];
        for l in 0..t.levels.len() {
            t.extract_into(l, &theta, &mut seg);
            assert_eq!(seg, t.extract(l, &theta), "level {l}");
        }
    }

    #[test]
    fn pooled_assemble_into_matches_allocating_assemble() {
        // The phase-assembly fan-out runs assemble_into on pool-recycled
        // buffers; output must be bit-identical to the allocating path no
        // matter what stale contents the recycled buffer carries.
        use crate::util::pool::Pool;
        let m = manifest();
        let t = Topology::build(&m, &TopologySpec::grid(vec![2, 2]));
        let theta: Vec<f32> = (0..m.total_params).map(|i| (i % 7) as f32 - 3.0).collect();
        let store = ModuleStore::from_base(&t, &theta);
        let pool: std::sync::Arc<Pool<f32>> = Pool::new(4);
        for round in 0..3 {
            for p in 0..t.paths {
                let mut buf = Pool::take(&pool, 0);
                buf.resize(17, f32::NAN); // poison before reuse
                t.assemble_into(&store, p, &mut buf);
                let want = store.assemble(&t, p);
                assert_eq!(buf.len(), want.len(), "round {round} path {p}");
                let same = buf.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "round {round} path {p}: pooled != allocating");
            }
        }
        assert!(pool.stats().hits > 0, "later rounds must reuse pooled buffers");
    }

    #[test]
    fn mixture_params_grows_with_k() {
        let m = manifest();
        let small = Topology::build(&m, &TopologySpec::grid(vec![2, 2])).mixture_params();
        let big = Topology::build(&m, &TopologySpec::grid(vec![4, 4])).mixture_params();
        assert!(big > small);
        assert!(small > m.total_params);
    }
}
