//! Fully-synchronous DiPaCo training — the §4.5 ablation.
//!
//! "At every step, each path computes gradients on its own batch of data
//! from its own data shard; gradients across all paths are then exchanged
//! and aggregated module by module; finally, the model performs one step
//! of AdamW update with the aggregated gradient."
//!
//! Gradients flow through the `grad_step` HLO; the per-module AdamW
//! update runs in rust over module space (unit-tested against the same
//! formula the train_step HLO uses).

use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

use crate::config::DilocoConfig;
use crate::data::corpus::Corpus;
use crate::data::dataset::{BatchSampler, Sharding};
use crate::info;
use crate::optim::OuterAccumulator;
use crate::runtime::engine::Engine;
use crate::topology::{ModuleId, ModuleStore, Topology};
use crate::util::kernels;
use crate::util::threadpool::parallel_map;

/// Module-space AdamW state.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

/// AdamW update in rust — must match `python/compile/model.py::adam_update`
/// for matrices; the decay mask is handled by passing `wd` per call site
/// (module granularity: modules contain both matrices and vectors, so the
/// sync trainer applies decay with the same per-leaf mask as the HLO).
/// Delegates to the fused chunked kernel, which is bit-identical to the
/// original per-element loop (see `util::kernels` property tests).
#[allow(clippy::too_many_arguments)]
pub fn adamw_update(
    theta: &mut [f32],
    st: &mut AdamState,
    g: &[f32],
    decay_mask: &[f32],
    step: f32,
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
    wd: f32,
) {
    kernels::adamw(
        theta, &mut st.m, &mut st.v, g, decay_mask, step, lr, b1, b2, eps, wd,
    );
}

/// Per-leaf weight-decay mask in theta space, mirroring
/// `model.py::decay_mask` (matrices yes, biases/LN no).
pub fn decay_mask(manifest: &crate::params::manifest::Manifest) -> Vec<f32> {
    let mut mask = vec![0.0f32; manifest.total_params];
    for leaf in &manifest.leaves {
        let on = leaf.shape.len() == 2 && !leaf.name.contains(".ln");
        if on {
            mask[leaf.range()].fill(1.0);
        }
    }
    mask
}

pub struct SyncResult {
    pub store: ModuleStore,
    pub loss_curve: Vec<(usize, f32)>,
}

/// Train a DiPaCo topology fully synchronously for `steps` steps.
pub fn train_sync(
    engine: &Arc<Engine>,
    corpus: &Arc<Corpus>,
    sharding: &Sharding,
    topo: &Topology,
    base_theta: &[f32],
    schedule: &DilocoConfig,
    steps: usize,
    seed: u64,
    threads: usize,
) -> Result<SyncResult> {
    let mc = engine.model();
    let mut store = ModuleStore::from_base(topo, base_theta);
    let mask_full = decay_mask(&engine.manifest);
    // module-space decay masks + AdamW states
    let mut adam: HashMap<ModuleId, AdamState> = HashMap::new();
    let mut masks: HashMap<usize, Vec<f32>> = HashMap::new();
    for m in topo.all_modules() {
        let size = topo.levels[m.level].size;
        adam.insert(m, AdamState { m: vec![0.0; size], v: vec![0.0; size] });
        masks
            .entry(m.level)
            .or_insert_with(|| topo.extract(m.level, &mask_full));
    }
    let mut samplers: Vec<BatchSampler> = (0..topo.paths)
        .map(|p| {
            BatchSampler::new(
                &sharding.shards[p].docs,
                mc.batch,
                mc.seq_train,
                seed ^ (p as u64) << 8,
            )
        })
        .collect();
    let mut loss_curve = Vec::new();
    for i in 0..steps {
        let step = (i + 1) as f32;
        let lr = schedule.lr_at(i + 1);
        // per-path gradients (parallel over paths; engine is Sync)
        let inputs: Vec<(usize, Vec<f32>, Vec<i32>)> = (0..topo.paths)
            .map(|p| {
                let theta = store.assemble(topo, p);
                let (tokens, _) = samplers[p].next_batch(corpus);
                (p, theta, tokens)
            })
            .collect();
        let grads: Vec<(usize, Vec<f32>, f32)> = parallel_map(&inputs, threads, |(p, theta, tokens)| {
            let (g, loss) = engine.grad_step(theta, tokens).expect("grad_step");
            (*p, g, loss)
        });
        let mean_loss = grads.iter().map(|(_, _, l)| *l as f64).sum::<f64>() / grads.len() as f64;
        loss_curve.push((i + 1, mean_loss as f32));
        // aggregate per module, then AdamW per module
        let mut accs: HashMap<ModuleId, OuterAccumulator> = HashMap::new();
        for (p, g, _) in &grads {
            for mid in topo.modules_of_path(*p) {
                let slice = topo.extract(mid.level, g);
                accs.entry(mid)
                    .or_insert_with(|| OuterAccumulator::new(slice.len()))
                    .add(&slice, 1.0);
            }
        }
        for (mid, acc) in accs {
            let g = acc.average();
            let params = store.get_mut(mid);
            let st = adam.get_mut(&mid).unwrap();
            adamw_update(
                params,
                st,
                &g,
                &masks[&mid.level],
                step,
                lr,
                0.9,
                0.999,
                1e-8,
                0.1,
            );
        }
        if (i + 1) % 50 == 0 {
            info!("sync", "step {}: loss {:.4}", i + 1, mean_loss);
        }
    }
    Ok(SyncResult { store, loss_curve })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_matches_reference_formula() {
        // One step from zero state, compare against hand-computed values.
        let mut theta = vec![1.0f32, -0.5];
        let mut st = AdamState { m: vec![0.0; 2], v: vec![0.0; 2] };
        let g = vec![0.3f32, -0.1];
        let mask = vec![1.0f32, 0.0];
        adamw_update(&mut theta, &mut st, &g, &mask, 1.0, 0.01, 0.9, 0.999, 1e-8, 0.1);
        // mhat = g, vhat = g^2 -> update = sign(g) (+ wd*theta where masked)
        let expect0 = 1.0 - 0.01 * (0.3 / (0.3 + 1e-8) + 0.1 * 1.0);
        let expect1 = -0.5 - 0.01 * (-0.1 / (0.1 + 1e-8));
        assert!((theta[0] - expect0).abs() < 1e-5, "{} vs {expect0}", theta[0]);
        assert!((theta[1] - expect1).abs() < 1e-5, "{} vs {expect1}", theta[1]);
    }

    #[test]
    fn decay_mask_matches_leaf_shapes() {
        let j = crate::params::manifest::tests::fake_manifest_json(2, 8);
        let man = crate::params::manifest::Manifest::from_json(
            &crate::util::json::Json::parse(&j).unwrap(),
        )
        .unwrap();
        let mask = decay_mask(&man);
        let wq = man.leaf("block0.attn.wq").unwrap();
        assert!(mask[wq.range()].iter().all(|&x| x == 1.0));
        let ln = man.leaf("block0.ln1.scale").unwrap();
        assert!(mask[ln.range()].iter().all(|&x| x == 0.0));
        let b1 = man.leaf("block1.mlp.b1").unwrap();
        assert!(mask[b1.range()].iter().all(|&x| x == 0.0));
    }
}
