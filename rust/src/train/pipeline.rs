//! Shared experiment plumbing: engine/corpus construction, cached base-
//! model pretraining, and evaluation helpers reused by every driver in
//! `examples/`. Keeping this in the library means the drivers stay thin
//! and all experiments share identical setups.

use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

use crate::config::{CorpusConfig, DilocoConfig};
use crate::data::corpus::Corpus;
use crate::info;
use crate::params::checkpoint::Checkpoint;
use crate::runtime::engine::{artifact_dir, Engine};
use crate::train::dense::DenseTrainer;

/// Standard experiment environment: one engine per preset + the shared
/// synthetic corpus.
pub struct Env {
    pub engine: Arc<Engine>,
    pub corpus: Arc<Corpus>,
    pub workdir: PathBuf,
}

impl Env {
    pub fn new(preset: &str, corpus_cfg: &CorpusConfig, workdir: PathBuf) -> Result<Env> {
        std::fs::create_dir_all(&workdir)?;
        let engine = Arc::new(
            Engine::load(&artifact_dir(preset))
                .with_context(|| format!("loading artifacts for preset {preset}"))?,
        );
        info!(
            "env",
            "engine {}: {} params, batch {} seq {}",
            preset,
            engine.manifest.total_params,
            engine.model().batch,
            engine.model().seq_train
        );
        let corpus = Arc::new(Corpus::synthetic(corpus_cfg));
        info!(
            "env",
            "corpus: {} docs ({} train / {} valid / {} router), {} domains",
            corpus.docs.len(),
            corpus.train.len(),
            corpus.valid.len(),
            corpus.router.len(),
            corpus.n_domains
        );
        Ok(Env {
            engine,
            corpus,
            workdir,
        })
    }

    /// Pretrain (or load from cache) the base dense model every DiPaCo
    /// experiment forks from (paper Figure 8's purple segment).
    pub fn base_model(&self, steps: usize, schedule: &DilocoConfig, seed: u64) -> Result<Vec<f32>> {
        let cache = self.workdir.join(format!(
            "base-{}-s{steps}-seed{seed}.dpc",
            self.engine.manifest.preset
        ));
        if cache.exists() {
            // random access: the cache also holds m/v, skip them entirely
            if let Ok(theta) = crate::params::checkpoint::load_section(&cache, "theta") {
                if theta.len() == self.engine.manifest.total_params {
                    info!("env", "base model loaded from {}", cache.display());
                    return Ok(theta);
                }
            }
            // fall through to retrain on any mismatch
        }
        info!("env", "pretraining base model for {steps} steps");
        let trainer = DenseTrainer::new(Arc::clone(&self.engine), Arc::clone(&self.corpus), schedule.clone());
        let res = trainer.train_from_scratch(&self.corpus.train, steps, seed)?;
        Checkpoint::new()
            .with("theta", res.theta.clone())
            .with("m", res.m)
            .with("v", res.v)
            .save(&cache)?;
        Ok(res.theta)
    }

    /// Validation PPL of a single dense model.
    pub fn valid_ppl(&self, theta: &[f32]) -> Result<f64> {
        crate::eval::ppl_docs(
            &self.engine,
            theta,
            &self.corpus.valid,
            &self.corpus,
            self.engine.model().seq_eval,
        )
    }

    /// Validation PPL over an explicit doc subset (drivers share one
    /// deterministic subset so rows are comparable).
    pub fn valid_ppl_subset(&self, theta: &[f32], docs: &[usize]) -> Result<f64> {
        crate::eval::ppl_docs(
            &self.engine,
            theta,
            docs,
            &self.corpus,
            self.engine.model().seq_eval,
        )
    }
}

/// Default inner-optimization schedule used across experiment drivers.
/// (Peak LR tuned once on the dense baseline — paper §4 searched "mainly
/// learning rate and value of Nesterov momentum".)
pub fn default_schedule(total_steps: usize) -> DilocoConfig {
    DilocoConfig {
        total_steps,
        warmup_steps: (total_steps / 20).clamp(20, 200),
        peak_lr: 1e-3,
        ..Default::default()
    }
}

/// Default corpus for experiments: 16 domains, mild skew.
pub fn default_corpus(n_docs: usize) -> CorpusConfig {
    CorpusConfig {
        n_domains: 16,
        n_docs,
        doc_len: (300, 700),
        skew: 0.3,
        seed: 1234,
    }
}

// ---------------------------------------------------------------------------
// Cached DiPaCo runs: experiment drivers share expensive training runs
// through results/runs/cache/<tag>/ so e.g. Table 1 reuses Figure 8's 4x4.
// ---------------------------------------------------------------------------

use crate::routing::router::Router;
use std::collections::HashMap;

/// The slice of a finished DiPaCo run the evaluation drivers need.
pub struct TrainedPaths {
    pub thetas: HashMap<usize, Vec<f32>>,
    pub early: HashMap<usize, Vec<f32>>,
    pub router: Router,
    pub base: Vec<f32>,
    /// (inner step, mean train loss) per phase.
    pub loss_curve: Vec<(usize, f64)>,
}

impl TrainedPaths {
    fn cache_dir(env: &Env, tag: &str) -> PathBuf {
        env.workdir.join("cache").join(tag)
    }

    pub fn save(&self, env: &Env, tag: &str) -> Result<()> {
        let dir = Self::cache_dir(env, tag);
        std::fs::create_dir_all(&dir)?;
        let mut thetas = Checkpoint::new();
        for (p, t) in &self.thetas {
            thetas = thetas.with(&format!("path{p}"), t.clone());
        }
        thetas.save(&dir.join("thetas.dpc"))?;
        let mut early = Checkpoint::new();
        for (p, t) in &self.early {
            early = early.with(&format!("path{p}"), t.clone());
        }
        early.save(&dir.join("early.dpc"))?;
        self.router.save(&dir.join("router.dpc"))?;
        Checkpoint::new()
            .with("theta", self.base.clone())
            .save(&dir.join("base.dpc"))?;
        let curve: Vec<f32> = self
            .loss_curve
            .iter()
            .flat_map(|&(s, l)| [s as f32, l as f32])
            .collect();
        Checkpoint::new()
            .with("curve", curve)
            .save(&dir.join("curve.dpc"))?;
        Ok(())
    }

    pub fn load(env: &Env, tag: &str) -> Option<TrainedPaths> {
        let dir = Self::cache_dir(env, tag);
        let read_map = |file: &str| -> Option<HashMap<usize, Vec<f32>>> {
            let ck = Checkpoint::load(&dir.join(file)).ok()?;
            let mut out = HashMap::new();
            for (name, data) in ck.sections {
                let p: usize = name.strip_prefix("path")?.parse().ok()?;
                out.insert(p, data);
            }
            Some(out)
        };
        let thetas = read_map("thetas.dpc")?;
        let early = read_map("early.dpc")?;
        let router = Router::load(&dir.join("router.dpc")).ok()?;
        let base = Checkpoint::load(&dir.join("base.dpc"))
            .ok()?
            .take("theta")?;
        let curve_raw = Checkpoint::load(&dir.join("curve.dpc")).ok()?.take("curve")?;
        let loss_curve = curve_raw
            .chunks(2)
            .map(|c| (c[0] as usize, c[1] as f64))
            .collect();
        crate::info!("cache", "loaded run {tag} ({} paths)", thetas.len());
        Some(TrainedPaths {
            thetas,
            early,
            router,
            base,
            loss_curve,
        })
    }

    /// Validation PPL, routing once per sequence (Table 3 row 1/2).
    pub fn ppl_once(&self, env: &Env, docs: &[usize], early_stop: bool) -> Result<f64> {
        let assign = crate::routing::router::route_docs(
            &env.engine,
            &self.base,
            &self.router,
            docs,
            &env.corpus,
        )?;
        let thetas = if early_stop { &self.early } else { &self.thetas };
        crate::eval::eval_routed(
            &env.engine,
            thetas,
            |d| assign[&d],
            docs,
            &env.corpus,
            env.engine.model().seq_eval,
        )
    }
}

/// Trained paths for the serving drivers (`dipaco serve`,
/// `examples/serve_paths.rs`): load the cached run under `tag`, or train
/// a short 2x2 DiPaCo first. Both drivers share one tag so the expensive
/// run happens once.
pub fn serve_demo_paths(env: &Env, tag: &str) -> Result<TrainedPaths> {
    if let Some(t) = TrainedPaths::load(env, tag) {
        return Ok(t);
    }
    let total = 200 + 60;
    let sched = default_schedule(total);
    let base = env.base_model(200, &sched, 7)?;
    let recipe = std_recipe(
        env,
        crate::config::TopologySpec::grid(vec![2, 2]),
        Some((2, 2)),
        total,
        1,
        false,
        tag,
    );
    cached_dipaco(env, tag, &recipe, base, 3, 0)
}

/// Run a DiPaCo recipe, or load it from the cache when `tag` exists.
pub fn cached_dipaco(
    env: &Env,
    tag: &str,
    recipe: &crate::train::dipaco::DipacoRecipe,
    base: Vec<f32>,
    gen_phases: usize,
    disc_phases: usize,
) -> Result<TrainedPaths> {
    if let Some(hit) = TrainedPaths::load(env, tag) {
        return Ok(hit);
    }
    let result = recipe.train(base, gen_phases, disc_phases)?;
    let trained = TrainedPaths {
        thetas: result.thetas,
        early: result.early_stopped,
        router: result.router,
        base: result.base_theta,
        loss_curve: result.loss_curve,
    };
    trained.save(env, tag)?;
    Ok(trained)
}

/// Dense baseline, cached.
pub fn cached_dense(
    env: &Env,
    tag: &str,
    steps: usize,
    schedule: &DilocoConfig,
    seed: u64,
) -> Result<(Vec<f32>, Vec<(usize, f32)>, Vec<(usize, f64)>)> {
    let dir = env.workdir.join("cache").join(tag);
    let f = dir.join("dense.dpc");
    if let Ok(mut ck) = Checkpoint::load(&f) {
        if let (Some(theta), Some(raw), Some(ppl_raw)) =
            (ck.take("theta"), ck.take("curve"), ck.take("ppl"))
        {
            let curve = raw.chunks(2).map(|c| (c[0] as usize, c[1])).collect();
            let ppl = ppl_raw.chunks(2).map(|c| (c[0] as usize, c[1] as f64)).collect();
            crate::info!("cache", "loaded dense run {tag}");
            return Ok((theta, curve, ppl));
        }
    }
    let mut trainer =
        DenseTrainer::new(Arc::clone(&env.engine), Arc::clone(&env.corpus), schedule.clone());
    trainer.eval_every = (steps / 6).max(1);
    let res = trainer.train_from_scratch(&env.corpus.train, steps, seed)?;
    std::fs::create_dir_all(&dir)?;
    let curve_raw: Vec<f32> = res.loss_curve.iter().flat_map(|&(s, l)| [s as f32, l]).collect();
    let ppl_raw: Vec<f32> = res
        .ppl_curve
        .iter()
        .flat_map(|&(s, p)| [s as f32, p as f32])
        .collect();
    Checkpoint::new()
        .with("theta", res.theta.clone())
        .with("curve", curve_raw)
        .with("ppl", ppl_raw)
        .save(&f)?;
    Ok((res.theta, res.loss_curve, res.ppl_curve))
}

/// Evaluation subset: first `n` validation docs (keeps single-core eval
/// affordable while staying deterministic across drivers).
pub fn eval_docs(corpus: &crate::data::corpus::Corpus, n: usize) -> Vec<usize> {
    corpus.valid.iter().copied().take(n).collect()
}

/// Router-data subset cap (discriminative scoring costs P x docs).
pub fn router_docs(corpus: &crate::data::corpus::Corpus, n: usize) -> Vec<usize> {
    corpus.router.iter().copied().take(n).collect()
}

/// Standard experiment recipe shared by the drivers (see DESIGN.md
/// experiment index): τ=20 inner steps, 2 executors, 4 workers, seed 7.
#[allow(clippy::too_many_arguments)]
pub fn std_recipe(
    env: &Env,
    spec: crate::config::TopologySpec,
    grid: Option<(usize, usize)>,
    total_steps: usize,
    overlap: usize,
    early_stop: bool,
    tag: &str,
) -> crate::train::dipaco::DipacoRecipe {
    let mut diloco = default_schedule(total_steps);
    diloco.inner_steps = 20;
    crate::train::dipaco::DipacoRecipe {
        engine: Arc::clone(&env.engine),
        corpus: Arc::clone(&env.corpus),
        spec,
        diloco,
        routing: crate::config::RoutingConfig {
            train_overlap: overlap,
            ..Default::default()
        },
        run: crate::config::RunConfig {
            workers: 4,
            outer_executors: 2,
            lease_ms: 120_000,
            ..Default::default()
        },
        rundir: env.workdir.join("rd").join(tag),
        early_stop,
        holdout_frac: if early_stop { 0.1 } else { 0.0 },
        grid,
    }
}
