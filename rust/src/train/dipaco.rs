//! The full DiPaCo training recipe (paper §2.6 + §4 experimental setup):
//!
//! 1. pretrain (or receive) a base dense model;
//! 2. extract prefix features and fit the **generative** router
//!    (k-means / product k-means), pre-shard the train split (optional
//!    top-n overlap);
//! 3. train paths with per-module DiLoCo phases over the §3 coordinator;
//! 4. optionally run **discriminative re-sharding** phases (§2.4.2 — "all
//!    instances of DiPaCo use one phase of discriminative routing") and
//!    continue training on the new shards;
//! 5. return thetas (+ early-stopped variants) and the final router for
//!    evaluation.

use anyhow::Result;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::config::{DilocoConfig, RoutingConfig, RunConfig, TopologySpec};
use crate::coordinator::phases::{DipacoRun, PhaseStats};
use crate::data::corpus::Corpus;
use crate::data::dataset::Sharding;
use crate::info;
use crate::routing::features::extract_features;
use crate::routing::router::{
    fit_discriminative, fit_generative, score_router_docs, shard_by_router, Router,
};
use crate::runtime::engine::Engine;
use crate::topology::Topology;
use crate::util::rng::Rng;

pub struct DipacoRecipe {
    pub engine: Arc<Engine>,
    pub corpus: Arc<Corpus>,
    pub spec: TopologySpec,
    pub diloco: DilocoConfig,
    pub routing: RoutingConfig,
    pub run: RunConfig,
    pub rundir: PathBuf,
    pub early_stop: bool,
    /// Holdout fraction per shard for early stopping.
    pub holdout_frac: f64,
    /// Grid hint for product k-means, e.g. (4, 4) for a 4x4 DiPaCo.
    pub grid: Option<(usize, usize)>,
}

pub struct DipacoResult {
    pub topo: Arc<Topology>,
    pub router: Router,
    pub sharding: Arc<Sharding>,
    pub thetas: HashMap<usize, Vec<f32>>,
    pub early_stopped: HashMap<usize, Vec<f32>>,
    pub base_theta: Vec<f32>,
    pub phase_stats: Vec<PhaseStats>,
    /// (phase -> mean train loss), concatenated over stages.
    pub loss_curve: Vec<(usize, f64)>,
}

impl DipacoRecipe {
    /// Train for `gen_phases` on the generative sharding, then (if
    /// `disc_phases > 0`) re-shard discriminatively and continue.
    pub fn train(&self, base_theta: Vec<f32>, gen_phases: usize, disc_phases: usize) -> Result<DipacoResult> {
        let topo = Arc::new(Topology::build(&self.engine.manifest, &self.spec));
        let k = topo.paths;
        let mut rng = Rng::new(self.run.seed ^ 0x0507);
        info!(
            "dipaco",
            "topology: {} paths, {} modules, mixture {}M params",
            topo.paths,
            topo.all_modules().len(),
            topo.mixture_params() / 1_000_000
        );

        // ---- stage 1: generative routing + sharding (paper §2.4.1) ----
        let train_feats =
            extract_features(&self.engine, &base_theta, &self.corpus.train, &self.corpus)?;
        let router = fit_generative(&train_feats, k, self.grid, &self.routing, &mut rng);
        let sharding = Arc::new(shard_by_router(
            &router,
            &self.corpus.train,
            &train_feats,
            k,
            self.routing.train_overlap,
            self.holdout_frac,
            self.run.seed,
        ));
        info!("dipaco", "generative shard sizes: {:?}", sharding.sizes());

        let mut run = DipacoRun::new(
            Arc::clone(&self.engine),
            Arc::clone(&self.corpus),
            Arc::clone(&sharding),
            Arc::clone(&topo),
            &base_theta,
            self.diloco.clone(),
            self.run.clone(),
            self.rundir.join("gen"),
            self.early_stop,
        )?;
        run.run(gen_phases)?;
        let mut loss_curve: Vec<(usize, f64)> = run
            .stats
            .iter()
            .map(|s| ((s.phase + 1) * self.diloco.inner_steps, s.mean_train_loss))
            .collect();
        let mut phase_stats = run.stats.clone();
        let mut thetas = run.all_path_thetas();
        let mut early = run.early_stopped_thetas()?;
        // Stage-1 result in module space — stage 2 continues from these
        // modules directly instead of re-extracting them from re-assembled
        // full-theta vectors.
        let stage1_modules = if disc_phases > 0 {
            Some(run.store.lock().unwrap().clone())
        } else {
            None
        };
        let mut final_router = router;
        let mut final_sharding = sharding;
        run.shutdown();
        drop(run);

        // ---- stage 2: discriminative re-shard + continue (§2.4.2) ----
        if disc_phases > 0 {
            let router_feats = extract_features(
                &self.engine,
                &base_theta,
                &self.corpus.router,
                &self.corpus,
            )?;
            let scores =
                score_router_docs(&self.engine, &thetas, &self.corpus.router, &self.corpus)?;
            let disc = fit_discriminative(&router_feats, &scores, k, &self.routing);
            let disc_shard = Arc::new(shard_by_router(
                &disc,
                &self.corpus.train,
                &train_feats,
                k,
                self.routing.train_overlap,
                self.holdout_frac,
                self.run.seed ^ 1,
            ));
            info!("dipaco", "discriminative shard sizes: {:?}", disc_shard.sizes());

            // Continue from the CURRENT modules: the new run's store is
            // seeded with the stage-1 module store as-is (module space to
            // module space — the full model is never re-materialized).
            let mut run2 = DipacoRun::new(
                Arc::clone(&self.engine),
                Arc::clone(&self.corpus),
                Arc::clone(&disc_shard),
                Arc::clone(&topo),
                &base_theta,
                self.diloco.clone(),
                self.run.clone(),
                self.rundir.join("disc"),
                self.early_stop,
            )?;
            *run2.store.lock().unwrap() =
                stage1_modules.expect("stage-1 store captured when disc_phases > 0");
            // offset the schedule so LR continues decaying
            for t in 0..disc_phases {
                // phases continue numbering after stage 1
                run2.run_phase(gen_phases + t)?;
            }
            loss_curve.extend(run2.stats.iter().map(|s| {
                ((s.phase + 1) * self.diloco.inner_steps, s.mean_train_loss)
            }));
            phase_stats.extend(run2.stats.clone());
            thetas = run2.all_path_thetas();
            let e2 = run2.early_stopped_thetas()?;
            early = e2;
            final_router = disc;
            final_sharding = disc_shard;
            run2.shutdown();
        }

        Ok(DipacoResult {
            topo,
            router: final_router,
            sharding: final_sharding,
            thetas,
            early_stopped: early,
            base_theta,
            phase_stats,
            loss_curve,
        })
    }
}

impl DipacoResult {
    /// Validation PPL with routing once per sequence (paper Table 3 row 1).
    pub fn eval_routed_once(&self, engine: &Engine, corpus: &Corpus) -> Result<f64> {
        let assign = crate::routing::router::route_docs(
            engine,
            &self.base_theta,
            &self.router,
            &corpus.valid,
            corpus,
        )?;
        crate::eval::eval_routed(
            engine,
            &self.thetas,
            |d| assign[&d],
            &corpus.valid,
            corpus,
            engine.model().seq_eval,
        )
    }
}
