//! Dense single-model trainer — the paper's baselines (the "150M" path-
//! sized model and the "1.3B"-analog large model) and the pretraining
//! stage that seeds every DiPaCo experiment (Figure 8: "we first pretrain
//! a 150M parameters model for 24k training steps").

use anyhow::Result;
use std::sync::Arc;

use crate::config::DilocoConfig;
use crate::data::corpus::Corpus;
use crate::data::dataset::BatchSampler;
use crate::info;
use crate::runtime::engine::Engine;

#[derive(Debug, Clone)]
pub struct DenseResult {
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// (global step, train loss) samples.
    pub loss_curve: Vec<(usize, f32)>,
    /// (global step, validation ppl) samples when eval_every > 0.
    pub ppl_curve: Vec<(usize, f64)>,
}

pub struct DenseTrainer {
    pub engine: Arc<Engine>,
    pub corpus: Arc<Corpus>,
    pub schedule: DilocoConfig,
    pub eval_every: usize,
    pub log_every: usize,
}

impl DenseTrainer {
    pub fn new(engine: Arc<Engine>, corpus: Arc<Corpus>, schedule: DilocoConfig) -> Self {
        DenseTrainer {
            engine,
            corpus,
            schedule,
            eval_every: 0,
            log_every: 50,
        }
    }

    /// Train for `steps` starting from (theta, m, v) at global step
    /// `start_step`, sampling from `docs`.
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &self,
        mut theta: Vec<f32>,
        mut m: Vec<f32>,
        mut v: Vec<f32>,
        docs: &[usize],
        steps: usize,
        start_step: usize,
        seed: u64,
    ) -> Result<DenseResult> {
        let mc = self.engine.model();
        let mut sampler = BatchSampler::new(docs, mc.batch, mc.seq_train, seed);
        let mut loss_curve = Vec::new();
        let mut ppl_curve = Vec::new();
        for i in 0..steps {
            let step = start_step + i + 1;
            let lr = self.schedule.lr_at(step);
            let (tokens, _) = sampler.next_batch(&self.corpus);
            let out = self.engine.train_step(&theta, &m, &v, step as f32, lr, &tokens)?;
            theta = out.theta;
            m = out.m;
            v = out.v;
            if self.log_every > 0 && (i + 1) % self.log_every == 0 {
                info!("dense", "step {step}: loss {:.4} lr {lr:.2e}", out.loss);
            }
            loss_curve.push((step, out.loss));
            if self.eval_every > 0 && (i + 1) % self.eval_every == 0 {
                // Capped eval subset: keeps periodic evals affordable.
                let n_eval = 64.min(self.corpus.valid.len());
                let ppl = crate::eval::ppl_docs(
                    &self.engine,
                    &theta,
                    &self.corpus.valid[..n_eval],
                    &self.corpus,
                    mc.seq_eval,
                )?;
                info!("dense", "step {step}: valid ppl {ppl:.3}");
                ppl_curve.push((step, ppl));
            }
        }
        Ok(DenseResult {
            theta,
            m,
            v,
            loss_curve,
            ppl_curve,
        })
    }

    /// Train from a fresh init.
    pub fn train_from_scratch(&self, docs: &[usize], steps: usize, seed: u64) -> Result<DenseResult> {
        let n = self.engine.manifest.total_params;
        let theta = self.engine.init(seed as u32)?;
        self.train(theta, vec![0.0; n], vec![0.0; n], docs, steps, 0, seed)
    }
}
