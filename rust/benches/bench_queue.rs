//! §3.1/§3.2 systems bench — task-queue throughput and fault-tolerance
//! overhead: lease/complete cycles under contention, with and without
//! injected preemptions, plus queue-state checkpointing cost.

use std::sync::Arc;
use std::time::Duration;

use dipaco::benchkit::{header, Bencher};
use dipaco::coordinator::queue::TaskQueue;
use dipaco::coordinator::task::{Task, TrainTask};
use dipaco::util::rng::Rng;

fn task(i: u64) -> Task {
    Task::Train(TrainTask {
        id: i + 1,
        phase: 0,
        path: i as usize,
        steps: 1,
        start_step: 0,
        ckpt_in: "in".into(),
        ckpt_out: "out".into(),
        opt_in: None,
        opt_out: "opt".into(),
    })
}

fn drive(n_tasks: u64, n_workers: usize, fail_p: f64) {
    let q = Arc::new(TaskQueue::new(Duration::from_millis(10)));
    for i in 0..n_tasks {
        q.push(task(i)).expect("bench queue is open");
    }
    std::thread::scope(|s| {
        for w in 0..n_workers {
            let q = Arc::clone(&q);
            s.spawn(move || {
                let mut rng = Rng::new(w as u64);
                while let Some((lease, _)) = q.lease(&format!("w{w}"), Duration::from_millis(50)) {
                    if fail_p > 0.0 && rng.f64() < fail_p {
                        q.fail(lease);
                        continue;
                    }
                    q.complete(lease);
                }
            });
        }
        q.wait_idle(Duration::from_micros(200));
        q.close();
    });
    assert_eq!(q.stats().completed, n_tasks);
}

fn main() {
    println!("task-queue bench (paper §3.1-3.2)\n");
    header();
    let mut csv = vec!["bench,mean_s,throughput_per_s".to_string()];
    for (name, workers, fail_p) in [
        ("1k tasks, 4 workers, no failures", 4usize, 0.0),
        ("1k tasks, 4 workers, 20% preemption", 4, 0.2),
        ("1k tasks, 16 workers, no failures", 16, 0.0),
        ("1k tasks, 16 workers, 20% preemption", 16, 0.2),
    ] {
        let r = Bencher::new(name)
            .runs(5, 20)
            .throughput(1000.0)
            .run(|| drive(1000, workers, fail_p));
        csv.push(format!("{name},{:.6},{:.0}", r.mean_s, r.throughput.unwrap_or(0.0)));
    }

    // queue-state checkpoint cost (paper: server checkpoints its queue)
    let q = TaskQueue::new(Duration::from_secs(10));
    for i in 0..1000 {
        q.push(task(i)).expect("bench queue is open");
    }
    let r = Bencher::new("checkpoint 1k-task queue state").runs(10, 50).run(|| {
        let state = q.checkpoint_state();
        let s = state.to_string();
        std::hint::black_box(s.len());
    });
    csv.push(format!("queue_state_checkpoint,{:.6},0", r.mean_s));
    let r = Bencher::new("restore 1k-task queue state").runs(10, 50).run(|| {
        let state = q.checkpoint_state();
        let q2 = TaskQueue::restore(&state, Duration::from_secs(10)).unwrap();
        std::hint::black_box(q2.stats().pending);
    });
    csv.push(format!("queue_state_restore,{:.6},0", r.mean_s));

    let out = dipaco::metrics::results_dir().join("bench_queue.csv");
    std::fs::create_dir_all(out.parent().unwrap()).unwrap();
    std::fs::write(&out, csv.join("\n")).unwrap();
    println!("\ncsv: {}", out.display());
}
