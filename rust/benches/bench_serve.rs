//! §2.6 serving bench — tok/s and p50/p95/p99 latency of the `serve::`
//! subsystem under skewed per-path load, on a synthetic executor with a
//! fixed per-batch + per-row cost (so the bench isolates queueing,
//! batching, and routing overhead from PJRT compute).
//!
//! Scenarios: uniform vs zipf-skewed path popularity, park vs reject
//! backpressure under overload, and the latency/throughput trade of the
//! micro-batch flush deadline.

use std::time::{Duration, Instant};

use dipaco::benchkit::{compare, header, Bencher};
use dipaco::config::{BreakerConfig, ServeConfig};
use dipaco::serve::batcher::{pad_batch, pad_batch_into};
use dipaco::serve::server::{PathExecutor, Server};
use dipaco::serve::stats::ServeReport;
use dipaco::testkit::routers::{one_hot, one_hot_router};
use dipaco::util::json::Json;
use dipaco::util::rng::Rng;

const PATHS: usize = 8;
const BATCH: usize = 8;
const SEQ: usize = 64;
const REQUESTS: usize = 800;
const CLIENTS: usize = 4;

/// Deterministic-cost executor: busy-waits per_batch + rows * per_row.
struct SynthExec {
    per_batch: Duration,
    per_row: Duration,
}

impl PathExecutor for SynthExec {
    fn batch(&self) -> usize {
        BATCH
    }
    fn seq(&self) -> usize {
        SEQ
    }
    fn forward(&mut self, _toks: &[i32], rows: usize) -> anyhow::Result<Vec<(f64, usize)>> {
        let end = Instant::now() + self.per_batch + self.per_row * rows as u32;
        while Instant::now() < end {
            std::hint::spin_loop();
        }
        Ok((0..rows).map(|_| (1.0, SEQ - 1)).collect())
    }
}

fn synth_fleet() -> Vec<SynthExec> {
    (0..PATHS)
        .map(|_| SynthExec {
            per_batch: Duration::from_micros(300),
            per_row: Duration::from_micros(40),
        })
        .collect()
}

/// Path popularity: uniform (skew 0) or zipf-like 1/(p+1)^skew.
fn path_stream(skew: f64, seed: u64) -> Vec<usize> {
    let w: Vec<f64> = (0..PATHS).map(|p| 1.0 / ((p + 1) as f64).powf(skew)).collect();
    let total: f64 = w.iter().sum();
    let mut rng = Rng::new(seed);
    (0..REQUESTS)
        .map(|_| {
            let mut x = rng.f64() * total;
            for (p, wp) in w.iter().enumerate() {
                x -= wp;
                if x <= 0.0 {
                    return p;
                }
            }
            PATHS - 1
        })
        .collect()
}

/// Full serve round: start, submit from CLIENTS threads via the router,
/// drain, shut down. Returns the final report.
fn drive(cfg: &ServeConfig, stream: &[usize]) -> ServeReport {
    let server = Server::start(cfg, one_hot_router(PATHS), synth_fleet());
    std::thread::scope(|s| {
        for w in 0..CLIENTS {
            let server = &server;
            s.spawn(move || {
                let mut tickets = Vec::new();
                for i in (w..stream.len()).step_by(CLIENTS) {
                    let z = one_hot(PATHS, stream[i]);
                    if let Ok(t) = server.submit(&z, vec![0i32; SEQ]) {
                        tickets.push(t);
                    }
                }
                for t in tickets {
                    let _ = t.wait();
                }
            });
        }
    });
    server.shutdown()
}

fn report_line(name: &str, r: &ServeReport) -> String {
    println!(
        "  {name}: p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms  {:.0} tok/s  fill {:.1}  \
         served {}  rejected {}  load {:?}",
        r.p50_ms, r.p95_ms, r.p99_ms, r.tok_per_s, r.mean_batch_fill, r.served, r.rejected,
        r.per_path_served
    );
    format!(
        "{name},{:.4},{:.4},{:.4},{:.0},{},{}",
        r.p50_ms, r.p95_ms, r.p99_ms, r.tok_per_s, r.served, r.rejected
    )
}

fn main() {
    println!("path-serving bench (paper §2.6), {PATHS} paths, {REQUESTS} requests\n");
    let mut csv =
        vec!["scenario,p50_ms,p95_ms,p99_ms,tok_per_s,served,rejected".to_string()];
    let mut summary: Vec<(&str, Json)> = Vec::new();

    // Padding hot path: per-flush allocation vs the worker's reused
    // buffer (pad_batch_into). Kernel rows reuse the CSV schema with
    // mean/p95 in the ms columns and pads/s in tok_per_s.
    println!("padding hot path (half-full {BATCH}-doc batch, seq {SEQ}):");
    header();
    let row = vec![0i32; SEQ];
    let rows: Vec<&[i32]> = (0..BATCH / 2).map(|_| row.as_slice()).collect();
    let r_alloc = Bencher::new("pad_batch (alloc per flush)")
        .runs(20, 200)
        .throughput(1.0)
        .run(|| {
            std::hint::black_box(pad_batch(&rows, BATCH).len());
        });
    csv.push(format!(
        "pad_batch alloc,{:.6},{:.6},0,{:.0},0,0",
        r_alloc.mean_s * 1e3,
        r_alloc.p95_s * 1e3,
        r_alloc.throughput.unwrap()
    ));
    let mut toks: Vec<i32> = Vec::new();
    let r_into = Bencher::new("pad_batch_into (reused buffer)")
        .runs(20, 200)
        .throughput(1.0)
        .run(|| {
            pad_batch_into(&rows, BATCH, &mut toks);
            std::hint::black_box(toks.len());
        });
    csv.push(format!(
        "pad_batch_into reuse,{:.6},{:.6},0,{:.0},0,0",
        r_into.mean_s * 1e3,
        r_into.p95_s * 1e3,
        r_into.throughput.unwrap()
    ));
    compare(&r_alloc, &r_into);
    summary.push(("pad_alloc_s", Json::num(r_alloc.mean_s)));
    summary.push(("pad_into_s", Json::num(r_into.mean_s)));
    summary.push(("pad_into_speedup", Json::num(r_alloc.mean_s / r_into.mean_s)));
    println!();

    let park = ServeConfig::default();
    let tight = ServeConfig {
        max_wait_ms: 0,
        ..Default::default()
    };
    let overload = ServeConfig {
        queue_cap: 4,
        reject_on_full: true,
        ..Default::default()
    };
    let uniform = path_stream(0.0, 1);
    let skewed = path_stream(1.2, 2);

    println!("representative runs:");
    for (name, cfg, stream) in [
        ("uniform load, park, 15ms window", &park, &uniform),
        ("zipf-1.2 load, park, 15ms window", &park, &skewed),
        ("uniform load, park, 0ms window", &tight, &uniform),
        ("zipf-1.2 overload, reject, cap 4", &overload, &skewed),
    ] {
        let r = drive(cfg, stream);
        csv.push(report_line(name, &r));
        assert_eq!(
            r.served + r.rejected,
            REQUESTS as u64,
            "every request is served or visibly rejected"
        );
    }

    // Self-healing overhead on the healthy path: with no faults, the
    // supervisor adds one catch_unwind frame per batch and admission adds
    // one breaker lock per request. That must be noise next to even a
    // synthetic 300us batch — measured here as guarded vs unguarded
    // throughput on the identical stream.
    println!("\nself-healing overhead (healthy path, no faults):");
    let unguarded = ServeConfig {
        breaker: BreakerConfig {
            enabled: false,
            ..Default::default()
        },
        ..Default::default()
    };
    let best_tok_s = |cfg: &ServeConfig| -> f64 {
        (0..3)
            .map(|_| drive(cfg, &uniform).tok_per_s)
            .fold(0.0, f64::max)
    };
    let guarded_tok_s = best_tok_s(&park);
    let unguarded_tok_s = best_tok_s(&unguarded);
    let overhead_pct = 100.0 * (1.0 - guarded_tok_s / unguarded_tok_s);
    println!(
        "  guarded {guarded_tok_s:.0} tok/s vs unguarded {unguarded_tok_s:.0} tok/s \
         ({overhead_pct:+.1}% overhead)"
    );
    csv.push(format!(
        "healthy-path guarded,0,0,0,{guarded_tok_s:.0},{REQUESTS},0"
    ));
    csv.push(format!(
        "healthy-path unguarded,0,0,0,{unguarded_tok_s:.0},{REQUESTS},0"
    ));
    // Generous bound (this is a bench, not a tier-1 test, but a gross
    // regression — e.g. a ranked-scores sort on the fast path — should
    // fail loudly here rather than ship).
    assert!(
        guarded_tok_s >= unguarded_tok_s / 1.5,
        "breaker/supervision checks cost >33% healthy-path throughput: \
         guarded {guarded_tok_s:.0} vs unguarded {unguarded_tok_s:.0} tok/s"
    );

    println!("\nwall-clock per full round ({REQUESTS} requests):");
    header();
    for (name, cfg, stream) in [
        ("serve round: uniform, park", &park, &uniform),
        ("serve round: zipf-1.2, park", &park, &skewed),
        ("serve round: zipf-1.2, reject", &overload, &skewed),
    ] {
        let r = Bencher::new(name)
            .warmup(1)
            .runs(3, 10)
            .budget(Duration::from_secs(6))
            .throughput(REQUESTS as f64)
            .run(|| {
                std::hint::black_box(drive(cfg, stream).served);
            });
        csv.push(format!(
            "{name} (wall),{:.4},{:.4},0,{:.0},{REQUESTS},0",
            r.mean_s * 1e3,
            r.p95_s * 1e3,
            r.throughput.unwrap_or(0.0)
        ));
    }

    summary.push(("guarded_tok_per_s", Json::num(guarded_tok_s)));
    summary.push(("unguarded_tok_per_s", Json::num(unguarded_tok_s)));

    let bench_dir = dipaco::metrics::results_dir().join("bench");
    let out = bench_dir.join("bench_serve.csv");
    std::fs::create_dir_all(&bench_dir).unwrap();
    std::fs::write(&out, csv.join("\n")).unwrap();
    println!("\ncsv: {}", out.display());
    let json_out = bench_dir.join("BENCH_serve.json");
    dipaco::metrics::write_summary(&json_out, summary).unwrap();
    println!("summary: {}", json_out.display());
}
