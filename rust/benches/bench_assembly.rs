//! L3 hot-path bench — per-phase parameter plumbing: path assembly
//! (modules -> theta), delta splitting (theta pair -> per-module outer
//! gradients), and checkpoint serialization, at path-preset scale. These
//! run once per path per phase; they must be negligible next to tau
//! train steps (~2s of PJRT compute at tau=20).

use dipaco::benchkit::{compare, header, Bencher};
use dipaco::config::TopologySpec;
use dipaco::params::checkpoint::Checkpoint;
use dipaco::params::manifest::Manifest;
use dipaco::topology::{ModuleStore, Topology};
use dipaco::util::json::Json;
use dipaco::util::pool::Pool;
use dipaco::util::rng::Rng;
use dipaco::util::threadpool::parallel_map;

fn synthetic_manifest(d: usize, blocks: usize) -> Manifest {
    let mut leaves = Vec::new();
    let mut off = 0usize;
    let mut push = |name: String, shape: Vec<usize>, off: &mut usize| {
        let size: usize = shape.iter().product();
        leaves.push(format!(
            r#"{{"name":"{name}","offset":{off},"size":{size},"shape":[{}]}}"#,
            shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
        ));
        *off += size;
    };
    push("embed.tok".into(), vec![256, d], &mut off);
    push("embed.pos".into(), vec![256, d], &mut off);
    for i in 0..blocks {
        push(format!("block{i}.attn.wq"), vec![d, d], &mut off);
        push(format!("block{i}.attn.wk"), vec![d, d], &mut off);
        push(format!("block{i}.attn.wv"), vec![d, d], &mut off);
        push(format!("block{i}.attn.wo"), vec![d, d], &mut off);
        push(format!("block{i}.mlp.w1"), vec![d, 4 * d], &mut off);
        push(format!("block{i}.mlp.w2"), vec![4 * d, d], &mut off);
    }
    push("head.w".into(), vec![d, 256], &mut off);
    let text = format!(
        r#"{{"preset":"bench","config":{{"vocab":256,"d_model":{d},"n_layers":{blocks},
          "n_heads":4,"d_ff":{f},"seq_train":128,"seq_eval":256,"batch":8,"prefix":32,"d_head":16}},
          "total_params":{off},"leaves":[{ls}],"entrypoints":[]}}"#,
        f = 4 * d,
        ls = leaves.join(",")
    );
    Manifest::from_json(&Json::parse(&text).unwrap()).unwrap()
}

fn main() {
    println!("parameter-plumbing bench (per-phase L3 hot path)\n");
    header();
    let mut csv = vec!["bench,params,mean_s".to_string()];
    let mut summary: Vec<(&str, Json)> = Vec::new();
    for (d, blocks, label) in [(64usize, 4usize, "path-scale"), (128, 8, "large-scale")] {
        let man = synthetic_manifest(d, blocks);
        let topo = Topology::build(&man, &TopologySpec::grid(vec![4, 4]));
        let mut rng = Rng::new(0);
        let theta: Vec<f32> = (0..man.total_params).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let after: Vec<f32> = theta.iter().map(|&x| x + 0.001).collect();
        let store = ModuleStore::from_base(&topo, &theta);

        let r = Bencher::new(&format!("assemble path theta ({label})"))
            .runs(20, 200)
            .run(|| {
                std::hint::black_box(store.assemble(&topo, 7));
            });
        csv.push(format!("assemble_{label},{},{:.9}", man.total_params, r.mean_s));
        let alloc = r;

        // pooled assemble_into — the phase loop's configuration since the
        // zero-copy pass (buffer recycled run over run, no allocation)
        let pool: std::sync::Arc<Pool<f32>> = Pool::new(8);
        let r = Bencher::new(&format!("assemble_into pooled ({label})"))
            .runs(20, 200)
            .run(|| {
                let mut buf = Pool::take(&pool, 0);
                topo.assemble_into(&store, 7, &mut buf);
                std::hint::black_box(buf.len());
            });
        csv.push(format!("assemble_into_{label},{},{:.9}", man.total_params, r.mean_s));
        compare(&alloc, &r);
        if label == "large-scale" {
            summary.push(("assemble_alloc_s", Json::num(alloc.mean_s)));
            summary.push(("assemble_pooled_s", Json::num(r.mean_s)));
            summary.push(("assemble_pooled_speedup", Json::num(alloc.mean_s / r.mean_s)));
        }

        // multi-path fan-out: all paths of the phase, serial vs threaded
        // (mirrors run_phase's data-parallel assembly)
        let paths: Vec<usize> = (0..topo.paths).collect();
        let mut fanout = Vec::new();
        for threads in [1usize, 4] {
            let r = Bencher::new(&format!(
                "assemble all {} paths, {threads} thread(s) ({label})",
                topo.paths
            ))
            .runs(5, 50)
            .run(|| {
                let lens = parallel_map(&paths, threads, |&p| {
                    let mut buf = Pool::take(&pool, 0);
                    topo.assemble_into(&store, p, &mut buf);
                    buf.len()
                });
                std::hint::black_box(lens.len());
            });
            csv.push(format!(
                "assemble_fanout_x{threads}_{label},{},{:.9}",
                man.total_params, r.mean_s
            ));
            fanout.push(r);
        }
        compare(&fanout[0], &fanout[1]);
        if label == "large-scale" {
            summary.push(("fanout_serial_s", Json::num(fanout[0].mean_s)));
            summary.push(("fanout_x4_s", Json::num(fanout[1].mean_s)));
            summary.push((
                "fanout_x4_speedup",
                Json::num(fanout[0].mean_s / fanout[1].mean_s),
            ));
        }

        let r = Bencher::new(&format!("split outer gradients ({label})"))
            .runs(20, 200)
            .run(|| {
                std::hint::black_box(topo.split_delta(7, &theta, &after));
            });
        csv.push(format!("split_{label},{},{:.9}", man.total_params, r.mean_s));

        let dir = std::env::temp_dir().join(format!("dipaco-bench-asm-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join(format!("{label}.dpc"));
        let ck = Checkpoint::new().with("theta", theta.clone());
        let r = Bencher::new(&format!("checkpoint save ({label})"))
            .runs(10, 50)
            .run(|| ck.save(&f).unwrap());
        csv.push(format!("ckpt_save_{label},{},{:.9}", man.total_params, r.mean_s));
        let r = Bencher::new(&format!("checkpoint load ({label})"))
            .runs(10, 50)
            .run(|| {
                std::hint::black_box(Checkpoint::load(&f).unwrap());
            });
        csv.push(format!("ckpt_load_{label},{},{:.9}", man.total_params, r.mean_s));
        println!();
    }
    let bench_dir = dipaco::metrics::results_dir().join("bench");
    let out = bench_dir.join("bench_assembly.csv");
    std::fs::create_dir_all(&bench_dir).unwrap();
    std::fs::write(&out, csv.join("\n")).unwrap();
    println!("csv: {}", out.display());
    let json_out = bench_dir.join("BENCH_assembly.json");
    dipaco::metrics::write_summary(&json_out, summary).unwrap();
    println!("summary: {}", json_out.display());
}
