//! L1/L2 hot-path bench — fused f32 kernels (artifact-free, always runs)
//! plus PJRT execution cost of each entrypoint and the rust-side dispatch
//! overhead (literal building + tuple decomposition) relative to raw
//! compute. The PJRT rows need `make artifacts` and are skipped without
//! them; the kernel rows run everywhere, so the CSV always lands.
//!
//! This is the wall-clock unit every experiment above is priced in: one
//! inner step of one path. Perf target (EXPERIMENTS.md §Perf): rust
//! dispatch overhead < 10% of PJRT execute time.

use dipaco::benchkit::{compare, header, Bencher};
use dipaco::runtime::engine::{artifact_dir, Engine};
use dipaco::util::json::Json;
use dipaco::util::kernels;
use dipaco::util::rng::Rng;

/// Element count for the kernel micro-benches: path-preset scale
/// (~1M f32 per path), the size the optimizer loops actually chew.
const KN: usize = 1 << 20;

fn main() {
    println!("train-step bench: fused kernels + PJRT entrypoints\n");
    header();
    let mut csv = vec!["bench,mean_s,tokens_per_s".to_string()];
    let mut summary: Vec<(&str, Json)> = Vec::new();

    // ---- part 1: fused optimizer kernels vs scalar reference ----
    // Same data, mutated in place run over run (cost is data-independent);
    // bit-exactness is pinned by util::kernels property tests, so only
    // speed is at stake here.
    let mut rng = Rng::new(7);
    let g: Vec<f32> = (0..KN).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let mask: Vec<f32> = (0..KN).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
    let mut p = vec![0.5f32; KN];
    let mut v = vec![0.0f32; KN];

    let r_s = Bencher::new("nesterov step, scalar reference")
        .runs(10, 40)
        .throughput(KN as f64)
        .run(|| {
            kernels::nesterov_scalar(&mut p, &mut v, &g, 1e-4, 0.9);
            std::hint::black_box(p[0]);
        });
    csv.push(format!("kernel_nesterov_scalar,{:.9},{:.0}", r_s.mean_s, r_s.throughput.unwrap()));
    let r_f = Bencher::new("nesterov step, fused chunks")
        .runs(10, 40)
        .throughput(KN as f64)
        .run(|| {
            kernels::nesterov_step(&mut p, &mut v, &g, 1e-4, 0.9);
            std::hint::black_box(p[0]);
        });
    csv.push(format!("kernel_nesterov_fused,{:.9},{:.0}", r_f.mean_s, r_f.throughput.unwrap()));
    compare(&r_s, &r_f);
    summary.push(("nesterov_speedup", Json::num(r_s.mean_s / r_f.mean_s)));

    let mut sum = vec![0.0f32; KN];
    let r_s = Bencher::new("weighted accumulate, scalar reference")
        .runs(10, 40)
        .throughput(KN as f64)
        .run(|| {
            kernels::accumulate_scalar(&mut sum, &g, 0.37);
            std::hint::black_box(sum[0]);
        });
    csv.push(format!("kernel_accum_scalar,{:.9},{:.0}", r_s.mean_s, r_s.throughput.unwrap()));
    let r_f = Bencher::new("weighted accumulate, fused chunks")
        .runs(10, 40)
        .throughput(KN as f64)
        .run(|| {
            kernels::accumulate(&mut sum, &g, 0.37);
            std::hint::black_box(sum[0]);
        });
    csv.push(format!("kernel_accum_fused,{:.9},{:.0}", r_f.mean_s, r_f.throughput.unwrap()));
    compare(&r_s, &r_f);
    summary.push(("accumulate_speedup", Json::num(r_s.mean_s / r_f.mean_s)));

    let mut theta = vec![0.5f32; KN];
    let mut am = vec![0.0f32; KN];
    let mut av = vec![0.0f32; KN];
    let r_s = Bencher::new("adamw update, scalar reference")
        .runs(10, 40)
        .throughput(KN as f64)
        .run(|| {
            kernels::adamw_scalar(
                &mut theta, &mut am, &mut av, &g, &mask, 3.0, 1e-3, 0.9, 0.999, 1e-8, 0.1,
            );
            std::hint::black_box(theta[0]);
        });
    csv.push(format!("kernel_adamw_scalar,{:.9},{:.0}", r_s.mean_s, r_s.throughput.unwrap()));
    let r_f = Bencher::new("adamw update, fused chunks")
        .runs(10, 40)
        .throughput(KN as f64)
        .run(|| {
            kernels::adamw(
                &mut theta, &mut am, &mut av, &g, &mask, 3.0, 1e-3, 0.9, 0.999, 1e-8, 0.1,
            );
            std::hint::black_box(theta[0]);
        });
    csv.push(format!("kernel_adamw_fused,{:.9},{:.0}", r_f.mean_s, r_f.throughput.unwrap()));
    compare(&r_s, &r_f);
    summary.push(("adamw_speedup", Json::num(r_s.mean_s / r_f.mean_s)));
    println!();

    // ---- part 2: PJRT entrypoints (needs artifacts; preset selectable
    // so the fused A/B can run on whichever artifacts carry the
    // train_steps entrypoint — DIPACO_BENCH_PRESET, default path) ----
    let preset = std::env::var("DIPACO_BENCH_PRESET").unwrap_or_else(|_| "path".into());
    let dir = artifact_dir(&preset);
    if dir.join("manifest.json").exists() {
        run_pjrt_part(&preset, &dir, &mut csv, &mut summary);
    } else {
        println!("(artifacts/{preset} not built; PJRT rows skipped)");
    }

    let bench_dir = dipaco::metrics::results_dir().join("bench");
    let out = bench_dir.join("bench_train_step.csv");
    std::fs::create_dir_all(&bench_dir).unwrap();
    std::fs::write(&out, csv.join("\n")).unwrap();
    println!("\ncsv: {}", out.display());
    let json_out = bench_dir.join("BENCH_train_step.json");
    dipaco::metrics::write_summary(&json_out, summary).unwrap();
    println!("summary: {}", json_out.display());
}

fn run_pjrt_part(
    preset: &str,
    dir: &std::path::Path,
    csv: &mut Vec<String>,
    summary: &mut Vec<(&str, Json)>,
) {
    let engine = Engine::load(dir).expect("engine");
    let mc = engine.model().clone();
    let n = engine.manifest.total_params;
    println!(
        "train-step bench: preset={preset} params={n} batch={} seq={}\n",
        mc.batch, mc.seq_train
    );

    let theta = engine.init(0).unwrap();
    let m = vec![0.0f32; n];
    let v = vec![0.0f32; n];
    let mut rng = Rng::new(1);
    let tokens_train: Vec<i32> = (0..mc.batch * mc.seq_train)
        .map(|_| rng.gen_range(mc.vocab) as i32)
        .collect();
    let tokens_eval: Vec<i32> = (0..mc.batch * mc.seq_eval)
        .map(|_| rng.gen_range(mc.vocab) as i32)
        .collect();
    let tokens_prefix: Vec<i32> = (0..mc.batch * mc.prefix)
        .map(|_| rng.gen_range(mc.vocab) as i32)
        .collect();

    let toks_per_step = (mc.batch * mc.seq_train) as f64;
    let r = Bencher::new("train_step (fwd+bwd+AdamW)")
        .runs(8, 30)
        .throughput(toks_per_step)
        .run(|| {
            std::hint::black_box(
                engine
                    .train_step(&theta, &m, &v, 1.0, 1e-3, &tokens_train)
                    .unwrap(),
            );
        });
    csv.push(format!("train_step,{:.6},{:.0}", r.mean_s, r.throughput.unwrap()));
    summary.push(("train_step_tokens_per_s", Json::num(r.throughput.unwrap())));

    let r = Bencher::new("token_logprobs seq_train")
        .runs(8, 30)
        .run(|| {
            std::hint::black_box(
                engine
                    .token_logprobs(&theta, &tokens_train, mc.seq_train)
                    .unwrap(),
            );
        });
    csv.push(format!("logprobs_train,{:.6},0", r.mean_s));

    let r = Bencher::new("token_logprobs seq_eval")
        .runs(8, 30)
        .run(|| {
            std::hint::black_box(
                engine
                    .token_logprobs(&theta, &tokens_eval, mc.seq_eval)
                    .unwrap(),
            );
        });
    csv.push(format!("logprobs_eval,{:.6},0", r.mean_s));

    let r = Bencher::new("features (router prefix)")
        .runs(8, 30)
        .run(|| {
            std::hint::black_box(engine.features(&theta, &tokens_prefix).unwrap());
        });
    csv.push(format!("features,{:.6},0", r.mean_s));

    // §Perf A/B: per-step dispatch loop vs fused lax.scan train_steps
    if mc.tau > 0 && engine.has("train_steps") {
        let tau = mc.tau;
        let batches: Vec<i32> = (0..tau * mc.batch * mc.seq_train)
            .map(|_| rng.gen_range(mc.vocab) as i32)
            .collect();
        let lrs: Vec<f32> = vec![1e-3; tau];
        let r_loop = Bencher::new(&format!("tau={tau} steps, per-step dispatch"))
            .runs(3, 8)
            .throughput((tau * mc.batch * mc.seq_train) as f64)
            .run(|| {
                let (mut th, mut mm, mut vv) = (theta.clone(), m.clone(), v.clone());
                for i in 0..tau {
                    let out = engine
                        .train_step(
                            &th,
                            &mm,
                            &vv,
                            (i + 1) as f32,
                            1e-3,
                            &batches[i * mc.batch * mc.seq_train..(i + 1) * mc.batch * mc.seq_train],
                        )
                        .unwrap();
                    th = out.theta;
                    mm = out.m;
                    vv = out.v;
                }
                std::hint::black_box(th.len());
            });
        csv.push(format!("tau_loop,{:.6},{:.0}", r_loop.mean_s, r_loop.throughput.unwrap()));
        let r_fused = Bencher::new(&format!("tau={tau} steps, fused lax.scan"))
            .runs(3, 8)
            .throughput((tau * mc.batch * mc.seq_train) as f64)
            .run(|| {
                std::hint::black_box(
                    engine.train_steps(&theta, &m, &v, 0.0, &lrs, &batches).unwrap().0.len(),
                );
            });
        csv.push(format!("tau_fused,{:.6},{:.0}", r_fused.mean_s, r_fused.throughput.unwrap()));
        compare(&r_loop, &r_fused);
    } else {
        println!("(artifacts built without train_steps; fused A/B skipped)");
    }

    // dispatch overhead: literal building for the train_step argument set
    // (the rust-side cost that is NOT XLA compute)
    let r = Bencher::new("dispatch overhead (literals only)")
        .runs(20, 100)
        .run(|| {
            let a = xla_literals(&theta, &m, &v, &tokens_train, mc.batch, mc.seq_train);
            std::hint::black_box(a);
        });
    csv.push(format!("dispatch_literals,{:.6},0", r.mean_s));
}

fn xla_literals(
    theta: &[f32],
    m: &[f32],
    v: &[f32],
    tokens: &[i32],
    batch: usize,
    seq: usize,
) -> usize {
    let a = xla::Literal::vec1(theta);
    let b = xla::Literal::vec1(m);
    let c = xla::Literal::vec1(v);
    let d = xla::Literal::scalar(1.0f32);
    let e = xla::Literal::scalar(1e-3f32);
    let f = xla::Literal::vec1(tokens)
        .reshape(&[batch as i64, seq as i64])
        .unwrap();
    a.size_bytes() + b.size_bytes() + c.size_bytes() + d.size_bytes() + e.size_bytes() + f.size_bytes()
}
