//! Checkpoint-format bench (ISSUE 3 / DESIGN.md "Checkpoint format"):
//!
//! 1. full-theta DPC1 load vs DPC2 single-section load — section reads
//!    must scale with MODULE size, not `total_params`, so the single-
//!    section time stays ~flat while the full load grows with the model;
//! 2. bytes-read-per-phase for the executor path: owned-sections reads
//!    through [`SectionReader`] vs loading every path checkpoint in full.
//!
//! CSV lands in `results/bench/bench_ckpt.csv`.

use dipaco::benchkit::{compare, header, Bencher};
use dipaco::config::TopologySpec;
use dipaco::coordinator::outer::shard_modules;
use dipaco::params::checkpoint::{load_section, Checkpoint, SectionReader};
use dipaco::params::manifest::Manifest;
use dipaco::topology::Topology;
use dipaco::util::json::Json;
use dipaco::util::rng::Rng;

/// Synthetic manifest with `blocks` transformer blocks at width `d`
/// (no artifacts needed).
fn synthetic_manifest(d: usize, blocks: usize) -> Manifest {
    let mut leaves = Vec::new();
    let mut off = 0usize;
    let mut push = |name: String, shape: Vec<usize>, off: &mut usize| {
        let size: usize = shape.iter().product();
        leaves.push(format!(
            r#"{{"name":"{name}","offset":{off},"size":{size},"shape":[{}]}}"#,
            shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
        ));
        *off += size;
    };
    push("embed.tok".into(), vec![256, d], &mut off);
    push("embed.pos".into(), vec![256, d], &mut off);
    for i in 0..blocks {
        push(format!("block{i}.attn.wq"), vec![d, d], &mut off);
        push(format!("block{i}.attn.wk"), vec![d, d], &mut off);
        push(format!("block{i}.attn.wv"), vec![d, d], &mut off);
        push(format!("block{i}.attn.wo"), vec![d, d], &mut off);
        push(format!("block{i}.mlp.w1"), vec![d, 4 * d], &mut off);
        push(format!("block{i}.mlp.w2"), vec![4 * d, d], &mut off);
    }
    push("head.w".into(), vec![d, 256], &mut off);
    let text = format!(
        r#"{{"preset":"bench","config":{{"vocab":256,"d_model":{d},"n_layers":{blocks},
          "n_heads":4,"d_ff":{f},"seq_train":128,"seq_eval":256,"batch":8,"prefix":32,"d_head":16}},
          "total_params":{off},"leaves":[{ls}],"entrypoints":[]}}"#,
        f = 4 * d,
        ls = leaves.join(",")
    );
    Manifest::from_json(&Json::parse(&text).unwrap()).unwrap()
}

fn main() {
    println!("checkpoint-format bench: DPC1 full load vs DPC2 section access\n");
    header();
    let dir = std::env::temp_dir().join(format!("dipaco-bench-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut csv =
        vec!["part,scale,total_params,section_params,variant,mean_s,bytes".to_string()];
    let mut summary: Vec<(&str, Json)> = Vec::new();

    // ---- part 1: one grid level per block, K=4 each, so the per-module
    // section size stays ~constant while total_params grows with blocks.
    for (blocks, label) in [(4usize, "4-block"), (16, "16-block")] {
        let man = synthetic_manifest(64, blocks);
        let topo = Topology::build(&man, &TopologySpec::grid(vec![4; blocks]));
        let mut rng = Rng::new(0);
        let theta: Vec<f32> =
            (0..man.total_params).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let after: Vec<f32> = theta.iter().map(|&x| x + 0.001).collect();

        // worker-style sectioned file for path 0 (delta per module), in
        // both formats
        let (ck, modules) = topo.delta_checkpoint(0, &theta, &after);
        let f1 = dir.join(format!("{label}.v1.dpc"));
        let f2 = dir.join(format!("{label}.v2.dpc"));
        ck.save_dpc1(&f1).unwrap();
        ck.save(&f2).unwrap();

        // a mid-file grid-module section (level 1 = first grid level)
        let section = modules[1].delta_section();
        let section_params = ck.get(&section).unwrap().len();

        let r = Bencher::new(&format!("DPC1 full load ({label})"))
            .runs(10, 60)
            .run(|| {
                std::hint::black_box(Checkpoint::load(&f1).unwrap());
            });
        csv.push(format!(
            "full_vs_section,{label},{},{section_params},dpc1_full,{:.9},{}",
            man.total_params,
            r.mean_s,
            std::fs::metadata(&f1).unwrap().len()
        ));
        let full = r;

        let r = Bencher::new(&format!("DPC2 full load ({label})"))
            .runs(10, 60)
            .run(|| {
                std::hint::black_box(Checkpoint::load(&f2).unwrap());
            });
        csv.push(format!(
            "full_vs_section,{label},{},{section_params},dpc2_full,{:.9},{}",
            man.total_params,
            r.mean_s,
            std::fs::metadata(&f2).unwrap().len()
        ));

        let r = Bencher::new(&format!("DPC2 single section ({label})"))
            .runs(10, 200)
            .run(|| {
                std::hint::black_box(load_section(&f2, &section).unwrap());
            });
        csv.push(format!(
            "full_vs_section,{label},{},{section_params},dpc2_section,{:.9},{}",
            man.total_params,
            r.mean_s,
            4 * section_params
        ));
        compare(&full, &r);
        let buffered = r;

        // zero-copy pass: mmap-backed reader, and read_into with a buffer
        // reused across reads (the executor's steady-state shape)
        let r = Bencher::new(&format!("DPC2 section, mmap reader ({label})"))
            .runs(10, 200)
            .run(|| {
                let mut rd = SectionReader::open_mapped(&f2).unwrap();
                std::hint::black_box(rd.read(&section).unwrap());
            });
        csv.push(format!(
            "full_vs_section,{label},{},{section_params},dpc2_section_mmap,{:.9},{}",
            man.total_params,
            r.mean_s,
            4 * section_params
        ));
        let mut buf: Vec<f32> = Vec::new();
        let r = Bencher::new(&format!("DPC2 section, mmap + reused buf ({label})"))
            .runs(10, 200)
            .run(|| {
                let mut rd = SectionReader::open_mapped(&f2).unwrap();
                rd.read_into(&section, &mut buf).unwrap();
                std::hint::black_box(buf.len());
            });
        csv.push(format!(
            "full_vs_section,{label},{},{section_params},dpc2_section_into,{:.9},{}",
            man.total_params,
            r.mean_s,
            4 * section_params
        ));
        compare(&buffered, &r);
        if label == "16-block" {
            summary.push(("section_buffered_s", Json::num(buffered.mean_s)));
            summary.push(("section_mmap_into_s", Json::num(r.mean_s)));
            summary.push(("section_mmap_speedup", Json::num(buffered.mean_s / r.mean_s)));
        }
        println!();
    }

    // ---- part 2: executor bytes-per-phase, 4x4 grid, 2 executor shards.
    // Per executor: read only owned `delta:` sections of each of the P
    // path checkpoints, vs the old full-theta load per row.
    let man = synthetic_manifest(64, 8);
    let topo = Topology::build(&man, &TopologySpec::grid(vec![4, 4]));
    let mut rng = Rng::new(1);
    let theta: Vec<f32> = (0..man.total_params).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let after: Vec<f32> = theta.iter().map(|&x| x + 0.001).collect();
    let files: Vec<std::path::PathBuf> = (0..topo.paths)
        .map(|p| {
            let (ck, _) = topo.delta_checkpoint(p, &theta, &after);
            let f = dir.join(format!("exec-path{p}.dpc"));
            ck.save(&f).unwrap();
            f
        })
        .collect();
    let shards = shard_modules(&topo, 2);
    let owned = &shards[0];
    let full_phase_bytes: u64 = files.iter().map(|f| std::fs::metadata(f).unwrap().len()).sum();

    let mut owned_bytes = 0u64;
    let r = Bencher::new("executor phase: owned sections only (DPC2)")
        .runs(5, 30)
        .run(|| {
            let mut bytes = 0u64;
            for (p, f) in files.iter().enumerate() {
                let mut reader = SectionReader::open(f).unwrap();
                for m in owned {
                    if topo.expert_of(p, m.level) != m.expert {
                        continue; // path doesn't traverse this module
                    }
                    std::hint::black_box(reader.read(&m.delta_section()).unwrap());
                }
                bytes += reader.bytes_read();
            }
            owned_bytes = bytes;
        });
    csv.push(format!(
        "executor_phase,4x4,{},0,owned_sections,{:.9},{owned_bytes}",
        man.total_params, r.mean_s
    ));
    let owned_r = r;

    let r = Bencher::new("executor phase: full load per row (baseline)")
        .runs(5, 30)
        .run(|| {
            for f in &files {
                std::hint::black_box(Checkpoint::load(f).unwrap());
            }
        });
    csv.push(format!(
        "executor_phase,4x4,{},0,full_loads,{:.9},{full_phase_bytes}",
        man.total_params, r.mean_s
    ));
    compare(&r, &owned_r);

    // the actual executor_loop configuration since the zero-copy pass:
    // mmap-backed reader, deltas decoded into one reused buffer
    let mut delta: Vec<f32> = Vec::new();
    let r = Bencher::new("executor phase: owned sections, mmap + reuse")
        .runs(5, 30)
        .run(|| {
            for (p, f) in files.iter().enumerate() {
                let mut reader = SectionReader::open_mapped(f).unwrap();
                for m in owned {
                    if topo.expert_of(p, m.level) != m.expert {
                        continue;
                    }
                    reader.read_into(&m.delta_section(), &mut delta).unwrap();
                    std::hint::black_box(delta.len());
                }
            }
        });
    csv.push(format!(
        "executor_phase,4x4,{},0,owned_sections_mmap,{:.9},{owned_bytes}",
        man.total_params, r.mean_s
    ));
    compare(&owned_r, &r);
    summary.push(("executor_owned_s", Json::num(owned_r.mean_s)));
    summary.push(("executor_owned_mmap_s", Json::num(r.mean_s)));
    summary.push(("executor_mmap_speedup", Json::num(owned_r.mean_s / r.mean_s)));
    summary.push(("owned_bytes_per_phase", Json::num(owned_bytes as f64)));
    summary.push(("full_bytes_per_phase", Json::num(full_phase_bytes as f64)));
    println!(
        "\nexecutor bytes/phase: owned-sections {owned_bytes} vs full {full_phase_bytes} \
         ({:.1}x less I/O)",
        full_phase_bytes as f64 / owned_bytes.max(1) as f64
    );

    let bench_dir = dipaco::metrics::results_dir().join("bench");
    let out = bench_dir.join("bench_ckpt.csv");
    std::fs::create_dir_all(&bench_dir).unwrap();
    std::fs::write(&out, csv.join("\n")).unwrap();
    println!("csv: {}", out.display());
    let json_out = bench_dir.join("BENCH_ckpt.json");
    dipaco::metrics::write_summary(&json_out, summary).unwrap();
    println!("summary: {}", json_out.display());
}
