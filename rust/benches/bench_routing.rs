//! Routing bench — offline coarse-routing costs: k-means fit/assign,
//! product k-means (paper §7.3: assignment cost grows with the sqrt of
//! pair count), and the logistic discriminative router. These run once
//! per re-sharding phase, over the whole corpus — they must be cheap
//! relative to a single path's training phase.

use dipaco::benchkit::{compare, header, Bencher};
use dipaco::routing::kmeans::{KMeans, ProductKMeans};
use dipaco::routing::logistic::{Logistic, TrainOpts};
use dipaco::util::rng::Rng;

fn features(n: usize, d: usize, k: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 2.0)).collect())
        .collect();
    let mut zs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % k;
        zs.push(centers[c].iter().map(|&m| rng.normal_f32(m, 0.5)).collect());
        labels.push(c);
    }
    (zs, labels)
}

fn main() {
    println!("routing bench (offline coarse routing, paper §2.4/§7.3)\n");
    header();
    let mut csv = vec!["bench,mean_s".to_string()];
    // corpus-scale: 2000 docs, d=64 features (path preset d_model)
    let (zs, labels) = features(2000, 64, 16, 1);

    let fit16 = Bencher::new("k-means fit k=16 (2k docs, d=64)")
        .runs(5, 12)
        .run(|| {
            let mut rng = Rng::new(2);
            std::hint::black_box(KMeans::fit(&zs, 16, 25, &mut rng));
        });
    csv.push(format!("kmeans_fit_k16,{:.6}", fit16.mean_s));

    let fitp = Bencher::new("product k-means fit 4x4 (2k docs)")
        .runs(5, 12)
        .run(|| {
            let mut rng = Rng::new(2);
            std::hint::black_box(ProductKMeans::fit(&zs, 4, 4, 25, &mut rng));
        });
    csv.push(format!("product_kmeans_fit_4x4,{:.6}", fitp.mean_s));
    compare(&fit16, &fitp);

    let mut rng = Rng::new(2);
    let km = KMeans::fit(&zs, 16, 25, &mut rng);
    let r = Bencher::new("k-means assign 2k docs")
        .runs(10, 50)
        .throughput(2000.0)
        .run(|| {
            for z in &zs {
                std::hint::black_box(km.assign(z));
            }
        });
    csv.push(format!("kmeans_assign_2k,{:.6}", r.mean_s));

    let r = Bencher::new("logistic fit k=16 (2k docs)")
        .runs(3, 8)
        .run(|| {
            std::hint::black_box(Logistic::fit(
                &zs,
                &labels,
                16,
                &TrainOpts {
                    epochs: 25,
                    ..Default::default()
                },
            ));
        });
    csv.push(format!("logistic_fit_k16,{:.6}", r.mean_s));

    let lg = Logistic::fit(&zs, &labels, 16, &TrainOpts { epochs: 10, ..Default::default() });
    let r = Bencher::new("logistic assign 2k docs")
        .runs(10, 50)
        .throughput(2000.0)
        .run(|| {
            for z in &zs {
                std::hint::black_box(lg.predict(z));
            }
        });
    csv.push(format!("logistic_assign_2k,{:.6}", r.mean_s));

    let out = dipaco::metrics::results_dir().join("bench_routing.csv");
    std::fs::create_dir_all(out.parent().unwrap()).unwrap();
    std::fs::write(&out, csv.join("\n")).unwrap();
    println!("\ncsv: {}", out.display());
}
