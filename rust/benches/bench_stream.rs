//! Streaming outer sync bench (DESIGN.md "Streaming outer sync"):
//!
//! 1. **Wire bytes per codec** — the same per-module path deltas encoded
//!    as f32 / bf16 / int8-quantized sections; int8 must cut published
//!    bytes by >= 3.5x vs f32 (header + section overhead included);
//! 2. **Codec throughput** — encode (with error-feedback residual) and
//!    decode rates for each codec;
//! 3. **Exchange window** — the last-publish -> last-applied gap. Serial
//!    publication leaves ALL read/decode/reduce/apply work after the
//!    final row lands; staggered per-module-group publication overlaps
//!    everything but the final groups with inner training, so the gap
//!    shrinks by ~the group count.
//!
//! CSV lands in `results/bench/bench_stream.csv`, baselines in
//! `results/bench/BENCH_stream.json` (merged by `make bench-all`).

use std::collections::HashMap;
use std::time::Instant;

use dipaco::benchkit::{header, Bencher};
use dipaco::config::{DeltaCodec, TopologySpec};
use dipaco::optim::{Nesterov, OuterAccumulator};
use dipaco::params::checkpoint::{decode_delta_into, encode_delta_feedback, Checkpoint, SectionReader};
use dipaco::params::manifest::Manifest;
use dipaco::topology::{ModuleId, Topology};
use dipaco::util::json::Json;
use dipaco::util::rng::Rng;

/// Synthetic manifest with `blocks` transformer blocks at width `d`
/// (no artifacts needed).
fn synthetic_manifest(d: usize, blocks: usize) -> Manifest {
    let mut leaves = Vec::new();
    let mut off = 0usize;
    let mut push = |name: String, shape: Vec<usize>, off: &mut usize| {
        let size: usize = shape.iter().product();
        leaves.push(format!(
            r#"{{"name":"{name}","offset":{off},"size":{size},"shape":[{}]}}"#,
            shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
        ));
        *off += size;
    };
    push("embed.tok".into(), vec![256, d], &mut off);
    push("embed.pos".into(), vec![256, d], &mut off);
    for i in 0..blocks {
        push(format!("block{i}.attn.wq"), vec![d, d], &mut off);
        push(format!("block{i}.attn.wk"), vec![d, d], &mut off);
        push(format!("block{i}.attn.wv"), vec![d, d], &mut off);
        push(format!("block{i}.attn.wo"), vec![d, d], &mut off);
        push(format!("block{i}.mlp.w1"), vec![d, 4 * d], &mut off);
        push(format!("block{i}.mlp.w2"), vec![4 * d, d], &mut off);
    }
    push("head.w".into(), vec![d, 256], &mut off);
    let text = format!(
        r#"{{"preset":"bench","config":{{"vocab":256,"d_model":{d},"n_layers":{blocks},
          "n_heads":4,"d_ff":{f},"seq_train":128,"seq_eval":256,"batch":8,"prefix":32,"d_head":16}},
          "total_params":{off},"leaves":[{ls}],"entrypoints":[]}}"#,
        f = 4 * d,
        ls = leaves.join(",")
    );
    Manifest::from_json(&Json::parse(&text).unwrap()).unwrap()
}

/// Save one group-row file: each module's delta encoded with `codec`.
fn save_group_row(
    topo: &Topology,
    codec: DeltaCodec,
    group: &[ModuleId],
    before: &[f32],
    after: &[f32],
    file: &std::path::Path,
) {
    let mut delta = Vec::new();
    let mut ck = Checkpoint::new();
    for &m in group {
        topo.module_delta_into(m, before, after, &mut delta);
        let (wire, _res) = encode_delta_feedback(codec, &delta);
        ck = ck.with(&m.delta_section(), wire);
    }
    ck.save(file).unwrap();
}

fn main() {
    println!("streaming outer sync bench: codec bytes + exchange-window gap\n");
    header();
    let dir = std::env::temp_dir().join(format!("dipaco-bench-stream-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut csv = vec!["part,variant,groups,mean_s,bytes".to_string()];
    let mut summary: Vec<(&str, Json)> = Vec::new();

    let man = synthetic_manifest(64, 8);
    let topo = Topology::build(&man, &TopologySpec::grid(vec![4, 4]));
    let mut rng = Rng::new(0);
    let theta: Vec<f32> = (0..man.total_params).map(|_| rng.normal_f32(0.0, 0.1)).collect();
    let after: Vec<f32> = theta
        .iter()
        .map(|&x| 0.995 * x - 0.01 * rng.normal_f32(0.0, 1.0))
        .collect();

    // ---- part 1+2: wire bytes and codec throughput on path 0's deltas
    let modules = topo.modules_of_path(0);
    let mut deltas: Vec<Vec<f32>> = Vec::new();
    for &m in &modules {
        let mut d = Vec::new();
        topo.module_delta_into(m, &theta, &after, &mut d);
        deltas.push(d);
    }
    let mut wire_bytes: HashMap<&str, u64> = HashMap::new();
    for codec in [DeltaCodec::F32, DeltaCodec::Bf16, DeltaCodec::Int8] {
        let file = dir.join(format!("path0.{codec}.dpc"));
        save_group_row(&topo, codec, &modules, &theta, &after, &file);
        let bytes = std::fs::metadata(&file).unwrap().len();
        wire_bytes.insert(codec.as_str(), bytes);

        let r = Bencher::new(&format!("{codec} encode + residual (path 0)"))
            .runs(10, 100)
            .run(|| {
                for d in &deltas {
                    let (wire, res) = encode_delta_feedback(codec, d);
                    std::hint::black_box((wire.len(), res.len()));
                }
            });
        csv.push(format!("codec_encode,{codec},1,{:.9},{bytes}", r.mean_s));

        let mut words: Vec<f32> = Vec::new();
        let mut out: Vec<f32> = Vec::new();
        let r = Bencher::new(&format!("{codec} decode (mmap read + dequant)"))
            .runs(10, 100)
            .run(|| {
                let mut rd = SectionReader::open_mapped(&file).unwrap();
                for m in &modules {
                    rd.read_into(&m.delta_section(), &mut words).unwrap();
                    decode_delta_into(codec, &words, &mut out).unwrap();
                    std::hint::black_box(out.len());
                }
            });
        csv.push(format!("codec_decode,{codec},1,{:.9},{bytes}", r.mean_s));
        println!();
    }
    let f32_bytes = wire_bytes["f32"];
    let int8_bytes = wire_bytes["int8"];
    let reduction = f32_bytes as f64 / int8_bytes.max(1) as f64;
    summary.push(("wire_bytes_f32", Json::num(f32_bytes as f64)));
    summary.push(("wire_bytes_bf16", Json::num(wire_bytes["bf16"] as f64)));
    summary.push(("wire_bytes_int8", Json::num(int8_bytes as f64)));
    summary.push(("int8_bytes_reduction", Json::num(reduction)));
    println!(
        "published bytes per path row: f32 {f32_bytes}, bf16 {}, int8 {int8_bytes} \
         ({reduction:.2}x smaller than f32)",
        wire_bytes["bf16"]
    );
    assert!(
        reduction >= 3.5,
        "int8 wire must cut bytes >= 3.5x vs f32, got {reduction:.2}x"
    );

    // ---- part 3: exchange window. Every path publishes its delta split
    // into `groups` rows; only the FINAL group of each path lands after
    // inner training ends, so the post-last-publish gap is the time to
    // read/decode/reduce/apply just those rows (plus the modules' outer
    // steps). groups=1 is the serial baseline: the whole exchange sits in
    // the window.
    let codec = DeltaCodec::F32;
    let mut gaps: Vec<(usize, f64, u64)> = Vec::new();
    for groups in [1usize, 3] {
        // stage the rows: (path, gid, file, modules)
        let mut rows: Vec<(usize, usize, std::path::PathBuf, Vec<ModuleId>)> = Vec::new();
        for p in 0..topo.paths {
            let gs = topo.publish_groups(p, groups);
            for (gid, g) in gs.iter().enumerate() {
                let file = dir.join(format!("win-p{p}-g{gid}-of{groups}.dpc"));
                save_group_row(&topo, codec, g, &theta, &after, &file);
                rows.push((p, gid, file, g.clone()));
            }
        }
        let last_gid = groups.min(modules.len()) - 1;
        let window_bytes: u64 = rows
            .iter()
            .filter(|(_, gid, _, _)| *gid == last_gid)
            .map(|(_, _, f, _)| std::fs::metadata(f).unwrap().len())
            .sum();

        let base: HashMap<ModuleId, Vec<f32>> = topo
            .all_modules()
            .iter()
            .map(|&m| (m, vec![0.0f32; topo.levels[m.level].size]))
            .collect();
        let mut words: Vec<f32> = Vec::new();
        let mut delta: Vec<f32> = Vec::new();
        let mut avg: Vec<f32> = Vec::new();
        let (warmup, iters) = (3, 30);
        let mut total = 0.0f64;
        for it in 0..warmup + iters {
            let mut accs: HashMap<ModuleId, OuterAccumulator> = base
                .iter()
                .map(|(&m, v)| (m, OuterAccumulator::new(v.len())))
                .collect();
            let mut params = base.clone();
            let mut opt = Nesterov::new(0.7, 0.9);
            // rows before the final group arrive DURING inner training —
            // their cost overlaps compute and stays out of the window
            for (_, _, file, mods) in rows.iter().filter(|(_, g, _, _)| *g != last_gid) {
                let mut rd = SectionReader::open_mapped(file).unwrap();
                for m in mods {
                    rd.read_into(&m.delta_section(), &mut words).unwrap();
                    decode_delta_into(codec, &words, &mut delta).unwrap();
                    accs.get_mut(m).unwrap().add(&delta, 1.0);
                }
            }
            // the window: final rows land, remaining reduce + apply runs
            let t0 = Instant::now();
            for (_, _, file, mods) in rows.iter().filter(|(_, g, _, _)| *g == last_gid) {
                let mut rd = SectionReader::open_mapped(file).unwrap();
                for m in mods {
                    rd.read_into(&m.delta_section(), &mut words).unwrap();
                    decode_delta_into(codec, &words, &mut delta).unwrap();
                    accs.get_mut(m).unwrap().add(&delta, 1.0);
                }
            }
            for (&m, acc) in &accs {
                acc.average_into(&mut avg);
                opt.step(m, params.get_mut(&m).unwrap(), &avg);
            }
            let dt = t0.elapsed().as_secs_f64();
            std::hint::black_box(&params);
            if it >= warmup {
                total += dt;
            }
        }
        let mean = total / iters as f64;
        println!(
            "exchange window, {groups} group(s): {:.3} ms gap, {window_bytes} bytes in window",
            mean * 1e3
        );
        csv.push(format!("exchange_window,gap,{groups},{mean:.9},{window_bytes}"));
        gaps.push((groups, mean, window_bytes));
    }
    let (serial, staggered) = (&gaps[0], &gaps[1]);
    let shrink = serial.1 / staggered.1.max(1e-12);
    summary.push(("gap_serial_s", Json::num(serial.1)));
    summary.push(("gap_staggered_s", Json::num(staggered.1)));
    summary.push(("stagger_gap_shrink", Json::num(shrink)));
    summary.push(("window_bytes_serial", Json::num(serial.2 as f64)));
    summary.push(("window_bytes_staggered", Json::num(staggered.2 as f64)));
    println!(
        "\nlast-publish -> last-applied gap: serial {:.3} ms vs staggered {:.3} ms \
         ({shrink:.2}x smaller window)",
        serial.1 * 1e3,
        staggered.1 * 1e3
    );

    let bench_dir = dipaco::metrics::results_dir().join("bench");
    let out = bench_dir.join("bench_stream.csv");
    std::fs::create_dir_all(&bench_dir).unwrap();
    std::fs::write(&out, csv.join("\n")).unwrap();
    println!("csv: {}", out.display());
    let json_out = bench_dir.join("BENCH_stream.json");
    dipaco::metrics::write_summary(&json_out, summary).unwrap();
    println!("summary: {}", json_out.display());
    let _ = std::fs::remove_dir_all(&dir);
}
