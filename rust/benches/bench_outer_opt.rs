//! §3.3 systems bench — outer-optimization efficiency.
//!
//! Paper claim: sharded executors with ONLINE parameter-gradient averaging
//! keep "average time per phase for outer update under 2 minutes" at
//! hundreds of paths, vs a naive gather-everything-then-average executor.
//! Reproduced shape: online+sharded beats naive, and the outer update is
//! a small fraction of phase wallclock.
//!
//! No PJRT needed: synthetic checkpoints at path-preset scale (260k f32).

use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use dipaco::benchkit::{compare, header, Bencher};
use dipaco::config::{DilocoConfig, TopologySpec};
use dipaco::coordinator::db::{CheckpointDb, CkptRow};
use dipaco::coordinator::outer::{
    naive_phase_outer, run_phase_outer, shard_modules, OuterConfig,
};
use dipaco::optim::Nesterov;
use dipaco::params::manifest::Manifest;
use dipaco::topology::{ModuleStore, Topology};
use dipaco::util::json::Json;
use dipaco::util::rng::Rng;

/// Manifest shaped like the `path` preset (4 blocks, d=64) without
/// requiring artifacts.
fn synthetic_manifest() -> Manifest {
    let d = 64;
    let mut leaves = Vec::new();
    let mut off = 0usize;
    let mut push = |name: String, shape: Vec<usize>, off: &mut usize| {
        let size: usize = shape.iter().product();
        leaves.push(format!(
            r#"{{"name":"{name}","offset":{off},"size":{size},"shape":[{}]}}"#,
            shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
        ));
        *off += size;
    };
    push("embed.tok".into(), vec![256, d], &mut off);
    push("embed.pos".into(), vec![256, d], &mut off);
    for i in 0..4 {
        for (sfx, shape) in [
            ("ln1.scale", vec![d]),
            ("ln1.bias", vec![d]),
            ("attn.wq", vec![d, d]),
            ("attn.wk", vec![d, d]),
            ("attn.wv", vec![d, d]),
            ("attn.wo", vec![d, d]),
            ("ln2.scale", vec![d]),
            ("ln2.bias", vec![d]),
            ("mlp.w1", vec![d, 4 * d]),
            ("mlp.b1", vec![4 * d]),
            ("mlp.w2", vec![4 * d, d]),
            ("mlp.b2", vec![d]),
        ] {
            push(format!("block{i}.{sfx}"), shape, &mut off);
        }
    }
    push("final.ln.scale".into(), vec![d], &mut off);
    push("final.ln.bias".into(), vec![d], &mut off);
    push("head.w".into(), vec![d, 256], &mut off);
    let text = format!(
        r#"{{"preset":"bench","config":{{"vocab":256,"d_model":{d},"n_layers":4,
          "n_heads":4,"d_ff":{f},"seq_train":128,"seq_eval":256,"batch":8,"prefix":32,"d_head":16}},
          "total_params":{off},"leaves":[{ls}],"entrypoints":[]}}"#,
        f = 4 * d,
        ls = leaves.join(",")
    );
    Manifest::from_json(&Json::parse(&text).unwrap()).unwrap()
}

/// Worker-style sectioned checkpoints: one `delta:L{l}E{e}` section per
/// traversed module (the DPC2 exchange unit), module list on the row.
fn make_ckpts(dir: &std::path::Path, topo: &Topology, theta: &[f32], paths: usize) -> Vec<CkptRow> {
    let mut rng = Rng::new(1);
    (0..paths)
        .map(|p| {
            let after: Vec<f32> = theta.iter().map(|&v| v + rng.normal_f32(0.0, 0.01)).collect();
            let file = dir.join(format!("path{p}.dpc"));
            let (ck, modules) = topo.delta_checkpoint(p, theta, &after);
            ck.save(&file).unwrap();
            CkptRow {
                rowid: 0,
                phase: 0,
                path_id: p,
                kind: "path".into(),
                file,
                step: 0,
                loss: 1.0,
                modules,
            }
        })
        .collect()
}

fn main() {
    let man = synthetic_manifest();
    println!(
        "outer-optimization bench: {} params/path (path-preset scale)\n",
        man.total_params
    );
    header();
    let dir = std::env::temp_dir().join(format!("dipaco-bench-outer-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut results_csv: Vec<String> = vec!["grid,paths,variant,executors,mean_s".to_string()];

    for (grid, label) in [(vec![2, 2], "2x2"), (vec![4, 4], "4x4")] {
        let spec = TopologySpec::grid(grid);
        let topo = Arc::new(Topology::build(&man, &spec));
        let theta: Vec<f32> = {
            let mut rng = Rng::new(0);
            (0..man.total_params).map(|_| rng.normal_f32(0.0, 0.1)).collect()
        };
        let rows = make_ckpts(&dir, &topo, &theta, topo.paths);
        let cfg = OuterConfig {
            diloco: DilocoConfig::default(),
            shard_sizes: vec![100; topo.paths],
            ..Default::default()
        };

        // naive: gather all, then average serially
        let topo_n = Arc::clone(&topo);
        let theta_n = theta.clone();
        let rows_n = rows.clone();
        let cfg_n = &cfg;
        let naive = Bencher::new(&format!("naive gather-then-average {label}"))
            .runs(5, 15)
            .run(move || {
                let store = Mutex::new(ModuleStore::from_base(&topo_n, &theta_n));
                let db = CheckpointDb::new();
                for r in &rows_n {
                    db.insert(r.clone());
                }
                let mut opt = Nesterov::new(0.7, 0.9);
                naive_phase_outer(&topo_n, &store, &mut opt, cfg_n, 0, &db).unwrap();
            });
        results_csv.push(format!("{label},{},naive,1,{:.6}", topo.paths, naive.mean_s));

        // online + sharded, 1..4 executors
        let mut best: Option<dipaco::benchkit::BenchResult> = None;
        for execs in [1usize, 2, 4] {
            let topo_o = Arc::clone(&topo);
            let theta_o = theta.clone();
            let rows_o = rows.clone();
            let cfg_o = &cfg;
            let r = Bencher::new(&format!("online sharded x{execs} {label}"))
                .runs(5, 15)
                .run(move || {
                    let store = Arc::new(Mutex::new(ModuleStore::from_base(&topo_o, &theta_o)));
                    let db = Arc::new(CheckpointDb::new());
                    let shards = shard_modules(&topo_o, execs);
                    let mut opts: Vec<Nesterov> =
                        (0..shards.len()).map(|_| Nesterov::new(0.7, 0.9)).collect();
                    let (tx, _rx) = channel();
                    for r in &rows_o {
                        db.insert(r.clone());
                    }
                    run_phase_outer(&topo_o, &store, &mut opts, &shards, cfg_o, 0, &db, &tx)
                        .unwrap();
                });
            results_csv.push(format!("{label},{},online,{execs},{:.6}", topo.paths, r.mean_s));
            if best.as_ref().map(|b| r.mean_s < b.mean_s).unwrap_or(true) {
                best = Some(r);
            }
        }
        compare(&naive, best.as_ref().unwrap());
        println!();
    }
    let bench_dir = dipaco::metrics::results_dir().join("bench");
    let out = bench_dir.join("bench_outer_opt.csv");
    std::fs::create_dir_all(&bench_dir).unwrap();
    std::fs::write(&out, results_csv.join("\n")).unwrap();
    println!("csv: {}", out.display());
}
