//! Section exchange plane bench (DESIGN.md "Section exchange plane"):
//! the same per-module delta sections pushed through the local
//! (shared-filesystem) plane and through the TCP loopback plane.
//!
//! 1. **Push throughput** — sections/s through `SectionTransport::publish`
//!    (one section per push so each sample is one framed round trip);
//! 2. **Push latency** — p50/p99 per section. Local publication is the
//!    checkpoint rename (a no-op at publish time), so its latency floor
//!    is what the TCP plane's connect + frame + ack overhead is judged
//!    against;
//! 3. **Read-back throughput** — sections/s through `open` + `read_into`,
//!    mmap'd DPC2 vs the executor-side section store, with a bitwise
//!    roundtrip check on every section.
//!
//! CSV lands in `results/bench/bench_transport.csv`, baselines in
//! `results/bench/BENCH_transport.json` (merged by `make bench-all`).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use dipaco::config::{TransportConfig, TransportMode};
use dipaco::params::checkpoint::Checkpoint;
use dipaco::topology::ModuleId;
use dipaco::transport::tcp::TcpExchange;
use dipaco::transport::{local::LocalTransport, PublishCtx, SectionTransport};
use dipaco::util::json::Json;
use dipaco::util::rng::Rng;

const LEVELS: usize = 8;
const EXPERTS: usize = 2;
const FLOATS_PER_SECTION: usize = 4096; // 16 KiB payload per section
const FILES: usize = 30;

fn modules() -> Vec<ModuleId> {
    let mut out = Vec::new();
    for level in 0..LEVELS {
        for expert in 0..EXPERTS {
            out.push(ModuleId { level, expert });
        }
    }
    out
}

/// Round-robin module shards for `executors` endpoints (what
/// `shard_modules` does, without needing a full Topology here).
fn shards(mods: &[ModuleId], executors: usize) -> Vec<Vec<ModuleId>> {
    let mut out = vec![Vec::new(); executors];
    for (i, &m) in mods.iter().enumerate() {
        out[i % executors].push(m);
    }
    out
}

/// Write one checkpoint per "path publish": every module's delta section,
/// deterministic in `tag` so the roundtrip check is exact.
fn write_ckpt(dir: &std::path::Path, tag: usize, mods: &[ModuleId]) -> (PathBuf, Vec<Vec<f32>>) {
    let mut rng = Rng::new(0xBE7C).fork(tag as u64);
    let mut ck = Checkpoint::new();
    let mut data = Vec::with_capacity(mods.len());
    for m in mods {
        let d: Vec<f32> = (0..FLOATS_PER_SECTION)
            .map(|_| rng.normal_f32(0.0, 0.1))
            .collect();
        ck = ck.with(&m.delta_section(), d.clone());
        data.push(d);
    }
    let file = dir.join(format!("push{tag}.dpc"));
    ck.save(&file).unwrap();
    (file, data)
}

struct PlaneResult {
    push_sections_per_s: f64,
    push_p50_us: f64,
    push_p99_us: f64,
    read_sections_per_s: f64,
}

fn percentile_us(sorted: &[Duration], p: usize) -> f64 {
    let idx = (sorted.len() * p / 100).min(sorted.len() - 1);
    sorted[idx].as_secs_f64() * 1e6
}

fn bench_plane(
    name: &str,
    transport: &dyn SectionTransport,
    files: &[(PathBuf, Vec<Vec<f32>>)],
    mods: &[ModuleId],
) -> PlaneResult {
    // ---- push: one section per publish call, so every latency sample is
    // one full section round trip through the plane
    let mut lat: Vec<Duration> = Vec::with_capacity(files.len() * mods.len());
    let t_all = Instant::now();
    for (path_id, (file, _)) in files.iter().enumerate() {
        let ctx = PublishCtx {
            phase: 0,
            path: path_id,
            kind: "path".to_string(),
        };
        for &m in mods {
            let t0 = Instant::now();
            transport.publish(&ctx, file, &[m]).unwrap();
            lat.push(t0.elapsed());
        }
    }
    let push_wall = t_all.elapsed().as_secs_f64();
    let pushes = lat.len();
    lat.sort();

    // ---- read-back: executor side, with a bitwise roundtrip check
    let mut buf: Vec<f32> = Vec::new();
    let t_read = Instant::now();
    for (file, data) in files {
        let mut src = transport.open(file).unwrap();
        for (m, want) in mods.iter().zip(data) {
            src.read_into(&m.delta_section(), &mut buf).unwrap();
            assert_eq!(&buf, want, "{name}: section {m} did not roundtrip");
        }
    }
    let read_wall = t_read.elapsed().as_secs_f64();

    let r = PlaneResult {
        push_sections_per_s: pushes as f64 / push_wall.max(1e-12),
        push_p50_us: percentile_us(&lat, 50),
        push_p99_us: percentile_us(&lat, 99),
        read_sections_per_s: (files.len() * mods.len()) as f64 / read_wall.max(1e-12),
    };
    println!(
        "{name:>5}: push {:>9.0} sections/s  p50 {:>7.1} us  p99 {:>7.1} us  \
         read {:>9.0} sections/s",
        r.push_sections_per_s, r.push_p50_us, r.push_p99_us, r.read_sections_per_s
    );
    r
}

fn main() {
    println!(
        "section exchange plane bench: {} files x {} sections x {} KiB\n",
        FILES,
        LEVELS * EXPERTS,
        FLOATS_PER_SECTION * 4 / 1024
    );
    let dir = std::env::temp_dir().join(format!("dipaco-bench-transport-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mods = modules();
    let files: Vec<(PathBuf, Vec<Vec<f32>>)> =
        (0..FILES).map(|i| write_ckpt(&dir, i, &mods)).collect();

    let local = bench_plane("local", &LocalTransport, &files, &mods);

    let tcp_cfg = TransportConfig {
        mode: TransportMode::Tcp,
        ..Default::default()
    };
    let exchange = TcpExchange::start(&shards(&mods, 2), tcp_cfg, None).unwrap();
    let tcp = bench_plane("tcp", exchange.as_ref(), &files, &mods);
    let store = exchange.store_stats();
    assert_eq!(
        store.puts as usize,
        FILES * mods.len(),
        "every pushed section must be accepted exactly once"
    );
    assert_eq!(store.nacks, 0, "loopback pushes must not nack");

    let overhead = tcp.push_p99_us / local.push_p99_us.max(1e-9);
    println!(
        "\ntcp loopback p99 push overhead vs local publish: {overhead:.1}x \
         ({} resends)",
        exchange.resends()
    );

    let bench_dir = dipaco::metrics::results_dir().join("bench");
    std::fs::create_dir_all(&bench_dir).unwrap();
    let mut csv = vec!["plane,metric,value".to_string()];
    for (plane, r) in [("local", &local), ("tcp", &tcp)] {
        csv.push(format!("{plane},push_sections_per_s,{:.3}", r.push_sections_per_s));
        csv.push(format!("{plane},push_p50_us,{:.3}", r.push_p50_us));
        csv.push(format!("{plane},push_p99_us,{:.3}", r.push_p99_us));
        csv.push(format!("{plane},read_sections_per_s,{:.3}", r.read_sections_per_s));
    }
    let out = bench_dir.join("bench_transport.csv");
    std::fs::write(&out, csv.join("\n")).unwrap();
    println!("csv: {}", out.display());

    let summary: Vec<(&str, Json)> = vec![
        ("push_sections_per_s_local", Json::num(local.push_sections_per_s)),
        ("push_p50_us_local", Json::num(local.push_p50_us)),
        ("push_p99_us_local", Json::num(local.push_p99_us)),
        ("read_sections_per_s_local", Json::num(local.read_sections_per_s)),
        ("push_sections_per_s_tcp", Json::num(tcp.push_sections_per_s)),
        ("push_p50_us_tcp", Json::num(tcp.push_p50_us)),
        ("push_p99_us_tcp", Json::num(tcp.push_p99_us)),
        ("read_sections_per_s_tcp", Json::num(tcp.read_sections_per_s)),
        ("tcp_p99_overhead_x", Json::num(overhead)),
    ];
    let json_out = bench_dir.join("BENCH_transport.json");
    dipaco::metrics::write_summary(&json_out, summary).unwrap();
    println!("summary: {}", json_out.display());
    let _ = std::fs::remove_dir_all(&dir);
}
