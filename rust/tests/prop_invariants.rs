//! Property-based invariants via the in-repo testkit (proptest stand-in):
//! topology/module algebra, queue exactly-once retirement under random
//! failure schedules, outer-averaging equivalences, checkpoint and JSON
//! round-trips.

use std::sync::mpsc::channel;
use std::sync::Mutex;
use std::time::Duration;

use dipaco::config::{DilocoConfig, StemPlacement, TopologySpec};
use dipaco::coordinator::db::CkptRow;
use dipaco::coordinator::outer::{executor_loop, OuterConfig};
use dipaco::coordinator::queue::TaskQueue;
use dipaco::coordinator::task::{Task, TrainTask};
use dipaco::optim::{Nesterov, OuterAccumulator};
use dipaco::params::checkpoint::Checkpoint;
use dipaco::params::manifest::Manifest;
use dipaco::testkit::forall;
use dipaco::topology::{ModuleStore, Topology};
use dipaco::util::json::Json;
use dipaco::util::rng::Rng;

/// Random miniature manifest mirroring the python layout.
fn fake_manifest(rng: &mut Rng) -> Manifest {
    let n_layers = 2 + rng.gen_range(5); // 2..=6
    let d = 4 * (1 + rng.gen_range(3)); // 4,8,12
    let mut leaves = Vec::new();
    let mut off = 0usize;
    let mut push = |name: String, shape: Vec<usize>, off: &mut usize| {
        let size: usize = shape.iter().product();
        leaves.push(format!(
            r#"{{"name":"{name}","offset":{off},"size":{size},"shape":[{}]}}"#,
            shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
        ));
        *off += size;
    };
    push("embed.tok".into(), vec![32, d], &mut off);
    push("embed.pos".into(), vec![16, d], &mut off);
    for i in 0..n_layers {
        push(format!("block{i}.attn.wq"), vec![d, d], &mut off);
        push(format!("block{i}.ln1.scale"), vec![d], &mut off);
        push(format!("block{i}.mlp.w1"), vec![d, 2 * d], &mut off);
    }
    push("head.w".into(), vec![d, 32], &mut off);
    let text = format!(
        r#"{{"preset":"prop","config":{{"vocab":32,"d_model":{d},"n_layers":{n_layers},
          "n_heads":2,"d_ff":{f},"seq_train":16,"seq_eval":16,"batch":1,"prefix":4,"d_head":{dh}}},
          "total_params":{off},"leaves":[{ls}],"entrypoints":[]}}"#,
        f = 2 * d,
        dh = d / 2,
        ls = leaves.join(",")
    );
    Manifest::from_json(&Json::parse(&text).unwrap()).unwrap()
}

fn random_spec(rng: &mut Rng, n_layers: usize) -> TopologySpec {
    let n_levels = 1 + rng.gen_range(n_layers.min(3));
    let experts: Vec<usize> = (0..n_levels).map(|_| 1 + rng.gen_range(4)).collect();
    let mut spec = TopologySpec::grid(experts);
    if rng.f64() < 0.3 {
        spec.stem = StemPlacement::PathSpecific;
    }
    if rng.f64() < 0.3 && n_layers > n_levels {
        spec.path_specific_blocks = vec![rng.gen_range(n_layers)];
    }
    spec
}

#[test]
fn prop_topology_covers_every_param_exactly_once() {
    forall(
        "coverage",
        100,
        40,
        |rng| {
            let man = fake_manifest(rng);
            let spec = random_spec(rng, man.model.n_layers);
            (man, spec)
        },
        |(man, spec)| {
            let topo = Topology::build(man, spec);
            let mut count = vec![0u32; man.total_params];
            for level in &topo.levels {
                for r in &level.segments {
                    for i in r.clone() {
                        count[i] += 1;
                    }
                }
            }
            if count.iter().all(|&c| c == 1) {
                Ok(())
            } else {
                Err(format!(
                    "{} params not covered exactly once",
                    count.iter().filter(|&&c| c != 1).count()
                ))
            }
        },
    );
}

#[test]
fn prop_assemble_then_split_is_identity() {
    forall(
        "assemble/split",
        200,
        30,
        |rng| {
            let man = fake_manifest(rng);
            let spec = random_spec(rng, man.model.n_layers);
            let theta: Vec<f32> = (0..man.total_params).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            (man, spec, theta)
        },
        |(man, spec, theta)| {
            let topo = Topology::build(man, spec);
            let store = ModuleStore::from_base(&topo, theta);
            for p in 0..topo.paths {
                let assembled = store.assemble(&topo, p);
                if &assembled != theta {
                    return Err(format!("path {p} assembly differs from base"));
                }
                // split of (theta - assembled) must be all zeros
                for (mid, delta) in topo.split_delta(p, theta, &assembled) {
                    if delta.iter().any(|&x| x != 0.0) {
                        return Err(format!("nonzero delta for module {mid}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_split_delta_sections_reconstruct_exactly() {
    // Worker-side exchange invariant: the per-module `delta:L{l}E{e}`
    // sections a worker ships, scattered back into a flat vector, must
    // equal `before - after` BIT-FOR-BIT (executors never see the full
    // vectors, so any drift here would silently corrupt the outer step).
    forall(
        "split sections reconstruct",
        250,
        30,
        |rng| {
            let man = fake_manifest(rng);
            let spec = random_spec(rng, man.model.n_layers);
            let before: Vec<f32> =
                (0..man.total_params).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let after: Vec<f32> = before
                .iter()
                .map(|v| v * 0.9 + rng.normal_f32(0.0, 0.1))
                .collect();
            (man, spec, before, after)
        },
        |(man, spec, before, after)| {
            let topo = Topology::build(man, spec);
            for p in 0..topo.paths {
                let parts = topo.split_delta(p, before, after);
                if parts.len() != topo.levels.len() {
                    return Err(format!("path {p}: {} sections", parts.len()));
                }
                let mut recon = vec![0.0f32; man.total_params];
                for (mid, delta) in &parts {
                    if delta.len() != topo.levels[mid.level].size {
                        return Err(format!("module {mid}: wrong section size"));
                    }
                    topo.scatter(mid.level, delta, &mut recon);
                }
                for i in 0..recon.len() {
                    let want = before[i] - after[i];
                    if recon[i].to_bits() != want.to_bits() {
                        return Err(format!(
                            "path {p} index {i}: {} != {} (not exact)",
                            recon[i], want
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dpc1_checkpoints_migrate_to_dpc2() {
    // Format migration: files written by the previous revision (DPC1)
    // load, and re-saving produces a DPC2 file with identical content.
    forall(
        "dpc1 -> dpc2 migration",
        650,
        20,
        |rng| {
            let n_sections = 1 + rng.gen_range(4);
            (0..n_sections)
                .map(|i| {
                    let len = 1 + rng.gen_range(1500);
                    (
                        format!("delta:L{i}E{}", rng.gen_range(8)),
                        (0..len).map(|_| rng.normal_f32(0.0, 10.0)).collect::<Vec<f32>>(),
                    )
                })
                .collect::<Vec<_>>()
        },
        |sections| {
            let mut ck = Checkpoint::new();
            for (name, data) in sections {
                ck = ck.with(name, data.clone());
            }
            let stem = std::env::temp_dir().join(format!(
                "dipaco-prop-mig-{}-{:x}",
                std::process::id(),
                sections.iter().map(|(_, d)| d.len()).sum::<usize>()
            ));
            let p1 = stem.with_extension("v1.dpc");
            let p2 = stem.with_extension("v2.dpc");
            ck.save_dpc1(&p1).map_err(|e| e.to_string())?;
            let loaded = Checkpoint::load(&p1).map_err(|e| e.to_string())?;
            if loaded != ck {
                return Err("dpc1 load mismatch".into());
            }
            loaded.save(&p2).map_err(|e| e.to_string())?;
            let migrated = Checkpoint::load(&p2).map_err(|e| e.to_string())?;
            if migrated != ck {
                return Err("dpc2 re-save mismatch".into());
            }
            // random access agrees with the full load on every section
            for (name, data) in sections {
                let got = dipaco::params::checkpoint::load_section(&p2, name)
                    .map_err(|e| e.to_string())?;
                if &got != data {
                    return Err(format!("section {name} random-access mismatch"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_paths_through_consistent_with_enumeration() {
    forall(
        "paths_through",
        300,
        30,
        |rng| {
            let man = fake_manifest(rng);
            let spec = random_spec(rng, man.model.n_layers);
            (man, spec)
        },
        |(man, spec)| {
            let topo = Topology::build(man, spec);
            for m in topo.all_modules() {
                let listed = topo.paths_of_module(m).len();
                let claimed = topo.paths_through(m);
                if listed != claimed {
                    return Err(format!("module {m}: {listed} enumerated vs {claimed} claimed"));
                }
            }
            // every path traverses exactly one expert per level
            for p in 0..topo.paths {
                let mods = topo.modules_of_path(p);
                if mods.len() != topo.levels.len() {
                    return Err(format!("path {p} traverses {} levels", mods.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_weighted_online_average_equals_batch() {
    forall(
        "online average",
        400,
        50,
        |rng| {
            let n = 1 + rng.gen_range(32);
            let k = 1 + rng.gen_range(8);
            let deltas: Vec<(Vec<f32>, f64)> = (0..k)
                .map(|_| {
                    (
                        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                        0.5 + rng.f64() * 10.0,
                    )
                })
                .collect();
            deltas
        },
        |deltas| {
            let n = deltas[0].0.len();
            let mut acc = OuterAccumulator::new(n);
            for (d, w) in deltas {
                acc.add(d, *w);
            }
            let avg = acc.average();
            let total_w: f64 = deltas.iter().map(|(_, w)| w).sum();
            for j in 0..n {
                let batch: f64 =
                    deltas.iter().map(|(d, w)| d[j] as f64 * w).sum::<f64>() / total_w;
                if (avg[j] as f64 - batch).abs() > 1e-4 {
                    return Err(format!("dim {j}: {} vs {batch}", avg[j]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_queue_exactly_once_under_random_failures() {
    forall(
        "queue exactly-once",
        500,
        8,
        |rng| {
            let n_tasks = 5 + rng.gen_range(30);
            let n_workers = 1 + rng.gen_range(6);
            let fail_p = rng.f64() * 0.5;
            (n_tasks as u64, n_workers, fail_p, rng.next_u64())
        },
        |&(n_tasks, n_workers, fail_p, seed)| {
            let q = std::sync::Arc::new(TaskQueue::new(Duration::from_millis(25)));
            for i in 0..n_tasks {
                q.push(Task::Train(TrainTask {
                    id: i + 1,
                    phase: 0,
                    path: i as usize,
                    steps: 1,
                    start_step: 0,
                    ckpt_in: "x".into(),
                    ckpt_out: "y".into(),
                    opt_in: None,
                    opt_out: "o_out".into(),
                }))
                .expect("property-test queue is open");
            }
            let retired = std::sync::Arc::new(std::sync::Mutex::new(Vec::<u64>::new()));
            std::thread::scope(|s| {
                for w in 0..n_workers {
                    let q = std::sync::Arc::clone(&q);
                    let retired = std::sync::Arc::clone(&retired);
                    s.spawn(move || {
                        let mut rng = Rng::new(seed ^ w as u64);
                        while let Some((lease, task)) =
                            q.lease(&format!("w{w}"), Duration::from_millis(150))
                        {
                            let r = rng.f64();
                            if r < fail_p / 2.0 {
                                continue; // hard crash: lease expires
                            } else if r < fail_p {
                                q.fail(lease); // graceful preemption
                                continue;
                            }
                            if q.complete(lease) {
                                retired.lock().unwrap().push(task.id());
                            }
                        }
                    });
                }
                q.wait_idle(Duration::from_millis(5));
                q.close();
            });
            let mut ids = retired.lock().unwrap().clone();
            ids.sort();
            let expect: Vec<u64> = (1..=n_tasks).collect();
            if ids == expect {
                Ok(())
            } else {
                Err(format!("retired {} of {} tasks (dups or losses)", ids.len(), n_tasks))
            }
        },
    );
}

#[test]
fn prop_random_fault_delivery_never_double_accumulates() {
    // Chaos-harness invariant: whatever at-least-once delivery order the
    // fault plane produces (duplicates from zombie re-publication, any
    // shuffle from stragglers/reorders), the executor must accumulate each
    // path's checkpoint EXACTLY once and land on a bit-identical store —
    // the (phase, path) dedup plus the path-id-sorted quorum reduce.
    forall(
        "no double accumulation under random delivery",
        800,
        8,
        |rng| {
            let man = fake_manifest(rng);
            let spec = random_spec(rng, man.model.n_layers);
            (man, spec, rng.next_u64())
        },
        |(man, spec, seed)| {
            let topo = Topology::build(man, spec);
            let theta: Vec<f32> = {
                let mut rng = Rng::new(*seed);
                (0..man.total_params).map(|_| rng.normal_f32(0.0, 1.0)).collect()
            };
            let dir = std::env::temp_dir().join(format!(
                "dipaco-prop-chaos-{}-{seed:x}",
                std::process::id()
            ));
            std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
            let rows: Vec<CkptRow> = (0..topo.paths)
                .map(|p| {
                    let after: Vec<f32> = theta
                        .iter()
                        .enumerate()
                        .map(|(i, &v)| v * 0.99 - 0.001 * ((i + 7 * p) % 13) as f32)
                        .collect();
                    let (ck, modules) = topo.delta_checkpoint(p, &theta, &after);
                    let file = dir.join(format!("path{p}.dpc"));
                    ck.save(&file).map_err(|e| e.to_string())?;
                    Ok(CkptRow {
                        rowid: 0,
                        phase: 0,
                        path_id: p,
                        kind: "path".into(),
                        file,
                        step: 1,
                        loss: 1.0,
                        modules,
                    })
                })
                .collect::<Result<_, String>>()?;
            let owned = topo.all_modules();
            let run = |deliveries: &[usize]| -> Result<ModuleStore, String> {
                let store = Mutex::new(ModuleStore::from_base(&topo, &theta));
                let cfg = OuterConfig {
                    diloco: DilocoConfig::default(),
                    shard_sizes: vec![1; topo.paths],
                    ..Default::default()
                };
                let mut opt = Nesterov::new(cfg.diloco.outer_lr, cfg.diloco.outer_momentum);
                let (tx, rx) = channel();
                for &i in deliveries {
                    tx.send(rows[i].clone()).unwrap();
                }
                drop(tx); // starvation would surface as a channel-closed error
                let (done_tx, _done_rx) = channel();
                executor_loop(&topo, &store, &mut opt, &owned, &cfg, 0, &rx, &done_tx)
                    .map_err(|e| format!("{e:#}"))?;
                Ok(store.into_inner().unwrap())
            };
            // canonical: each row once, in path order
            let canonical: Vec<usize> = (0..topo.paths).collect();
            let reference = run(&canonical)?;
            // faulted: shuffled at-least-once schedule with duplicates
            let schedule =
                dipaco::testkit::gens::delivery_schedule(&mut Rng::new(*seed), topo.paths, 3);
            let faulted = run(&schedule)?;
            for m in topo.all_modules() {
                for (i, (x, y)) in reference.get(m).iter().zip(faulted.get(m)).enumerate() {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!(
                            "module {m}[{i}] diverged under schedule {schedule:?}: {x} vs {y}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_checkpoint_roundtrip_arbitrary_sections() {
    forall(
        "checkpoint roundtrip",
        600,
        25,
        |rng| {
            let n_sections = rng.gen_range(5);
            (0..n_sections)
                .map(|i| {
                    let len = rng.gen_range(2000);
                    (
                        format!("sec{i}"),
                        (0..len).map(|_| rng.normal_f32(0.0, 10.0)).collect::<Vec<f32>>(),
                    )
                })
                .collect::<Vec<_>>()
        },
        |sections| {
            let mut ck = Checkpoint::new();
            for (name, data) in sections {
                ck = ck.with(name, data.clone());
            }
            let p = std::env::temp_dir().join(format!(
                "dipaco-prop-ck-{}-{:x}.dpc",
                std::process::id(),
                sections.iter().map(|(_, d)| d.len()).sum::<usize>()
            ));
            ck.save(&p).map_err(|e| e.to_string())?;
            let back = Checkpoint::load(&p).map_err(|e| e.to_string())?;
            if back == ck {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        },
    );
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.normal_f32(0.0, 1000.0) as f64 * 100.0).round() / 100.0),
            3 => Json::Str(
                (0..rng.gen_range(12))
                    .map(|_| char::from_u32(32 + rng.gen_range(90) as u32).unwrap())
                    .collect(),
            ),
            4 => Json::Arr((0..rng.gen_range(4)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.gen_range(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(
        "json roundtrip",
        700,
        60,
        |rng| gen_value(rng, 3),
        |v| {
            let s = v.to_string();
            let back = Json::parse(&s).map_err(|e| e.to_string())?;
            if &back == v {
                Ok(())
            } else {
                Err(format!("{s} reparsed differently"))
            }
        },
    );
}
